"""Quickstart: the BVLSM key-value store in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API (put/get/range/delete), then the paper's core effect:
identical workload through the three systems, with write amplification and
the Key-ValueOffset separation visible in the engine stats.
"""
import shutil
import tempfile

from repro.core import DB, DBConfig, ShardedDB, WriteBatch

# --- 1. basic API ----------------------------------------------------------
d = tempfile.mkdtemp(prefix="bvlsm_quickstart_")
db = DB.open(d, DBConfig.bvlsm(wal_mode="sync", value_threshold=4096))

db.put(b"user/1", b"small value")  # < threshold: stays inline
db.put(b"user/2", b"B" * 65536)  # 64 KiB: separated at WAL time
db.put(b"user/3", b"C" * 16384)
print("get user/1:", db.get(b"user/1"))
print("get user/2:", len(db.get(b"user/2")), "bytes (via BValue store)")
db.delete(b"user/1")
print("after delete:", db.get(b"user/1"))
print("range user/:", [(k, len(v)) for k, v in db.range(b"user/", end=b"user0")])

# atomic multi-op batch: one WAL record, one fsync, all-or-nothing on crash
batch = WriteBatch()
batch.put(b"user/4", b"D" * 8192).put(b"user/5", b"small").delete(b"user/3")
db.write(batch)
print("after batch:", [(k, len(v)) for k, v in db.range(b"user/", end=b"user0")])

db.flush()
print("\nengine stats:", {k: v for k, v in db.stats.snapshot().items() if "bytes" in k})
print("BVCache:", db.bvcache.stats())
db.close()

# crash-safety: reopen and read back
db2 = DB.open(d, DBConfig.bvlsm(wal_mode="sync"))
assert db2.get(b"user/2") == b"B" * 65536
print("\nreopened after close — data intact")
db2.close()
shutil.rmtree(d)

# same surface, horizontally sharded: N independent engines behind one router
d = tempfile.mkdtemp(prefix="bvlsm_quickstart_sharded_")
sdb = ShardedDB.open(d, shards=4, config=DBConfig.bvlsm(wal_mode="sync"))
for i in range(8):
    sdb.put(f"user/{i}".encode(), b"E" * 8192)
print("\nsharded range:", [k for k, _ in sdb.range(b"user/", limit=8)])
print("per-shard writes:", [s["user_writes"] for s in sdb.stats()["per_shard"]])
sdb.close()
shutil.rmtree(d)

# --- 2. the paper's effect: one workload, three systems ---------------------
print("\nwrite amplification, 200 × 32 KiB random puts:")
import numpy as np

val = np.random.default_rng(0).bytes(32768)
for name, cfg in [
    ("rocksdb (none)", DBConfig.rocksdb_like(wal_mode="sync", memtable_size=1 << 20)),
    ("blobdb (flush)", DBConfig.blobdb_like(wal_mode="sync", memtable_size=1 << 20)),
    ("bvlsm (wal)   ", DBConfig.bvlsm(wal_mode="sync", memtable_size=1 << 20)),
]:
    d = tempfile.mkdtemp()
    db = DB(d, cfg)
    keys = [f"{i:08d}".encode() for i in np.random.default_rng(1).permutation(200)]
    for k in keys:
        db.put(k, val)
    db.flush()
    db.compact_all()
    st = db.stats.snapshot()
    print(
        f"  {name}: write_amp={st['write_amp']:.2f} "
        f"(wal={st['wal_bytes']>>10}KiB flush={st['flush_bytes']>>10}KiB "
        f"compact={st['compaction_bytes']>>10}KiB bvalue={st['bvalue_bytes']>>10}KiB)"
    )
    db.close()
    shutil.rmtree(d)
