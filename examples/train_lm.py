"""End-to-end training driver: an LM trained for a few hundred steps with
BVLSM-backed fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_lm.py                 # ~8M params, CPU-friendly
    PYTHONPATH=src python examples/train_lm.py --large         # ~100M params (accelerator-scale)
    PYTHONPATH=src python examples/train_lm.py --simulate-preemption

Demonstrates: data pipeline → jit'd train step (AdamW, remat, grad clip) →
async BVLSM checkpoints → kill/restart resume (exact data cursor).
"""
import argparse
import shutil

from repro.configs import get_config
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--simulate-preemption", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    base = get_config("llama3-8b")
    if args.large:  # ~100M
        cfg = base.reduced(d_model=640, n_layers=10, n_heads=10, n_kv_heads=5,
                           head_dim=64, d_ff=2560, vocab=32000, vocab_pad_multiple=128)
        batch, seq = 8, 512
    else:  # ~8M — a few hundred steps run in minutes on this CPU container
        cfg = base.reduced(d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
                           head_dim=32, d_ff=1024, vocab=8192, vocab_pad_multiple=128)
        batch, seq = 4, 128
    print(f"model: {cfg.params_count()/1e6:.1f}M params, {args.steps} steps")

    tcfg = TrainerConfig(
        steps=args.steps,
        global_batch=batch,
        seq_len=seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=50,
        ckpt_async=True,
        log_every=20,
        train=TrainConfig(
            opt=OptimizerConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
        ),
    )
    trainer = Trainer(cfg, tcfg)
    if args.simulate_preemption:
        orig_cb = trainer.pipeline.next_batch
        count = {"n": 0}

        def wrapped():
            count["n"] += 1
            if count["n"] == args.steps // 2:
                trainer._preempted = True  # as if SIGTERM arrived
            return orig_cb()

        trainer.pipeline.next_batch = wrapped

    try:
        result = trainer.run()
        ms = result["metrics"]
        if ms:
            print(f"\nstatus={result['status']} steps={result['step']}")
            print(f"loss {ms[0]['loss']:.4f} → {ms[-1]['loss']:.4f}")
            print(f"checkpoint loop-stall total: {trainer.ckpt.stall_seconds:.2f}s "
                  f"({trainer.ckpt.save_count} saves)")
            print("storage engine:", {k: v for k, v in trainer.store.stats().items()
                                      if k in ("write_amp", "wal_bytes", "bvalue_bytes")})
        if result["status"] == "preempted":
            print("\nre-run the same command to resume from the preemption checkpoint.")
    finally:
        trainer.close()


if __name__ == "__main__":
    main()
