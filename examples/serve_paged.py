"""Serving example: continuous batching over the BVLSM-style paged KV cache,
plus the paged flash-decode Pallas kernel consuming the same page tables
(interpret mode on CPU; native on TPU).

    PYTHONPATH=src python examples/serve_paged.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import paged_decode
from repro.kernels.ref import paged_decode_reference
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedKVCache

# --- 1. continuous-batching engine -----------------------------------------
cfg = get_config("qwen3-4b").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))

engine = ServingEngine(cfg, params, max_batch=4, max_len=128, page_size=32)
rng = np.random.default_rng(0)
for rid in range(10):
    engine.submit(Request(rid, rng.integers(1, cfg.vocab, 24).astype(np.int32), max_new_tokens=12))
t0 = time.monotonic()
done = engine.run_until_drained()
dt = time.monotonic() - t0
m = engine.metrics()
print(f"served {m['requests']} requests / {m['tokens']} tokens in {dt:.1f}s")
print(f"mean latency {m['mean_latency_s']*1e3:.0f} ms, TTFT {m['mean_ttft_s']*1e3:.0f} ms")

# --- 2. the BVLSM read path on TPU: page table → page gather → attention ----
print("\npaged flash-decode kernel (page table = Key-ValueOffset metadata):")
B, H, K, hd, P, page, maxp = 4, 8, 4, 64, 32, 128, 4
kv = PagedKVCache(P, page, n_layers=1, n_kv_heads=K, head_dim=hd, max_pages_per_seq=maxp, dtype=jnp.float32)
for sid in range(B):
    kv.admit(sid, prompt_len=int(rng.integers(100, maxp * page)))
pt = jnp.asarray(kv.page_table(range(B)))
lengths = jnp.asarray(kv.lengths(range(B)))
pages_k = jnp.asarray(rng.normal(size=(P, page, K, hd)), jnp.float32)
pages_v = jnp.asarray(rng.normal(size=(P, page, K, hd)), jnp.float32)
q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)

out = paged_decode(q, pages_k, pages_v, pt, lengths, interpret=True)  # Pallas kernel
ref = paged_decode_reference(q, pages_k, pages_v, pt, lengths)
print(f"  kernel vs oracle max|Δ| = {float(jnp.max(jnp.abs(out-ref))):.2e}")
print(f"  page-table bytes per seq: {pt.shape[1]*4} B — the only metadata the scheduler touches")
print(f"  arena utilization: {kv.utilization():.0%}")
