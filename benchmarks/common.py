"""Shared benchmark helpers: engine construction per system, key/value
generation matching the paper's methodology (16 B keys; 4–64 KiB values),
and result formatting."""
from __future__ import annotations

import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import DB, DBConfig

SYSTEMS = {
    "rocksdb": "none",  # coupled KV — RocksDB baseline
    "blobdb": "flush",  # flush-time separation — BlobDB/WiscKey baseline
    "bvlsm": "wal",  # WAL-time separation — the paper
}

WAL_MODES = ["off", "async", "sync"]
KEY_SIZE = 16


def zipf_indices(rng, n_records: int, count: int, theta: float = 0.99) -> np.ndarray:
    """Standard YCSB zipfian sample via rejection-free inverse CDF
    approximation (shared by ycsb.py and readpath.py so their hot-set
    workloads stay comparable)."""
    ranks = np.arange(1, n_records + 1, dtype=np.float64)
    probs = 1.0 / ranks**theta
    probs /= probs.sum()
    return rng.choice(n_records, size=count, p=probs)


def make_db(system: str, wal_mode: str, workdir: str | None = None, **overrides) -> tuple[DB, str]:
    path = workdir or tempfile.mkdtemp(prefix=f"bench_{system}_{wal_mode}_")
    kw = dict(
        separation_mode=SYSTEMS[system],
        wal_mode=wal_mode,
        value_threshold=4096,
        memtable_size=8 << 20,
        level1_max_bytes=32 << 20,
        num_bvalue_queues=4,
        bvcache_bytes=8 << 20,
    )
    kw.update(overrides)
    return DB(path, DBConfig(**kw)), path


def gen_keys(n: int, pattern: str, seed: int = 0) -> list[bytes]:
    if pattern == "seq":
        return [f"{i:016d}".encode() for i in range(n)]
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n)
    return [f"{i:016d}".encode() for i in ids]


def gen_value(size: int, seed: int) -> bytes:
    # mildly compressible payload, deterministic
    rng = np.random.default_rng(seed)
    return rng.integers(0, 64, size=size, dtype=np.uint8).tobytes()


def run_fill(db: DB, keys: list[bytes], value_size: int, threads: int = 1) -> dict:
    """Fill the DB with `keys`; threads > 1 partitions the keyspace across
    concurrent writers (exercises the group-commit write pipeline)."""
    val = gen_value(value_size, 7)
    t0 = time.monotonic()
    if threads <= 1:
        for k in keys:
            db.put(k, val)
    else:
        errors: list[BaseException] = []

        def fill(part: list[bytes]) -> None:
            try:
                for k in part:
                    db.put(k, val)
            except BaseException as e:  # surface instead of dying silently
                errors.append(e)

        ts = [
            threading.Thread(target=fill, args=(keys[i::threads],))
            for i in range(threads)
        ]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        if errors:
            raise errors[0]
    db.flush()
    dt = time.monotonic() - t0
    user_mb = len(keys) * (KEY_SIZE + value_size) / 1e6
    st = db.stats.snapshot()
    return {
        "seconds": dt,
        "mb_per_s": user_mb / dt,
        "ops_per_s": len(keys) / dt,
        "write_amp": st["write_amp"],
        "stall_s": st["stall_seconds"],
        "device_mb": st["device_bytes"] / 1e6,
        "fsyncs_per_write": st["fsyncs_per_write"],
        "avg_group_size": st["avg_group_size"],
        "group_size_hist": st["group_size_hist"],
    }


def cleanup(db: DB, path: str) -> None:
    try:
        db.close()
    finally:
        shutil.rmtree(path, ignore_errors=True)
