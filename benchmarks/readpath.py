"""Read-path micro-benchmark: shared block cache + restart-point blocks.

Measures point-get and short-scan ops/s over a multi-level LSM across the
PR-3 read-stack grid:

* workload — ``uniform`` (random over the whole keyspace) vs ``zipfian``
  (YCSB-style hot set, theta 0.99: the workload a block cache exists for),
  plus ``scan`` (``scan(start, 10)`` from uniform-random starts),
  ``cursor`` (PR-7 iterator: ``seek(start)`` + 10 × ``next()`` on a pinned
  snapshot view — the streaming path ``scan`` is now a wrapper over), and
  ``multiget`` (PR-9 batched path: the same zipfian key stream issued as
  ``multi_get`` batches of 64 — one memtable/version resolve, vectorized
  bloom probes, and block-coalesced table reads per batch);
* cache — shared block cache on (default capacity) vs ``block_cache_bytes=0``;
* format — SSTable block format ``v4`` (prefix-compressed keys inside
  restart intervals) vs ``v2`` (restart points, intra-block binary search)
  vs ``v1`` (the pre-PR-3 linear-decode blocks).

Each (format, cache) variant gets its own DB, filled identically (inline
values — the bench isolates the key/metadata path from BValue separation)
with a small memtable so the data spreads over L0/L1/L2, then compacted to
quiescence. Measurement rounds are interleaved ACROSS variants (round-robin,
like ``benchmarks/writepath.py``) so a slow container-I/O period hits every
variant equally; the MEDIAN round is recorded (``--repeat N``).

Emits ``BENCH_readpath.json``. Row schema (one row = one ``cells`` entry)::

    workload            str    "uniform" | "zipfian" | "scan"
    format              int    1 | 2 (sstable_format_version of the DB)
    cache               bool   block cache enabled for this DB
    n                   int    timed operations in the recorded round
    seconds             float  wall time of the recorded round
    ops_per_s           float  n / seconds
    block_cache_hit_rate float cache hit rate at round end (0.0 cache-off)
    block_cache_hits/misses/evictions  int  cumulative cache counters
    samples_ops_per_s   list   every round's ops/s, ascending (median recorded)

``summary`` holds the trajectory numbers:

* ``zipfian_cache_speedup_v2`` — zipfian point-get ops/s, cache on ÷ off,
  v2 blocks (the headline: the acceptance floor is 2.0);
* ``zipfian_cache_speedup_v1`` — same on v1 blocks;
* ``uniform_v2_over_v1_cache_off`` — uniform point-gets, v2 ÷ v1 with the
  cache disabled (isolates restart-point binary search vs linear decode —
  the only cells where the block format is actually in the lookup loop;
  must be >= ~1.0);
* ``uniform_cache_speedup_v2`` / ``scan_cache_speedup_v2`` — secondary
  dimensions;
* ``cursor_cache_speedup_v2`` — cursor walks, cache on ÷ off (v2);
* ``cursor_vs_scan_v2_cache_on`` — cursor walk ÷ ``scan`` ops/s, v2 with
  the cache on; ``scan`` streams from the same cursor, so this ratio is
  the wrapper overhead and should sit near 1.0;
* ``multiget_speedup_v4`` — the PR-9 headline: batch-64 ``multi_get``
  keys/s ÷ sequential ``get`` ops/s, same zipfian stream, v4 cache-on
  (acceptance floor 1.5; the batch amortizes per-op snapshot/version
  resolution and probes all table blooms in one numpy pass);
* ``uniform_v4_over_v2_cache_off`` — uniform point-gets, v4 ÷ v2 with the
  cache disabled: prefix-compressed blocks must NOT regress scalar gets
  (restart entries are self-parseable, so binary search is unchanged and
  only the short intra-interval walk decodes prefixes);
* ``mixed_2q_over_lru_hit_rate`` — from the mixed cells: point-get hit
  rate under 2Q ÷ under plain LRU when a cursor sweep of the whole
  keyspace is interleaved with hot-set point-gets against a cache far
  smaller than the sweep (scan resistance: must be > 1).

Mixed cells (``workload == "mixed"``) run OUTSIDE the main grid: two
identical v4 DBs differing only in ``block_cache_policy`` serve rounds of
hot-set point-gets punctuated by full-keyspace cursor sweeps; the recorded
``hit_rate`` counts the point-get phases only (deltas around each phase),
because the sweep phase misses almost everything under either policy.

The summary deliberately carries NO cache-on v1-vs-v2 ratio: warm cached
blocks serve from materialized key→entry dicts, a code path identical for
both formats, so that ratio only measures DB-instance noise (allocator
layout, build order — empirically ±10% either way on this container). The
raw cache-on cells stay in ``cells`` for transparency.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import numpy as np

from repro.core import DB, DBConfig

from .common import zipf_indices

VALUE_SIZE = 100  # inline (< value_threshold): isolates the key/block path
KEY_FMT = "user%012d"

MULTIGET_BATCH = 64

VARIANTS = [  # (format_version, cache_enabled)
    (4, True),
    (4, False),
    (2, True),
    (2, False),
    (1, True),
    (1, False),
]


def _build_db(fmt: int, cache: bool, records: int, **overrides) -> tuple[DB, str]:
    path = tempfile.mkdtemp(prefix=f"rp_v{fmt}_{'c' if cache else 'n'}_")
    kw = dict(
        separation_mode="wal",
        wal_mode="off",  # fill speed; reads never touch the WAL
        value_threshold=4096,
        memtable_size=256 << 10,  # small: force rotations + compactions
        # drain L0 completely: compaction timing is nondeterministic, and
        # two variants ending with different L0 file counts would pay
        # different per-get candidate/bloom costs — the grid must compare
        # formats and caching over IDENTICAL tree shapes.
        l0_compaction_trigger=1,
        sstable_format_version=fmt,
        block_cache_bytes=(8 << 20) if cache else 0,
    )
    kw.update(overrides)
    db = DB(path, DBConfig(**kw))
    val = b"\x5a" * VALUE_SIZE
    for i in range(records):
        db.put((KEY_FMT % i).encode(), val)
    db.flush()
    db.compact_all()
    return db, path


def _time_gets(db: DB, keys: list[bytes]) -> float:
    get = db.get
    t0 = time.monotonic()
    for k in keys:
        if get(k) is None:
            raise RuntimeError("benchmark key missing")
    return time.monotonic() - t0


def _time_scans(db: DB, starts: list[bytes], count: int) -> float:
    rng = db.range
    t0 = time.monotonic()
    for s in starts:
        for _ in rng(s, limit=count):
            pass
    return time.monotonic() - t0


def _time_multi_gets(db: DB, keys: list[bytes], batch: int = MULTIGET_BATCH) -> float:
    mg = db.multi_get
    t0 = time.monotonic()
    for i in range(0, len(keys), batch):
        chunk = keys[i : i + batch]
        got = mg(chunk)
        if any(v is None for v in got):
            raise RuntimeError("benchmark key missing")
    return time.monotonic() - t0


def _time_cursors(db: DB, starts: list[bytes], count: int) -> float:
    t0 = time.monotonic()
    for s in starts:
        with db.iterator() as cur:
            ok = cur.seek(s)
            n = 0
            while ok and n < count:
                n += 1
                ok = cur.next()
    return time.monotonic() - t0


def _run_mixed_policy(records: int, rounds: int = 10, hot_gets: int = 200) -> list[dict]:
    """Scan-resistance cells: hot-set point-gets interleaved with full
    cursor sweeps, cache ~2x+ smaller than the swept data, 2Q vs LRU.

    Geometry matters here: 512 B blocks make the sweep span hundreds of
    blocks while the hot set (first 80 records) stays inside a ~20-block
    working set, and the 128 KiB cache is sized so the hot set fits in Am
    but a single sweep overflows the whole budget — the exact regime where
    LRU loses its working set and 2Q must not."""
    rng = np.random.default_rng(7)
    hot = [(KEY_FMT % i).encode() for i in
           rng.integers(0, min(80, records), size=hot_gets)]
    cache_bytes = 128 << 10
    cells = []
    for policy in ("2q", "lru"):
        db, path = _build_db(4, True, records,
                             block_cache_policy=policy,
                             block_cache_bytes=cache_bytes,
                             block_size=512)
        try:
            hits = misses = 0
            t_get = 0.0
            _time_gets(db, hot)  # warm: earn Am residency before measuring
            for _ in range(rounds):
                with db.iterator() as cur:  # the sweep a cache must survive
                    ok = cur.seek(b"")
                    while ok:
                        ok = cur.next()
                st0 = db.stats.snapshot()
                t_get += _time_gets(db, hot)
                st1 = db.stats.snapshot()
                hits += st1["block_cache_hits"] - st0["block_cache_hits"]
                misses += st1["block_cache_misses"] - st0["block_cache_misses"]
            total = hits + misses
            cells.append({
                "workload": "mixed",
                "format": 4,
                "cache": True,
                "cache_policy": policy,
                "n": rounds * hot_gets,
                "seconds": t_get,
                "ops_per_s": rounds * hot_gets / t_get,
                "hit_rate": hits / total if total else 0.0,
                "cache_bytes": cache_bytes,
            })
            print(
                f"mixed    v4 policy={policy:3s}: "
                f"{cells[-1]['ops_per_s']:9.0f} ops/s  "
                f"pointget_hit_rate={cells[-1]['hit_rate']:.2f}",
                flush=True,
            )
        finally:
            db.close()
            shutil.rmtree(path, ignore_errors=True)
    return cells


def run(records: int = 8000, ops: int = 12000, scans: int = 600,
        scan_count: int = 10, repeat: int = 3) -> dict:
    rng = np.random.default_rng(42)
    zipf_keys = [(KEY_FMT % i).encode() for i in zipf_indices(rng, records, ops)]
    uni_keys = [(KEY_FMT % i).encode() for i in rng.integers(0, records, size=ops)]
    starts = [(KEY_FMT % i).encode() for i in rng.integers(0, records, size=scans)]

    dbs: dict[tuple[int, bool], tuple[DB, str]] = {}
    cells: list[dict] = []
    try:
        for fmt, cache in VARIANTS:
            dbs[(fmt, cache)] = _build_db(fmt, cache, records)
            print(f"built v{fmt} cache={'on' if cache else 'off'}", flush=True)
        # warm every variant identically (cache-on variants fill their LRU;
        # cache-off variants get the page cache equally hot)
        for db, _ in dbs.values():
            _time_gets(db, zipf_keys[: ops // 4])
            _time_gets(db, uni_keys[: ops // 4])

        workloads = {
            "zipfian": lambda db: (len(zipf_keys), _time_gets(db, zipf_keys)),
            "uniform": lambda db: (len(uni_keys), _time_gets(db, uni_keys)),
            "multiget": lambda db: (len(zipf_keys), _time_multi_gets(db, zipf_keys)),
            "scan": lambda db: (len(starts), _time_scans(db, starts, scan_count)),
            "cursor": lambda db: (len(starts), _time_cursors(db, starts, scan_count)),
        }
        samples: dict[tuple, list[dict]] = {
            (w, fmt, cache): [] for w in workloads for fmt, cache in VARIANTS
        }
        for _ in range(repeat):
            for workload, fn in workloads.items():
                for fmt, cache in VARIANTS:
                    db, _ = dbs[(fmt, cache)]
                    n, dt = fn(db)
                    st = db.stats.snapshot()
                    samples[(workload, fmt, cache)].append({
                        "workload": workload,
                        "format": fmt,
                        "cache": cache,
                        "n": n,
                        "seconds": dt,
                        "ops_per_s": n / dt,
                        "block_cache_hit_rate": st["block_cache_hit_rate"],
                        "block_cache_hits": st["block_cache_hits"],
                        "block_cache_misses": st["block_cache_misses"],
                        "block_cache_evictions": st["block_cache_evictions"],
                    })
        for key, rounds in samples.items():
            ranked = sorted(rounds, key=lambda c: c["ops_per_s"])
            cell = ranked[len(ranked) // 2]
            cell["samples_ops_per_s"] = [round(c["ops_per_s"], 1) for c in ranked]
            cells.append(cell)
            workload, fmt, cache = key
            print(
                f"{workload:8s} v{fmt} cache={'on ' if cache else 'off'}: "
                f"{cell['ops_per_s']:9.0f} ops/s  "
                f"hit_rate={cell['block_cache_hit_rate']:.2f}",
                flush=True,
            )
    finally:
        for db, path in dbs.values():
            try:
                db.close()
            finally:
                shutil.rmtree(path, ignore_errors=True)

    def cell(workload, fmt, cache):
        return next(
            c for c in cells
            if c["workload"] == workload and c["format"] == fmt and c["cache"] == cache
        )["ops_per_s"]

    mixed = _run_mixed_policy(records)
    mixed_rate = {c["cache_policy"]: c["hit_rate"] for c in mixed}
    cells.extend(mixed)

    summary = {
        "zipfian_cache_speedup_v2": cell("zipfian", 2, True) / cell("zipfian", 2, False),
        "zipfian_cache_speedup_v1": cell("zipfian", 1, True) / cell("zipfian", 1, False),
        "zipfian_cache_speedup_v4": cell("zipfian", 4, True) / cell("zipfian", 4, False),
        "uniform_cache_speedup_v2": cell("uniform", 2, True) / cell("uniform", 2, False),
        "uniform_v2_over_v1_cache_off": cell("uniform", 2, False) / cell("uniform", 1, False),
        "uniform_v4_over_v2_cache_off": cell("uniform", 4, False) / cell("uniform", 2, False),
        "scan_cache_speedup_v2": cell("scan", 2, True) / cell("scan", 2, False),
        "cursor_cache_speedup_v2": cell("cursor", 2, True) / cell("cursor", 2, False),
        "cursor_vs_scan_v2_cache_on": cell("cursor", 2, True) / cell("scan", 2, True),
        "multiget_speedup_v4": cell("multiget", 4, True) / cell("zipfian", 4, True),
        "multiget_speedup_v4_cache_off": cell("multiget", 4, False) / cell("zipfian", 4, False),
        "mixed_2q_hit_rate": mixed_rate["2q"],
        "mixed_lru_hit_rate": mixed_rate["lru"],
        "mixed_2q_over_lru_hit_rate": mixed_rate["2q"] / max(mixed_rate["lru"], 1e-9),
    }
    return {
        "config": {
            "records": records, "ops": ops, "scans": scans,
            "scan_count": scan_count, "value_size": VALUE_SIZE, "repeat": repeat,
            "multiget_batch": MULTIGET_BATCH,
        },
        "cells": cells,
        "summary": summary,
    }


def main() -> None:
    ap = argparse.ArgumentParser()

    def positive(v: str) -> int:
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--records", type=positive, default=8000)
    ap.add_argument("--ops", type=positive, default=12000)
    ap.add_argument("--scans", type=positive, default=600)
    ap.add_argument("--scan-count", type=positive, default=10)
    ap.add_argument("--repeat", type=positive, default=3,
                    help="median-of-N per cell, rounds interleaved across variants")
    ap.add_argument("--out", default="BENCH_readpath.json")
    args = ap.parse_args()
    res = run(records=args.records, ops=args.ops, scans=args.scans,
              scan_count=args.scan_count, repeat=args.repeat)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print("summary:", {k: round(v, 2) for k, v in res["summary"].items()})


if __name__ == "__main__":
    main()
