"""Read-path micro-benchmark: shared block cache + restart-point blocks.

Measures point-get and short-scan ops/s over a multi-level LSM across the
PR-3 read-stack grid:

* workload — ``uniform`` (random over the whole keyspace) vs ``zipfian``
  (YCSB-style hot set, theta 0.99: the workload a block cache exists for),
  plus ``scan`` (``scan(start, 10)`` from uniform-random starts) and
  ``cursor`` (PR-7 iterator: ``seek(start)`` + 10 × ``next()`` on a pinned
  snapshot view — the streaming path ``scan`` is now a wrapper over);
* cache — shared block cache on (default capacity) vs ``block_cache_bytes=0``;
* format — SSTable block format ``v2`` (restart points, intra-block binary
  search) vs ``v1`` (the pre-PR-3 linear-decode blocks).

Each (format, cache) variant gets its own DB, filled identically (inline
values — the bench isolates the key/metadata path from BValue separation)
with a small memtable so the data spreads over L0/L1/L2, then compacted to
quiescence. Measurement rounds are interleaved ACROSS variants (round-robin,
like ``benchmarks/writepath.py``) so a slow container-I/O period hits every
variant equally; the MEDIAN round is recorded (``--repeat N``).

Emits ``BENCH_readpath.json``. Row schema (one row = one ``cells`` entry)::

    workload            str    "uniform" | "zipfian" | "scan"
    format              int    1 | 2 (sstable_format_version of the DB)
    cache               bool   block cache enabled for this DB
    n                   int    timed operations in the recorded round
    seconds             float  wall time of the recorded round
    ops_per_s           float  n / seconds
    block_cache_hit_rate float cache hit rate at round end (0.0 cache-off)
    block_cache_hits/misses/evictions  int  cumulative cache counters
    samples_ops_per_s   list   every round's ops/s, ascending (median recorded)

``summary`` holds the trajectory numbers:

* ``zipfian_cache_speedup_v2`` — zipfian point-get ops/s, cache on ÷ off,
  v2 blocks (the headline: the acceptance floor is 2.0);
* ``zipfian_cache_speedup_v1`` — same on v1 blocks;
* ``uniform_v2_over_v1_cache_off`` — uniform point-gets, v2 ÷ v1 with the
  cache disabled (isolates restart-point binary search vs linear decode —
  the only cells where the block format is actually in the lookup loop;
  must be >= ~1.0);
* ``uniform_cache_speedup_v2`` / ``scan_cache_speedup_v2`` — secondary
  dimensions;
* ``cursor_cache_speedup_v2`` — cursor walks, cache on ÷ off (v2);
* ``cursor_vs_scan_v2_cache_on`` — cursor walk ÷ ``scan`` ops/s, v2 with
  the cache on; ``scan`` streams from the same cursor, so this ratio is
  the wrapper overhead and should sit near 1.0.

The summary deliberately carries NO cache-on v1-vs-v2 ratio: warm cached
blocks serve from materialized key→entry dicts, a code path identical for
both formats, so that ratio only measures DB-instance noise (allocator
layout, build order — empirically ±10% either way on this container). The
raw cache-on cells stay in ``cells`` for transparency.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import numpy as np

from repro.core import DB, DBConfig

from .common import zipf_indices

VALUE_SIZE = 100  # inline (< value_threshold): isolates the key/block path
KEY_FMT = "user%012d"

VARIANTS = [  # (format_version, cache_enabled)
    (2, True),
    (2, False),
    (1, True),
    (1, False),
]


def _build_db(fmt: int, cache: bool, records: int) -> tuple[DB, str]:
    path = tempfile.mkdtemp(prefix=f"rp_v{fmt}_{'c' if cache else 'n'}_")
    db = DB(
        path,
        DBConfig(
            separation_mode="wal",
            wal_mode="off",  # fill speed; reads never touch the WAL
            value_threshold=4096,
            memtable_size=256 << 10,  # small: force rotations + compactions
            # drain L0 completely: compaction timing is nondeterministic, and
            # two variants ending with different L0 file counts would pay
            # different per-get candidate/bloom costs — the grid must compare
            # formats and caching over IDENTICAL tree shapes.
            l0_compaction_trigger=1,
            sstable_format_version=fmt,
            block_cache_bytes=(8 << 20) if cache else 0,
        ),
    )
    val = b"\x5a" * VALUE_SIZE
    for i in range(records):
        db.put((KEY_FMT % i).encode(), val)
    db.flush()
    db.compact_all()
    return db, path


def _time_gets(db: DB, keys: list[bytes]) -> float:
    get = db.get
    t0 = time.monotonic()
    for k in keys:
        if get(k) is None:
            raise RuntimeError("benchmark key missing")
    return time.monotonic() - t0


def _time_scans(db: DB, starts: list[bytes], count: int) -> float:
    scan = db.scan
    t0 = time.monotonic()
    for s in starts:
        scan(s, count)
    return time.monotonic() - t0


def _time_cursors(db: DB, starts: list[bytes], count: int) -> float:
    t0 = time.monotonic()
    for s in starts:
        with db.iterator() as cur:
            ok = cur.seek(s)
            n = 0
            while ok and n < count:
                n += 1
                ok = cur.next()
    return time.monotonic() - t0


def run(records: int = 8000, ops: int = 12000, scans: int = 600,
        scan_count: int = 10, repeat: int = 3) -> dict:
    rng = np.random.default_rng(42)
    zipf_keys = [(KEY_FMT % i).encode() for i in zipf_indices(rng, records, ops)]
    uni_keys = [(KEY_FMT % i).encode() for i in rng.integers(0, records, size=ops)]
    starts = [(KEY_FMT % i).encode() for i in rng.integers(0, records, size=scans)]

    dbs: dict[tuple[int, bool], tuple[DB, str]] = {}
    cells: list[dict] = []
    try:
        for fmt, cache in VARIANTS:
            dbs[(fmt, cache)] = _build_db(fmt, cache, records)
            print(f"built v{fmt} cache={'on' if cache else 'off'}", flush=True)
        # warm every variant identically (cache-on variants fill their LRU;
        # cache-off variants get the page cache equally hot)
        for db, _ in dbs.values():
            _time_gets(db, zipf_keys[: ops // 4])
            _time_gets(db, uni_keys[: ops // 4])

        workloads = {
            "zipfian": lambda db: (len(zipf_keys), _time_gets(db, zipf_keys)),
            "uniform": lambda db: (len(uni_keys), _time_gets(db, uni_keys)),
            "scan": lambda db: (len(starts), _time_scans(db, starts, scan_count)),
            "cursor": lambda db: (len(starts), _time_cursors(db, starts, scan_count)),
        }
        samples: dict[tuple, list[dict]] = {
            (w, fmt, cache): [] for w in workloads for fmt, cache in VARIANTS
        }
        for _ in range(repeat):
            for workload, fn in workloads.items():
                for fmt, cache in VARIANTS:
                    db, _ = dbs[(fmt, cache)]
                    n, dt = fn(db)
                    st = db.stats.snapshot()
                    samples[(workload, fmt, cache)].append({
                        "workload": workload,
                        "format": fmt,
                        "cache": cache,
                        "n": n,
                        "seconds": dt,
                        "ops_per_s": n / dt,
                        "block_cache_hit_rate": st["block_cache_hit_rate"],
                        "block_cache_hits": st["block_cache_hits"],
                        "block_cache_misses": st["block_cache_misses"],
                        "block_cache_evictions": st["block_cache_evictions"],
                    })
        for key, rounds in samples.items():
            ranked = sorted(rounds, key=lambda c: c["ops_per_s"])
            cell = ranked[len(ranked) // 2]
            cell["samples_ops_per_s"] = [round(c["ops_per_s"], 1) for c in ranked]
            cells.append(cell)
            workload, fmt, cache = key
            print(
                f"{workload:8s} v{fmt} cache={'on ' if cache else 'off'}: "
                f"{cell['ops_per_s']:9.0f} ops/s  "
                f"hit_rate={cell['block_cache_hit_rate']:.2f}",
                flush=True,
            )
    finally:
        for db, path in dbs.values():
            try:
                db.close()
            finally:
                shutil.rmtree(path, ignore_errors=True)

    def cell(workload, fmt, cache):
        return next(
            c for c in cells
            if c["workload"] == workload and c["format"] == fmt and c["cache"] == cache
        )["ops_per_s"]

    summary = {
        "zipfian_cache_speedup_v2": cell("zipfian", 2, True) / cell("zipfian", 2, False),
        "zipfian_cache_speedup_v1": cell("zipfian", 1, True) / cell("zipfian", 1, False),
        "uniform_cache_speedup_v2": cell("uniform", 2, True) / cell("uniform", 2, False),
        "uniform_v2_over_v1_cache_off": cell("uniform", 2, False) / cell("uniform", 1, False),
        "scan_cache_speedup_v2": cell("scan", 2, True) / cell("scan", 2, False),
        "cursor_cache_speedup_v2": cell("cursor", 2, True) / cell("cursor", 2, False),
        "cursor_vs_scan_v2_cache_on": cell("cursor", 2, True) / cell("scan", 2, True),
    }
    return {
        "config": {
            "records": records, "ops": ops, "scans": scans,
            "scan_count": scan_count, "value_size": VALUE_SIZE, "repeat": repeat,
        },
        "cells": cells,
        "summary": summary,
    }


def main() -> None:
    ap = argparse.ArgumentParser()

    def positive(v: str) -> int:
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--records", type=positive, default=8000)
    ap.add_argument("--ops", type=positive, default=12000)
    ap.add_argument("--scans", type=positive, default=600)
    ap.add_argument("--scan-count", type=positive, default=10)
    ap.add_argument("--repeat", type=positive, default=3,
                    help="median-of-N per cell, rounds interleaved across variants")
    ap.add_argument("--out", default="BENCH_readpath.json")
    args = ap.parse_args()
    res = run(records=args.records, ops=args.ops, scans=args.scans,
              scan_count=args.scan_count, repeat=args.repeat)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print("summary:", {k: round(v, 2) for k, v in res["summary"].items()})


if __name__ == "__main__":
    main()
