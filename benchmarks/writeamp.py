"""Write amplification — device bytes written per user byte stored.

The paper's headline pillar: BVLSM's WAL-time separation keeps big values
out of compaction rewrites. This benchmark isolates the *picking policy's*
contribution on top of that: the same 64 KiB workload — a sequential fill
of the key window (the phase where files land with disjoint ranges and a
write-amp-aware picker promotes them by trivial move instead of rewriting
them at every level) followed by random overwrites across the window (the
paper's 64 KiB random-write methodology: 16 B keys, bounded window so
overwrites keep compaction pressure up) — runs once per cell of

    system  ×  {overlap, fullness}

where ``overlap`` is overlap-ratio scoring + trivial moves
(``compaction_pick_policy="overlap", trivial_move=True``) and ``fullness``
is the fullness-only ablation baseline (legacy scoring, every input byte
rewritten). Byte counters — not timings — are the measurement, so cells
run single-background-thread for determinism and the workload sequence is
seeded and identical across cells.

Reported per cell: ``write_amp`` (total device bytes / user bytes — the
paper's metric), ``compaction_write_amp`` (compaction bytes / user bytes —
the slice the picking policy controls), ``trivial_moves`` and the raw byte
counters. Summary carries, per system, the overlap-vs-fullness ratio; the
committed trajectory gate (and the CI smoke gate) is

    write_amp(overlap) < write_amp(fullness)        [bvlsm, 64 KiB]

Output (``--out``): ``{schema, workload, cells, summary}`` — committed as
``BENCH_writeamp.json`` and uploaded by CI next to the other artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import KEY_SIZE, cleanup, gen_value, make_db

#: the two sides of the picking ablation
POLICIES = {
    "overlap": dict(compaction_pick_policy="overlap", trivial_move=True),
    "fullness": dict(compaction_pick_policy="fullness", trivial_move=False),
}


def run_cell(
    system: str,
    policy: str,
    keys: list[bytes],
    value: bytes,
    memtable_bytes: int,
    level1_bytes: int,
) -> dict:
    """One (system, policy) cell: identical seeded workload, quiesce, read
    the byte counters."""
    db, path = make_db(
        system,
        "async",
        memtable_size=memtable_bytes,
        level1_max_bytes=level1_bytes,
        l0_compaction_trigger=2,
        # determinism: byte counters, not throughput, are the measurement
        background_threads=1,
        max_subcompactions=1,
        **POLICIES[policy],
    )
    t0 = time.monotonic()
    try:
        for k in keys:
            db.put(k, value)
        db.flush()
        db.compact_all()
        dt = time.monotonic() - t0
        st = db.stats.snapshot()
    finally:
        cleanup(db, path)
    user = st["user_bytes_written"]
    return {
        "bench": "writeamp",
        "system": system,
        "policy": policy,
        "ops": len(keys),
        "seconds": round(dt, 3),
        "ops_per_s": round(len(keys) / dt, 1),
        "user_mb": round(user / 1e6, 2),
        "device_mb": round(st["device_bytes"] / 1e6, 2),
        "write_amp": st["write_amp"],
        "compaction_write_amp": st["compaction_bytes_written"] / user if user else 0.0,
        "compaction_bytes_written": st["compaction_bytes_written"],
        "flush_bytes": st["flush_bytes"],
        "wal_bytes": st["wal_bytes"],
        "bvalue_bytes": st["bvalue_bytes"],
        "trivial_moves": st["trivial_moves"],
        "trivial_move_bytes": st["trivial_move_bytes"],
        "compaction_count": st["compaction_count"],
    }


def run(
    ops: int,
    key_space: int,
    value_size: int,
    systems: list[str],
    memtable_bytes: int,
    level1_bytes: int,
    seed: int = 17,
) -> dict:
    rng = np.random.default_rng(seed)
    # phase 1: sequential fill — disjoint table ranges, the trivial-move
    # showcase; phase 2: random overwrites — the paper's random-write churn
    ids = list(range(key_space))
    ids.extend(rng.integers(0, key_space, size=max(0, ops - key_space)))
    keys = [f"{i:016d}".encode() for i in ids]
    value = gen_value(value_size, 23)
    cells = []
    for system in systems:
        if system == "bvlsm":
            mem, l1 = memtable_bytes, level1_bytes
        else:
            # values ride the memtable in these systems: scale the level
            # budgets up so the tree still develops multiple levels without
            # rotating on every single put (the comparison that matters is
            # within-system, overlap vs fullness, at identical sizing)
            mem = max(memtable_bytes, 16 * value_size)
            l1 = 2 * mem
        for policy in POLICIES:
            rec = run_cell(system, policy, keys, value, mem, l1)
            cells.append(rec)
            print(
                f"writeamp {system:8s} {policy:8s}: WA={rec['write_amp']:7.3f} "
                f"compWA={rec['compaction_write_amp']:7.4f} "
                f"trivial={rec['trivial_moves']:3d} "
                f"compactions={rec['compaction_count']:3d} "
                f"device={rec['device_mb']:.1f}MB",
                flush=True,
            )
    by = {(c["system"], c["policy"]): c for c in cells}
    summary = {}
    for system in systems:
        ov, fu = by[(system, "overlap")], by[(system, "fullness")]
        summary[f"{system}_write_amp_overlap"] = ov["write_amp"]
        summary[f"{system}_write_amp_fullness"] = fu["write_amp"]
        summary[f"{system}_compaction_bytes_saved"] = (
            fu["compaction_bytes_written"] - ov["compaction_bytes_written"]
        )
        summary[f"{system}_writeamp_win"] = ov["write_amp"] < fu["write_amp"]
    print(
        "summary: "
        + " ".join(
            f"{s}: {summary[f'{s}_write_amp_overlap']:.3f} vs "
            f"{summary[f'{s}_write_amp_fullness']:.3f} "
            f"(win={summary[f'{s}_writeamp_win']})"
            for s in systems
        ),
        flush=True,
    )
    return {
        "schema": "writeamp/v1",
        "workload": {
            "ops": ops,
            "key_space": key_space,
            "key_size": KEY_SIZE,
            "value_size": value_size,
            "memtable_bytes": memtable_bytes,
            "level1_bytes": level1_bytes,
            "wal_mode": "async",
            "seed": seed,
        },
        "cells": cells,
        "summary": summary,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ops", type=int, default=12000)
    ap.add_argument("--key-space", type=int, default=6000,
                    help="bounded window: ops/key_space ≈ overwrite factor")
    ap.add_argument("--value-size", type=int, default=64 << 10,
                    help="paper workload: 64 KiB values")
    ap.add_argument("--systems", nargs="+", default=["bvlsm", "rocksdb"],
                    choices=["bvlsm", "blobdb", "rocksdb"])
    # pointer entries are ~40 B, so the LSM tree only develops a multi-level
    # structure at small level budgets; the separated 64 KiB payloads land
    # in BValue files either way
    ap.add_argument("--memtable-bytes", type=int, default=8 << 10)
    ap.add_argument("--level1-bytes", type=int, default=8 << 10)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(
        args.ops, args.key_space, args.value_size, args.systems,
        args.memtable_bytes, args.level1_bytes,
    )
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
