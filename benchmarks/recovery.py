"""Recovery + fault-tolerance benchmark (PR 6).

Two experiments, one artifact (``BENCH_recovery.json``):

* **reopen-vs-WAL**: crash the DB (no flush) with increasing amounts of
  un-flushed WAL and measure cold-reopen time — the cost of the replay +
  torn-tail scan + dangling-pointer probe recovery path, reported as
  ``recover_mb_per_s``.
* **fault-storm**: a steady write workload is hit with a storm of
  *transient* injected I/O errors on SSTable writes (probability-based, so
  flushes keep failing and retrying until the transient-retry budget is
  exhausted and the DB latches read-only). Reported: accepted-write
  throughput before / during / after, the fraction of storm-phase writes
  rejected by the read-only latch, retries burned, and the time from
  ``resume()`` until the write backlog is fully drained on a healthy disk
  (``time_to_recover_s``).

Usage: ``PYTHONPATH=src python -m benchmarks.recovery [--quick] [--out F]``
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

from repro.core import DB, DBConfig, FaultInjectionEnv

VALUE_SIZE = 1024
KEY_SIZE = 16


def _cfg(env=None, memtable_size=64 << 20, value_threshold=256) -> DBConfig:
    cfg = DBConfig.bvlsm(
        wal_mode="sync",
        value_threshold=value_threshold,
        memtable_size=memtable_size,
        num_bvalue_queues=2,
    )
    cfg.env = env
    cfg.bg_error_backoff_ms = 5.0
    return cfg


def bench_reopen(wal_mb: float) -> dict:
    """Fill ~wal_mb of unflushed WAL, crash, time the reopen."""
    path = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # huge memtable + inline values: nothing rotates, nothing separates,
        # so every byte written lands in (and must be replayed from) the WAL
        db = DB(path, _cfg(value_threshold=VALUE_SIZE * 4))
        n = int(wal_mb * 1e6 / (KEY_SIZE + VALUE_SIZE))
        val = b"r" * VALUE_SIZE
        for i in range(n):
            db.put(f"{i:016d}".encode(), val)
        db.close(crash=True)
        actual_mb = sum(
            os.path.getsize(os.path.join(path, f))
            for f in os.listdir(path)
            if f.startswith("wal_")
        ) / 1e6
        t0 = time.monotonic()
        db = DB(path, _cfg(value_threshold=VALUE_SIZE * 4))
        dt = time.monotonic() - t0
        assert db.get(f"{n - 1:016d}".encode()) == val
        db.close()
        return {
            "experiment": "reopen",
            "wal_mb": round(actual_mb, 2),
            "keys": n,
            "reopen_s": round(dt, 4),
            "ops_per_s": round(n / dt, 1) if dt else None,  # keys replayed /s
            "recover_mb_per_s": round(actual_mb / dt, 2) if dt else None,
        }
    finally:
        shutil.rmtree(path, ignore_errors=True)


def bench_fault_storm(n_per_phase: int, storm_p: float = 0.4) -> dict:
    """Throughput before/during/after a transient-fault storm on flushes."""
    path = tempfile.mkdtemp(prefix="bench_storm_")
    env = FaultInjectionEnv(seed=1)
    try:
        # inline values so the memtable fills at value speed and the storm
        # actually intercepts a steady stream of flush jobs
        db = DB(
            path,
            _cfg(env, memtable_size=128 << 10, value_threshold=VALUE_SIZE * 4),
        )
        val = b"s" * VALUE_SIZE

        from repro.core import DBReadOnlyError

        def phase(base: int) -> tuple[float, int]:
            """ops/s of *accepted* writes; rejected (read-only) ops counted."""
            ok = rejected = 0
            t0 = time.monotonic()
            for i in range(base, base + n_per_phase):
                try:
                    db.put(f"{i:016d}".encode(), val)
                    ok += 1
                except DBReadOnlyError:
                    rejected += 1
            return ok / (time.monotonic() - t0), rejected

        before, _ = phase(0)
        env.add_fault(
            op="write", path_substr=".sst", count=None, probability=storm_p
        )
        # a sustained storm exhausts the transient-retry budget and latches
        # the DB read-only — writes fail fast (typed) instead of hanging
        during, rejected = phase(n_per_phase)
        env.clear_faults()
        t0 = time.monotonic()
        db.resume()
        after, _ = phase(2 * n_per_phase)
        db.flush()
        db.wait_idle()  # backlog fully drained on healthy disk
        time_to_recover = time.monotonic() - t0
        s = db.stats.snapshot()
        db.close()
        return {
            "experiment": "fault_storm",
            "storm_probability": storm_p,
            "ops_per_s": round(after, 1),  # post-recovery steady state
            "ops_per_s_before": round(before, 1),
            "ops_per_s_during": round(during, 1),
            # fraction of storm-phase writes the DB refused (read-only latch);
            # rejected writes fail fast, so wall-clock ops/s alone overstates
            # the health of the "during" phase
            "storm_reject_fraction": round(rejected / n_per_phase, 3),
            "writes_rejected": rejected,
            "bg_retries": s["bg_retries"],
            "bg_errors_transient_exhausted": s["bg_errors_transient_exhausted"],
            "resumes": s["resumes"],
            "time_to_recover_s": round(time_to_recover, 3),
        }
    finally:
        shutil.rmtree(path, ignore_errors=True)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args(argv)
    sizes = [1, 4] if args.quick else [1, 4, 16]
    n_storm = 2_000 if args.quick else 10_000
    cells = [bench_reopen(mb) for mb in sizes]
    cells.append(bench_fault_storm(n_storm))
    res = {"bench": "recovery", "quick": args.quick, "cells": cells}
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    main()
