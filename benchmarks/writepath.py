"""Write-path micro-benchmark: group commit vs the single-record baseline.

Measures put() ops/s with 1/4/8/16 concurrent writer threads under sync and
async WAL, with the leader/follower group commit enabled and disabled
(``wal_group_commit=False`` is the pre-pipeline one-record-one-fsync path).
Values are 1 KiB inline entries so the bench isolates the WAL commit path
from BValue separation.

Emits ``BENCH_writepath.json``::

    {"cells": [{threads, wal, group_commit, ops_per_s, fsyncs_per_write,
                avg_group_size, group_size_hist}, ...],
     "speedups": {"sync_t8": <group-on ops/s ÷ group-off ops/s>, ...}}

so future PRs can track the write-path trajectory. The interesting row is
sync WAL at 8 threads: group commit must amortize durability barriers
(fsyncs_per_write well under 0.5) and deliver a multiple of the baseline.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

from repro.core import DB, DBConfig

VALUE = b"\x5a" * 1024  # inline (< value_threshold): isolates the WAL path


def _bench_cell(threads: int, wal: str, group_commit: bool, ops_per_thread: int) -> dict:
    path = tempfile.mkdtemp(prefix=f"wp_{wal}_t{threads}_")
    db = DB(
        path,
        DBConfig(
            separation_mode="wal",
            wal_mode=wal,
            wal_group_commit=group_commit,
            value_threshold=4096,
            memtable_size=32 << 20,  # large: keep flush/compaction out of the timing
        ),
    )
    errors: list[BaseException] = []

    def writer(t: int) -> None:
        try:
            for i in range(ops_per_thread):
                db.put(f"t{t:02d}k{i:07d}".encode(), VALUE)
        except BaseException as e:
            errors.append(e)

    try:
        t0 = time.monotonic()
        if threads == 1:
            writer(0)
        else:
            ts = [threading.Thread(target=writer, args=(t,)) for t in range(threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        dt = time.monotonic() - t0
        if errors:
            raise errors[0]
        st = db.stats.snapshot()
    finally:
        db.close()
        shutil.rmtree(path, ignore_errors=True)
    n = threads * ops_per_thread
    return {
        "threads": threads,
        "wal": wal,
        "group_commit": group_commit,
        "n": n,
        "seconds": dt,
        "ops_per_s": n / dt,
        "fsyncs_per_write": st["fsyncs_per_write"],
        "avg_group_size": st["avg_group_size"],
        "group_size_hist": st["group_size_hist"],
    }


def run(thread_counts=(1, 4, 8, 16), wal_modes=("sync", "async"),
        ops_per_thread: int = 300) -> dict:
    cells = []
    for wal in wal_modes:
        for threads in thread_counts:
            for group_commit in (False, True):
                time.sleep(0.2)  # let the previous cell's teardown I/O settle
                cell = _bench_cell(threads, wal, group_commit, ops_per_thread)
                cells.append(cell)
                print(
                    f"wal={wal:5s} t={threads:2d} group={'on ' if group_commit else 'off'}: "
                    f"{cell['ops_per_s']:9.0f} ops/s  "
                    f"f/w={cell['fsyncs_per_write']:.3f}  "
                    f"grp={cell['avg_group_size']:.1f}",
                    flush=True,
                )
    speedups = {}
    for wal in wal_modes:
        for threads in thread_counts:
            on = next(c for c in cells if c["wal"] == wal and c["threads"] == threads and c["group_commit"])
            off = next(c for c in cells if c["wal"] == wal and c["threads"] == threads and not c["group_commit"])
            speedups[f"{wal}_t{threads}"] = on["ops_per_s"] / off["ops_per_s"]
    return {"cells": cells, "speedups": speedups}


def main() -> None:
    ap = argparse.ArgumentParser()
    def positive(v: str) -> int:
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--ops-per-thread", type=positive, default=300)
    ap.add_argument("--threads", type=int, nargs="*", default=[1, 4, 8, 16])
    ap.add_argument("--out", default="BENCH_writepath.json")
    args = ap.parse_args()
    res = run(thread_counts=tuple(args.threads), ops_per_thread=args.ops_per_thread)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print("speedups:", {k: round(v, 2) for k, v in res["speedups"].items()})


if __name__ == "__main__":
    main()
