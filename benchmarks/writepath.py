"""Write-path micro-benchmark: pipelined group commit vs group commit vs
the single-record baseline.

Measures put() ops/s with 1/4/8/16 concurrent writer threads under sync and
async WAL across three write-pipeline modes:

* ``off``       — ``wal_group_commit=False``: the pre-pipeline
  one-record-one-fsync path (baseline);
* ``group``     — PR 1's leader/follower group commit, single outstanding
  group (``wal_pipelined_commit=False``);
* ``pipelined`` — write pipeline v2 (the default): leader handoff overlaps
  the next group's encode+write with the previous group's fsync, adaptive
  group sizing, covered-fsync elision.

Values are 1 KiB inline entries so the bench isolates the WAL commit path
from BValue separation.

Emits ``BENCH_writepath.json``. Row schema (one row = one ``cells`` entry)::

    threads          int    concurrent writer threads
    wal              str    "sync" | "async"
    mode             str    "off" | "group" | "pipelined"
    group_commit     bool   wal_group_commit for this cell
    pipelined        bool   wal_pipelined_commit for this cell
    n                int    total put() calls (threads x ops_per_thread)
    seconds          float  wall time for all puts
    ops_per_s        float  n / seconds
    fsyncs_per_write float  (wal+bvalue fsyncs) / user writes (skips excluded)
    wal_fsync_skips  int    groups covered by a later-started fsync
    avg_group_size   float  mean writers merged per commit group
    group_size_hist  dict   pow2 bucket -> commit-group count
    pipeline_depth_max int  max commit groups in flight at once
    gauges           dict   adaptive-controller gauges at cell end
    samples_ops_per_s list  every repeat's ops/s, ascending (the recorded
                            row is the median sample; --repeat N)

``speedups`` summarizes each thread count: ``{wal}_t{n}`` is pipelined
ops/s ÷ baseline ops/s (the headline trajectory number — PR 1's group
commit scored 5.8x on sync_t8), ``{wal}_t{n}_group`` is group-only ÷
baseline, and ``{wal}_t{n}_pipeline_gain`` is pipelined ÷ group-only.
The interesting row is sync WAL at 8 threads: pipelining must at least
hold PR 1's amortization while overlapping fsync with group formation.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

from repro.core import DB, DBConfig

VALUE = b"\x5a" * 1024  # inline (< value_threshold): isolates the WAL path

MODES = {
    "off": dict(wal_group_commit=False, wal_pipelined_commit=False),
    "group": dict(wal_group_commit=True, wal_pipelined_commit=False),
    "pipelined": dict(wal_group_commit=True, wal_pipelined_commit=True),
}


def _bench_cell(threads: int, wal: str, mode: str, ops_per_thread: int) -> dict:
    path = tempfile.mkdtemp(prefix=f"wp_{wal}_t{threads}_")
    knobs = MODES[mode]
    db = DB(
        path,
        DBConfig(
            separation_mode="wal",
            wal_mode=wal,
            value_threshold=4096,
            memtable_size=32 << 20,  # large: keep flush/compaction out of the timing
            **knobs,
        ),
    )
    errors: list[BaseException] = []

    def writer(t: int) -> None:
        try:
            for i in range(ops_per_thread):
                db.put(f"t{t:02d}k{i:07d}".encode(), VALUE)
        except BaseException as e:
            errors.append(e)

    try:
        t0 = time.monotonic()
        if threads == 1:
            writer(0)
        else:
            ts = [threading.Thread(target=writer, args=(t,)) for t in range(threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        dt = time.monotonic() - t0
        if errors:
            raise errors[0]
        st = db.stats.snapshot()
    finally:
        db.close()
        shutil.rmtree(path, ignore_errors=True)
    n = threads * ops_per_thread
    return {
        "threads": threads,
        "wal": wal,
        "mode": mode,
        "group_commit": knobs["wal_group_commit"],
        "pipelined": knobs["wal_pipelined_commit"],
        "n": n,
        "seconds": dt,
        "ops_per_s": n / dt,
        "fsyncs_per_write": st["fsyncs_per_write"],
        "wal_fsync_skips": st["wal_fsync_skips"],
        "avg_group_size": st["avg_group_size"],
        "group_size_hist": st["group_size_hist"],
        "pipeline_depth_max": st["pipeline_depth_max"],
        "gauges": st["gauges"],
    }


def run(thread_counts=(1, 4, 8, 16), wal_modes=("sync", "async"),
        ops_per_thread: int = 300, repeat: int = 1) -> dict:
    cells = []
    for wal in wal_modes:
        for threads in thread_counts:
            samples: dict[str, list[dict]] = {m: [] for m in MODES}
            # repeats are interleaved ACROSS modes (round-robin) so a slow
            # container-I/O period hits every mode equally instead of
            # poisoning one mode's back-to-back samples; the MEDIAN sample
            # is recorded (resists both slow outliers and lucky bursts)
            for _ in range(repeat):
                for mode in MODES:
                    time.sleep(0.2)  # let the previous cell's teardown settle
                    samples[mode].append(_bench_cell(threads, wal, mode, ops_per_thread))
            for mode in MODES:
                ranked = sorted(samples[mode], key=lambda c: c["ops_per_s"])
                cell = ranked[len(ranked) // 2]
                cell["samples_ops_per_s"] = [round(c["ops_per_s"], 1) for c in ranked]
                cells.append(cell)
                print(
                    f"wal={wal:5s} t={threads:2d} mode={mode:9s}: "
                    f"{cell['ops_per_s']:9.0f} ops/s  "
                    f"f/w={cell['fsyncs_per_write']:.3f}  "
                    f"grp={cell['avg_group_size']:.1f}  "
                    f"depth={cell['pipeline_depth_max']}",
                    flush=True,
                )
    speedups = {}
    for wal in wal_modes:
        for threads in thread_counts:
            by_mode = {
                c["mode"]: c
                for c in cells
                if c["wal"] == wal and c["threads"] == threads
            }
            off = by_mode["off"]["ops_per_s"]
            speedups[f"{wal}_t{threads}"] = by_mode["pipelined"]["ops_per_s"] / off
            speedups[f"{wal}_t{threads}_group"] = by_mode["group"]["ops_per_s"] / off
            speedups[f"{wal}_t{threads}_pipeline_gain"] = (
                by_mode["pipelined"]["ops_per_s"] / by_mode["group"]["ops_per_s"]
            )
    return {"cells": cells, "speedups": speedups}


def main() -> None:
    ap = argparse.ArgumentParser()
    def positive(v: str) -> int:
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("must be >= 1")
        return n

    ap.add_argument("--ops-per-thread", type=positive, default=300)
    ap.add_argument("--threads", type=int, nargs="*", default=[1, 4, 8, 16])
    ap.add_argument("--repeat", type=positive, default=1,
                    help="median-of-N per cell, rounds interleaved across modes")
    ap.add_argument("--out", default="BENCH_writepath.json")
    args = ap.parse_args()
    res = run(thread_counts=tuple(args.threads), ops_per_thread=args.ops_per_thread,
              repeat=args.repeat)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print("speedups:", {k: round(v, 2) for k, v in res["speedups"].items()})


if __name__ == "__main__":
    main()
