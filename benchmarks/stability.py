"""Paper Fig. 9 — sustained-write I/O stability.

Engine layer: continuous random 4 KiB-value writes for --seconds per
system; report per-interval instant throughput, mean, and σ (the paper's
claim: BVLSM has the smallest σ; RocksDB oscillates with compaction; BlobDB
collapses after its in-memory absorption phase).

Framework layer (the DESIGN.md §3 jitter mapping): train-step wall-time
jitter with synchronous vs BVLSM-async checkpointing.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import cleanup, gen_value, make_db


def engine_stability(seconds: float = 20.0, value_size: int = 4096,
                     interval: float = 1.0, systems=("rocksdb", "blobdb", "bvlsm")) -> list[dict]:
    out = []
    val = gen_value(value_size, 5)
    for system in systems:
        db, path = make_db(system, "async")
        try:
            t_end = time.monotonic() + seconds
            i = 0
            while time.monotonic() < t_end:
                db.put(f"{i:016d}".encode(), val)
                i += 1
            series = db.stats.interval_throughput(interval)
        finally:
            cleanup(db, path)
        rates = np.array([r for _, r in series if r > 0] or [0.0])
        rec = {
            "bench": "stability",
            "system": system,
            "intervals": len(rates),
            "mean_mb_s": float(rates.mean()),
            "std_mb_s": float(rates.std()),
            "min_mb_s": float(rates.min()),
            "max_mb_s": float(rates.max()),
            "cv": float(rates.std() / rates.mean()) if rates.mean() else 0.0,
            "series": [(round(t, 1), round(r, 2)) for t, r in series],
        }
        out.append(rec)
        print(
            f"stability {system:8s}: mean={rec['mean_mb_s']:7.1f} MB/s "
            f"σ={rec['std_mb_s']:6.1f} cv={rec['cv']:.3f} "
            f"[{rec['min_mb_s']:.0f}..{rec['max_mb_s']:.0f}]",
            flush=True,
        )
    return out


def checkpoint_jitter(steps: int = 60, ckpt_interval: int = 10) -> list[dict]:
    """Train-step jitter: sync vs async BVLSM checkpointing."""
    import shutil

    from repro.configs import get_config
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_step import TrainConfig
    from repro.training.trainer import Trainer, TrainerConfig

    out = []
    for mode in ("sync", "bvlsm_async"):
        ckpt_dir = f"/tmp/jitter_{mode}"
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        cfg = get_config("llama3-8b").reduced(d_model=128, n_layers=4)
        tcfg = TrainerConfig(
            steps=steps,
            global_batch=4,
            seq_len=128,
            ckpt_dir=ckpt_dir,
            ckpt_interval=ckpt_interval,
            ckpt_async=(mode == "bvlsm_async"),
            log_every=10_000,
            train=TrainConfig(opt=OptimizerConfig(warmup_steps=10, total_steps=1000)),
        )
        tr = Trainer(cfg, tcfg)
        try:
            tr.run()
            times = np.array(tr.step_times[2:])  # drop compile step
            rec = {
                "bench": "ckpt_jitter",
                "mode": mode,
                "mean_ms": float(times.mean() * 1e3),
                "std_ms": float(times.std() * 1e3),
                "p99_ms": float(np.percentile(times, 99) * 1e3),
                "max_ms": float(times.max() * 1e3),
                "loop_stall_s": tr.ckpt.stall_seconds,
            }
        finally:
            tr.close()
            shutil.rmtree(ckpt_dir, ignore_errors=True)
        out.append(rec)
        print(
            f"ckpt_jitter {mode:12s}: mean={rec['mean_ms']:6.1f}ms "
            f"p99={rec['p99_ms']:7.1f}ms max={rec['max_ms']:7.1f}ms "
            f"loop_stall={rec['loop_stall_s']:.2f}s",
            flush=True,
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=20.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = engine_stability(args.seconds) + checkpoint_jitter()
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
