"""Paper Fig. 9 — sustained-write I/O stability.

Three layers:

* **engine** — continuous random 4 KiB-value writes for ``--seconds`` per
  system; report per-interval instant throughput, mean, σ, cv (σ/mean) and
  the stall tail (p99 ms) — the paper's claim: BVLSM has the smallest σ;
  RocksDB oscillates with compaction; BlobDB collapses after its in-memory
  absorption phase.
* **ablation** — the background-scheduler jitter win on bvlsm: a
  sustained (saturating) mixed-size overwrite workload over a pre-filled
  key window (steady level structure, so σ is not inflated by the
  empty-tree ramp) with *driver-side* interval accounting (GC-internal
  rewrites don't masquerade as foreground throughput), run interleaved
  with median-of-rounds cv (use odd ``--rounds``; with an even count the
  upper median is reported), against (a) the full background
  stack — prioritized job scheduler with parallel lock-disjoint
  compactions, partitioned subcompactions, and the shared background-I/O
  token bucket — vs (b) single-thread unlimited mode
  (``background_threads=1, max_subcompactions=1, bg_io_bytes_per_sec=0``).
  ``summary.ablation_cv_scheduled < summary.ablation_cv_unthrottled``
  is the committed trajectory gate.
* **ckpt** — train-step wall-time jitter with synchronous vs BVLSM-async
  checkpointing (the DESIGN.md §3 mapping); skipped with ``--skip-ckpt``.

Output (``--out``): one JSON dict ``{schema, engine, ablation, summary,
ckpt}`` — committed as ``BENCH_stability.json`` and uploaded by CI next to
the writepath/readpath artifacts.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import cleanup, gen_value, make_db


def _run_sustained(db, seconds: float, values, n_keys: int, interval: float,
                   warmup: bool = False, fg_gc_every: float = 0.0,
                   gc_threshold: float = 0.4) -> dict:
    """Write for ``seconds`` cycling a bounded key window (sustained
    overwrites keep compaction + value-log-GC pressure up); returns the
    per-interval *foreground* throughput series + engine jitter counters.

    ``warmup`` first writes the whole window once and quiesces, so the
    measurement starts from a steady level structure instead of an empty
    tree (an empty-tree ramp adds a throughput trend that inflates σ with
    workload-independent noise).

    ``fg_gc_every > 0`` reproduces the pre-scheduler reclamation story:
    the writer itself calls ``gc_collect`` inline every that-many seconds
    (foreground, unthrottled) — the dips it tears into the series are
    exactly what promoting GC to a rate-limited background job removes.

    The series is accounted *driver-side* (bytes the foreground writer
    acked per interval bucket) rather than from the engine's user-bytes
    timeline, so a background GC pass's internal rewrites don't masquerade
    as foreground throughput."""
    nvals = len(values)
    if warmup:
        for i in range(n_keys):
            db.put(f"{i:016d}".encode(), values[i % nvals])
        db.flush()
        db.wait_idle()
    t0 = time.monotonic()
    t_end = t0 + seconds
    buckets: dict[int, int] = {}
    next_gc = t0 + fg_gc_every if fg_gc_every > 0 else None
    gc_passes = 0
    i = 0
    now = t0
    while now < t_end:
        if next_gc is not None and now >= next_gc:
            db.gc_collect(gc_threshold)  # foreground: the writer IS the GC
            gc_passes += 1
            next_gc = time.monotonic() + fg_gc_every
        v = values[i % nvals]
        db.put(f"{i % n_keys:016d}".encode(), v)
        i += 1
        now = time.monotonic()
        buckets[int((now - t0) / interval)] = (
            buckets.get(int((now - t0) / interval), 0) + 16 + len(v)
        )
    st = db.stats.snapshot()
    n_full = int(seconds / interval)  # drop the trailing partial bucket
    series = [
        (round((b + 1) * interval, 1), round(buckets.get(b, 0) / interval / 1e6, 2))
        for b in range(n_full)
    ]
    rates = np.array([r for _, r in series] or [0.0])
    return {
        "ops": i,
        "gc_passes": gc_passes if fg_gc_every > 0 else st["jobs"].get("gc", {}).get("count", 0),
        "intervals": len(rates),
        "mean_mb_s": float(rates.mean()),
        "std_mb_s": float(rates.std()),
        "min_mb_s": float(rates.min()),
        "max_mb_s": float(rates.max()),
        "cv": float(rates.std() / rates.mean()) if rates.mean() else 0.0,
        "stall_s": st["stall_seconds"],
        "stall_events": st["stall_events"],
        "stall_p99_ms": st["stall_p99_ms"],
        "stall_stop_s": st.get("stall_stop_seconds", 0.0),
        "stall_delay_s": st.get("stall_delay_seconds", 0.0),
        "rate_limiter_waits": st["rate_limiter_waits"],
        "rate_limiter_wait_s": st["rate_limiter_wait_seconds"],
        "subcompactions": st["subcompactions"],
        "jobs": st["jobs"],
        "series": series,
    }


def engine_stability(seconds: float = 20.0, value_size: int = 4096,
                     interval: float = 1.0, systems=("rocksdb", "blobdb", "bvlsm")) -> list[dict]:
    """The paper's three-system comparison (Fig. 9 workload: sustained
    unique-key 4 KiB writes, async WAL)."""
    out = []
    vals = [gen_value(value_size, 5)]
    for system in systems:
        db, path = make_db(system, "async")
        try:
            rec = _run_sustained(db, seconds, vals, n_keys=1 << 60, interval=interval)
        finally:
            cleanup(db, path)
        rec = {"bench": "stability", "system": system, **rec}
        out.append(rec)
        print(
            f"stability {system:8s}: mean={rec['mean_mb_s']:7.1f} MB/s "
            f"σ={rec['std_mb_s']:6.1f} cv={rec['cv']:.3f} "
            f"stall_p99={rec['stall_p99_ms']:.1f}ms "
            f"[{rec['min_mb_s']:.0f}..{rec['max_mb_s']:.0f}]",
            flush=True,
        )
    return out


#: the two sides of the scheduler ablation. Both must reclaim the dead
#: BValue bytes the overwrite workload produces; each uses its era's
#: mechanism — that asymmetry (foreground unthrottled pass vs scheduled
#: rate-limited job) is precisely the jitter lever under test, alongside
#: parallel lock-disjoint compactions and the shared I/O token bucket.
ABLATION_VARIANTS = {
    # the full background stack: 2 compaction threads, partitioned
    # subcompactions, token-bucket-limited background writes, and GC
    # promoted to a threshold-triggered background job. The 12 MB/s bucket
    # was sized as a BACKGROUND-only cap, so the unified foreground charge
    # (PR 5) is pinned off here — this ablation isolates the scheduler
    # stack, and letting ~10 MB/s of foreground value-log traffic shrink
    # the background refill would change what it measures.
    "scheduled": dict(
        background_threads=2,
        max_subcompactions=2,
        bg_io_bytes_per_sec=12 << 20,
        unified_io_budget=False,
        gc_auto=True,
        gc_dead_ratio_trigger=0.4,
    ),
    # pre-scheduler story: one background thread, unlimited I/O, GC runs
    # foreground+unthrottled from the writer (fg_gc_every below)
    "unthrottled": dict(
        background_threads=1,
        max_subcompactions=1,
        bg_io_bytes_per_sec=0,
        gc_auto=False,
    ),
}

#: GC/compaction-heavy sustained-overwrite workload shared by both sides:
#: mixed value sizes (50% 1 KiB inline / 50% 8 KiB separated) over a small
#: key window that the run overwrites several times, with small BValue
#: files so sealed-file dead ratios actually cross the GC trigger mid-run
ABLATION_DB = dict(
    memtable_size=1 << 20,
    level1_max_bytes=4 << 20,
    l0_compaction_trigger=4,
    value_threshold=4096,
    bvalue_max_file_bytes=2 << 20,
)

ABLATION_KEYS = 4000

#: cadence of the baseline's foreground GC passes (seconds)
ABLATION_FG_GC_EVERY = 5.0


def scheduler_ablation(seconds: float = 10.0, interval: float = 1.0,
                       rounds: int = 3) -> list[dict]:
    """bvlsm jitter with/without the background stack, at steady state
    (pre-filled key window). Rounds interleave the variants (A B B A ...)
    so machine drift hits both equally; each variant's headline cv is the
    MEDIAN across rounds (single rounds on a shared container are noisy;
    the median is the representative one)."""
    values = [gen_value(1 << 10, 11), gen_value(8 << 10, 13)]
    per_variant: dict[str, list[dict]] = {name: [] for name in ABLATION_VARIANTS}
    for r in range(rounds):
        order = list(ABLATION_VARIANTS) if r % 2 == 0 else list(reversed(ABLATION_VARIANTS))
        for name in order:
            cfg = ABLATION_VARIANTS[name]
            db, path = make_db("bvlsm", "async", **ABLATION_DB, **cfg)
            try:
                rec = _run_sustained(
                    db, seconds, values, n_keys=ABLATION_KEYS, interval=interval,
                    warmup=True,
                    fg_gc_every=0.0 if cfg.get("gc_auto") else ABLATION_FG_GC_EVERY,
                )
            finally:
                cleanup(db, path)
            per_variant[name].append(rec)
            print(
                f"ablation  {name:12s} r{r}: mean={rec['mean_mb_s']:6.1f} MB/s "
                f"cv={rec['cv']:.3f} stall_p99={rec['stall_p99_ms']:.0f}ms "
                f"gc_passes={rec['gc_passes']} rl_waits={rec['rate_limiter_waits']} "
                f"subcompactions={rec['subcompactions']}",
                flush=True,
            )
    out = []
    for name, recs in per_variant.items():
        ranked = sorted(recs, key=lambda r: r["cv"])
        median = ranked[len(ranked) // 2]
        out.append({
            "bench": "stability_ablation", "variant": name,
            "config": ABLATION_VARIANTS[name], "rounds": len(recs),
            "fg_gc_every": 0.0 if ABLATION_VARIANTS[name].get("gc_auto") else ABLATION_FG_GC_EVERY,
            "all_cv": [round(r["cv"], 4) for r in recs], **median,
        })
    return out


def checkpoint_jitter(steps: int = 60, ckpt_interval: int = 10) -> list[dict]:
    """Train-step jitter: sync vs async BVLSM checkpointing."""
    import shutil

    from repro.configs import get_config
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_step import TrainConfig
    from repro.training.trainer import Trainer, TrainerConfig

    out = []
    for mode in ("sync", "bvlsm_async"):
        ckpt_dir = f"/tmp/jitter_{mode}"
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        cfg = get_config("llama3-8b").reduced(d_model=128, n_layers=4)
        tcfg = TrainerConfig(
            steps=steps,
            global_batch=4,
            seq_len=128,
            ckpt_dir=ckpt_dir,
            ckpt_interval=ckpt_interval,
            ckpt_async=(mode == "bvlsm_async"),
            log_every=10_000,
            train=TrainConfig(opt=OptimizerConfig(warmup_steps=10, total_steps=1000)),
        )
        tr = Trainer(cfg, tcfg)
        try:
            tr.run()
            times = np.array(tr.step_times[2:])  # drop compile step
            rec = {
                "bench": "ckpt_jitter",
                "mode": mode,
                "mean_ms": float(times.mean() * 1e3),
                "std_ms": float(times.std() * 1e3),
                "p99_ms": float(np.percentile(times, 99) * 1e3),
                "max_ms": float(times.max() * 1e3),
                "loop_stall_s": tr.ckpt.stall_seconds,
            }
        finally:
            tr.close()
            shutil.rmtree(ckpt_dir, ignore_errors=True)
        out.append(rec)
        print(
            f"ckpt_jitter {mode:12s}: mean={rec['mean_ms']:6.1f}ms "
            f"p99={rec['p99_ms']:7.1f}ms max={rec['max_ms']:7.1f}ms "
            f"loop_stall={rec['loop_stall_s']:.2f}s",
            flush=True,
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seconds", type=float, default=20.0)
    ap.add_argument("--interval", type=float, default=1.0)
    ap.add_argument("--rounds", type=int, default=3,
                    help="ablation rounds per variant (odd → true median)")
    ap.add_argument("--skip-ckpt", action="store_true",
                    help="engine layers only (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    engine = engine_stability(args.seconds, interval=args.interval)
    ablation = scheduler_ablation(args.seconds, interval=args.interval, rounds=args.rounds)
    by_variant = {r["variant"]: r for r in ablation}
    summary = {
        "bvlsm_cv": next(r["cv"] for r in engine if r["system"] == "bvlsm"),
        "ablation_cv_scheduled": by_variant["scheduled"]["cv"],
        "ablation_cv_unthrottled": by_variant["unthrottled"]["cv"],
        "ablation_stall_p99_ms_scheduled": by_variant["scheduled"]["stall_p99_ms"],
        "ablation_stall_p99_ms_unthrottled": by_variant["unthrottled"]["stall_p99_ms"],
        "jitter_win": by_variant["scheduled"]["cv"] < by_variant["unthrottled"]["cv"],
    }
    print(
        f"summary: cv scheduled={summary['ablation_cv_scheduled']:.3f} "
        f"vs unthrottled={summary['ablation_cv_unthrottled']:.3f} "
        f"→ jitter_win={summary['jitter_win']}",
        flush=True,
    )
    res = {
        "schema": "stability/v2",
        "engine": engine,
        "ablation": ablation,
        "summary": summary,
        "ckpt": [] if args.skip_ckpt else checkpoint_jitter(),
    }
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
