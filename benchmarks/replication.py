"""Replication benchmark (PR 8).

Three experiments, one artifact (``BENCH_replication.json``):

* **catch-up**: bootstrap a replica from a checkpoint, then accumulate a
  WAL backlog on the primary *before* attaching — attach and measure how
  fast the follower's catch-up reader drains it (``catch_up_mb_per_s``).
* **steady-lag**: a live stream under a steady write load; the replica's
  sequence lag is sampled after every put and reported as p50/p99
  (``lag_p99_seqs``), plus accepted primary write throughput with the
  ship hook on the commit path.
* **failover**: converge a pair, crash the primary, promote the replica
  and measure promote-to-first-accepted-write latency
  (``failover_to_first_write_ms``) — the window where neither side takes
  writes.

Usage: ``PYTHONPATH=src python -m benchmarks.replication [--quick] [--out F]``
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.core import DB, DBConfig
from repro.core.replication import attach, bootstrap_replica

KEY_SIZE = 16
VALUE_SIZE = 1024


def _cfg(memtable_size=256 << 20) -> DBConfig:
    # huge memtable: nothing flushes, so the whole workload lives in the
    # WAL — exactly the bytes replication has to move
    return DBConfig.bvlsm(
        wal_mode="async",
        value_threshold=256,
        memtable_size=memtable_size,
        num_bvalue_queues=2,
    )


def _repl_bytes(db) -> int:
    """Bytes replication has to move: WAL records (pointers + inline
    values) plus the separated value files the follower mirrors."""
    import os

    total = sum(
        os.path.getsize(os.path.join(db.path, f))
        for f in os.listdir(db.path)
        if f.startswith("wal_")
    )
    bvdir = os.path.join(db.path, "bvalue")
    if os.path.isdir(bvdir):
        total += sum(
            os.path.getsize(os.path.join(bvdir, f)) for f in os.listdir(bvdir)
        )
    return total


def _fill(db, base: int, n: int, val: bytes) -> None:
    for i in range(base, base + n):
        db.put(f"{i:016d}".encode(), val)


def _converge(link, timeout: float) -> bool:
    """Nudge-and-wait loop: the stream goes quiet once writes stop, so
    convergence needs periodic re-nudges (same idiom as the test suite)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        link.nudge()
        if link.wait_caught_up(timeout=1.0):
            return True
    return False


def _percentile(samples: list[int], q: float) -> int:
    if not samples:
        return 0
    s = sorted(samples)
    return s[min(len(s) - 1, int(q * len(s)))]


def bench_catch_up(backlog_mb: float) -> dict:
    """Backlog accumulated while detached; attach and time the drain."""
    proot = tempfile.mkdtemp(prefix="bench_repl_p_")
    rroot = proot + "_r"
    try:
        primary = DB(proot, _cfg())
        val = b"c" * VALUE_SIZE
        _fill(primary, 0, 500, val)  # seed lands in the checkpoint
        replica = bootstrap_replica(primary, rroot, cfg=_cfg())
        n = int(backlog_mb * 1e6 / (KEY_SIZE + VALUE_SIZE))
        base_bytes = _repl_bytes(primary)
        _fill(primary, 500, n, val)
        # the backlog must be durable before we time the read: flush the
        # async WAL buffer and the async BValue writer batches
        primary.wal.flush()
        primary.bvalue.flush()
        backlog = _repl_bytes(primary) - base_bytes
        t0 = time.monotonic()
        link = attach(primary, replica)
        ok = _converge(link, timeout=300.0)
        dt = time.monotonic() - t0
        assert ok, "catch-up did not converge"
        assert replica.get(f"{500 + n - 1:016d}".encode()) == val
        link.detach()
        primary.close()
        replica.close()
        return {
            "experiment": "catch_up",
            "backlog_mb": round(backlog / 1e6, 2),
            "keys": n,
            "catch_up_s": round(dt, 4),
            "ops_per_s": round(n / dt, 1) if dt else None,
            "catch_up_mb_per_s": round(backlog / 1e6 / dt, 2) if dt else None,
        }
    finally:
        shutil.rmtree(proot, ignore_errors=True)
        shutil.rmtree(rroot, ignore_errors=True)


def bench_steady_lag(n_writes: int) -> dict:
    """Sequence lag distribution under a live stream at write speed."""
    proot = tempfile.mkdtemp(prefix="bench_repl_p_")
    rroot = proot + "_r"
    try:
        primary = DB(proot, _cfg())
        val = b"s" * VALUE_SIZE
        _fill(primary, 0, 200, val)
        replica = bootstrap_replica(primary, rroot, cfg=_cfg())
        link = attach(primary, replica)
        _converge(link, timeout=60.0)
        warmup = n_writes // 10
        samples: list[int] = []
        t0 = time.monotonic()
        for i in range(n_writes):
            primary.put(f"{200 + i:016d}".encode(), val)
            if i >= warmup:
                samples.append(link.lag)
        write_dt = time.monotonic() - t0
        t1 = time.monotonic()
        assert _converge(link, timeout=120.0)
        settle = time.monotonic() - t1
        link.detach()
        primary.close()
        replica.close()
        return {
            "experiment": "steady_lag",
            "writes": n_writes,
            "ops_per_s": round(n_writes / write_dt, 1) if write_dt else None,
            "lag_p50_seqs": _percentile(samples, 0.50),
            "lag_p99_seqs": _percentile(samples, 0.99),
            "lag_max_seqs": max(samples) if samples else 0,
            "settle_s": round(settle, 4),  # drain time after load stops
        }
    finally:
        shutil.rmtree(proot, ignore_errors=True)
        shutil.rmtree(rroot, ignore_errors=True)


def bench_failover(n_writes: int) -> dict:
    """Crash the primary; promote() until the first accepted write."""
    proot = tempfile.mkdtemp(prefix="bench_repl_p_")
    rroot = proot + "_r"
    try:
        primary = DB(proot, _cfg())
        val = b"f" * VALUE_SIZE
        _fill(primary, 0, 200, val)
        replica = bootstrap_replica(primary, rroot, cfg=_cfg())
        link = attach(primary, replica)
        _fill(primary, 200, n_writes, val)
        assert _converge(link, timeout=120.0)
        primary.close(crash=True)
        t0 = time.monotonic()
        replica.promote()
        replica.put(b"post-failover", b"first-write")
        failover_ms = (time.monotonic() - t0) * 1e3
        assert replica.get(f"{200 + n_writes - 1:016d}".encode()) == val
        assert replica.get(b"post-failover") == b"first-write"
        replica.close()
        return {
            "experiment": "failover",
            "writes_replicated": n_writes,
            "failover_to_first_write_ms": round(failover_ms, 3),
        }
    finally:
        shutil.rmtree(proot, ignore_errors=True)
        shutil.rmtree(rroot, ignore_errors=True)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default="BENCH_replication.json")
    args = ap.parse_args(argv)
    sizes = [1] if args.quick else [1, 4, 16]
    n_steady = 1_000 if args.quick else 5_000
    cells = [bench_catch_up(mb) for mb in sizes]
    cells.append(bench_steady_lag(n_steady))
    cells.append(bench_failover(n_steady // 2))
    res = {"bench": "replication", "quick": args.quick, "cells": cells}
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    main()
