"""Collect every ``BENCH_*.json`` in the working directory into ONE
markdown summary table (ops/s, cv, write_amp where each applies) — CI
appends the output to ``$GITHUB_STEP_SUMMARY`` so every run shows its
benchmark numbers without downloading artifacts.

Usage: ``python -m benchmarks.ci_summary [glob ...]`` (default
``BENCH_*.json``). Tolerant by design: unknown schemas contribute
whatever of the three columns they carry; a malformed file becomes one
error row instead of failing the step.
"""
from __future__ import annotations

import glob
import json
import sys


def _fmt(v, nd=2) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:,.{nd}f}"
    return f"{v:,}"


def _rows_for(name: str, res: dict) -> list[tuple]:
    """(bench, cell-label, ops/s, cv, write_amp) rows for one artifact."""
    rows = []
    for c in res.get("cells", []):
        if "policy" in c:  # writeamp
            label = f"{c.get('system', '?')}/{c['policy']}"
            rows.append((name, label, c.get("ops_per_s"), None, c.get("write_amp")))
        elif "workload" in c:  # readpath
            label = (
                f"{c['workload']}/v{c.get('format', '?')}/"
                f"{'cache' if c.get('cache') else 'nocache'}"
            )
            if "cache_policy" in c:  # PR-9 2Q-vs-LRU mixed cells
                label += f"/{c['cache_policy']}"
            rows.append((name, label, c.get("ops_per_s"), None, None))
        elif "shards" in c:  # ycsb sharding (before "threads": cells carry both)
            label = (
                f"shards={c['shards']}/"
                f"{'devmodel' if c.get('device_model') else 'raw'}/"
                f"{c.get('speedup_vs_1shard', 0):.2f}x"
            )
            rows.append((name, label, c.get("write_ops_s"), None, None))
        elif "threads" in c:  # writepath
            label = f"{c.get('wal', '?')}/t{c['threads']}/{c.get('mode', '?')}"
            rows.append((name, label, c.get("ops_per_s"), None, c.get("write_amp")))
        elif "experiment" in c:  # recovery / replication
            label = c["experiment"]
            if "wal_mb" in c:
                label += f"/{c['wal_mb']}MB"
            elif "backlog_mb" in c:
                label += f"/{c['backlog_mb']}MB@{c.get('catch_up_mb_per_s', '?')}MB/s"
            elif "lag_p99_seqs" in c:
                label += f"/p99={c['lag_p99_seqs']}seqs"
            elif "failover_to_first_write_ms" in c:
                label += f"/{c['failover_to_first_write_ms']}ms"
            rows.append((name, label, c.get("ops_per_s"), None, None))
        else:
            rows.append((name, "cell", c.get("ops_per_s"), c.get("cv"), c.get("write_amp")))
    summ = res.get("summary")
    if isinstance(summ, dict) and "agg_write_speedup" in summ:  # sharding
        rows.append((
            name,
            f"summary/{summ.get('shards', '?')}-shard "
            f"{summ['agg_write_speedup']:.2f}x write",
            None, None, None,
        ))
    for c in res.get("engine", []):  # stability
        rows.append((name, f"engine/{c.get('system', '?')}", None, c.get("cv"), None))
    for c in res.get("ablation", []):
        rows.append((name, f"ablation/{c.get('variant', '?')}", None, c.get("cv"), None))
    return rows


def main(patterns: list[str]) -> str:
    paths = sorted({p for pat in patterns for p in glob.glob(pat)})
    lines = [
        "## Benchmark summary",
        "",
        "| artifact | cell | ops/s | cv | write_amp |",
        "|---|---|---:|---:|---:|",
    ]
    for path in paths:
        try:
            res = json.load(open(path))
            rows = _rows_for(path, res)
        except Exception as e:  # one bad artifact must not kill the summary
            rows = [(path, f"unreadable: {e}", None, None, None)]
        for bench, label, ops, cv, wa in rows:
            lines.append(
                f"| {bench} | {label} | {_fmt(ops, 0)} | {_fmt(cv, 3)} | {_fmt(wa, 3)} |"
            )
    if not paths:
        lines.append("| _none found_ | | | | |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(main(sys.argv[1:] or ["BENCH_*.json"]))
