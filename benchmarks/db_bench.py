"""Paper Fig. 6 + Fig. 7 — db_bench fill{random,seq} across the six
workloads (R-WO/R-WA/R-WS/S-WO/S-WA/S-WS) × value sizes 4–64 KiB ×
{rocksdb, blobdb, bvlsm}.

Scaled to this container (--mb controls user bytes per cell, default 48 MB
— enough to trigger flushes and L0→L1 compactions at the scaled MemTable
size); same key size (16 B), same value grid, same systems as the paper.

``--threads N`` runs each cell with N concurrent writers through the
group-commit write pipeline; the per-cell output then also reports
fsyncs-per-write and the average writer-group size so the amortization of
durability barriers is visible next to throughput.
"""
from __future__ import annotations

import argparse
import json

from .common import KEY_SIZE, cleanup, gen_keys, make_db, run_fill


def run(pattern: str = "random", mb: int = 48, value_sizes=(4096, 16384, 65536),
        wal_modes=("off", "async", "sync"), systems=("rocksdb", "blobdb", "bvlsm"),
        threads: int = 1) -> list[dict]:
    out = []
    for vs in value_sizes:
        n = max(64, int(mb * 1e6 / (vs + KEY_SIZE)))
        keys = gen_keys(n, pattern)
        for wal in wal_modes:
            for system in systems:
                db, path = make_db(system, wal)
                try:
                    r = run_fill(db, keys, vs, threads=threads)
                finally:
                    cleanup(db, path)
                rec = {
                    "bench": f"fill{pattern}",
                    "system": system,
                    "wal": wal,
                    "value_size": vs,
                    "n": n,
                    "threads": threads,
                    **r,
                }
                out.append(rec)
                print(
                    f"fill{pattern:6s} {system:8s} wal={wal:5s} v={vs//1024:3d}K "
                    f"t={threads:2d}: {r['mb_per_s']:8.1f} MB/s  "
                    f"wamp={r['write_amp']:.2f}  stall={r['stall_s']:.2f}s  "
                    f"f/w={r['fsyncs_per_write']:.3f}  grp={r['avg_group_size']:.1f}",
                    flush=True,
                )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="random", choices=["random", "seq"])
    ap.add_argument("--mb", type=int, default=48)
    ap.add_argument("--threads", type=int, default=1, help="concurrent writer threads")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.pattern, args.mb, threads=args.threads)
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
