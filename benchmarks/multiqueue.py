"""Paper Fig. 10 — multi-queue vs single-queue value-store throughput
(FIO-analogue on the BValue store directly), across block sizes and
dispatch policies, plus writer-thread scaling.

The paper measures NVMe SQ parallelism; our userspace analogue exercises
one writer thread + file per queue (GIL released during pwrite/fsync).
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

from repro.core.bvalue import BValueManager

from .common import gen_value


def bvalue_fill(num_queues: int, value_size: int, total_mb: int, dispatch: str = "round_robin",
                sync: bool = False, writers: int = 4) -> dict:
    d = tempfile.mkdtemp(prefix=f"mq{num_queues}_")
    # fine-grained submission regime (256 KiB batches, 4 ms gather) — the
    # paper's FIO comparison targets per-submission parallelism, not the
    # engine's default latency-optimized batching
    mgr = BValueManager(d, num_queues=num_queues, async_writes=not sync,
                        dispatch=dispatch, batch_bytes=1 << 18, gather_window_s=0.004)
    val = gen_value(value_size, 11)
    n = max(16, int(total_mb * 1e6 / value_size))
    try:
        t0 = time.monotonic()
        if sync:
            # parallel client threads on the sync path (per-caller fsync)
            per = n // writers

            def worker(w):
                for i in range(per):
                    mgr.put(f"k{w}_{i}".encode(), val, sync=True)

            ts = [threading.Thread(target=worker, args=(w,)) for w in range(writers)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            n_done = per * writers
        else:
            for i in range(n):
                mgr.put(f"k{i}".encode(), val, sync=False)
            mgr.flush()
            n_done = n
        dt = time.monotonic() - t0
    finally:
        mgr.close()
        shutil.rmtree(d, ignore_errors=True)
    return {
        "queues": num_queues,
        "value_size": value_size,
        "dispatch": dispatch,
        "sync": sync,
        "mb_per_s": n_done * value_size / 1e6 / dt,
        "iops": n_done / dt,
    }


def run(total_mb: int = 64) -> list[dict]:
    out = []
    for vs in (4096, 16384, 65536):
        for q in (1, 2, 4, 8):
            r = bvalue_fill(q, vs, total_mb)
            r["bench"] = "multiqueue_async"
            out.append(r)
            print(
                f"mq async v={vs//1024:3d}K queues={q}: {r['mb_per_s']:8.1f} MB/s "
                f"({r['iops']:8.0f} iops)",
                flush=True,
            )
    # sync mode: parallel writers vs queue count (the paper's FIO setup:
    # 4 threads sharing 1 SQ vs 4 threads with private SQs)
    for q in (1, 4):
        r = bvalue_fill(q, 4096, total_mb // 4, sync=True, writers=4)
        r["bench"] = "multiqueue_sync"
        out.append(r)
        print(f"mq sync  v=  4K queues={q} writers=4: {r['mb_per_s']:8.1f} MB/s", flush=True)
    # dispatch policy
    for disp in ("round_robin", "least_loaded"):
        r = bvalue_fill(4, 65536, total_mb, dispatch=disp)
        r["bench"] = "dispatch"
        out.append(r)
        print(f"dispatch {disp:12s}: {r['mb_per_s']:8.1f} MB/s", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mb", type=int, default=64)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.mb)
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
