"""Paper Fig. 8 — YCSB Workload A (50% update / 50% read, zipfian keys)
against the three systems. Per the paper: 16 B keys, 8 KiB values,
preloaded records; we report insert/update/read mean + p99 latencies.

Read-path dimensions beyond the paper (PR 3): every variant also runs a
short-scan phase (YCSB-E-style ``scan(start, 10)`` from zipfian starts)
and reports the shared block-cache hit rate; the ``bvlsm-blockcache``
variant re-runs BVLSM with ``block_cache_bytes=0`` so the block cache's
contribution to read/scan latency is isolated the same way the BVCache
ablation isolates big-value caching.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import cleanup, gen_value, make_db, zipf_indices


def run(records: int = 5000, ops: int = 4000, value_size: int = 8192,
        wal: str = "async", systems=("rocksdb", "blobdb", "bvlsm"),
        bvcache_ablation: bool = True, block_cache_ablation: bool = True,
        scan_count: int = 10) -> list[dict]:
    out = []
    rng = np.random.default_rng(42)
    idx = zipf_indices(rng, records, ops)
    coins = rng.uniform(size=ops)
    scan_idx = zipf_indices(rng, records, max(1, ops // 8))
    val = gen_value(value_size, 3)
    variants = [(s_, wal, {}) for s_ in systems]
    if bvcache_ablation:
        # §III-D ablation in sync mode (no pinned entries → the flag isolates
        # the cache's optimization value on recently-written reads)
        variants.append(("bvlsm_sync+cache", "sync", {}))
        variants.append(("bvlsm_sync-cache", "sync", {"bvcache_enabled": False}))
    if block_cache_ablation:
        # PR-3 ablation: same system, block cache off — read/scan deltas
        # against plain "bvlsm" isolate the shared block cache
        variants.append(("bvlsm-blockcache", wal, {"block_cache_bytes": 0}))
    for system, wal_mode, overrides in variants:
        real_system = system.split("_sync")[0] if "_sync" in system else system
        real_system = real_system.split("-blockcache")[0]
        db, path = make_db(real_system, wal_mode, **overrides)
        try:
            ins_lat = []
            t_load0 = time.monotonic()
            for i in range(records):
                t0 = time.monotonic()
                db.put(f"user{i:012d}".encode(), val)
                ins_lat.append(time.monotonic() - t0)
            load_s = time.monotonic() - t_load0
            db.wait_idle()

            upd_lat, read_lat = [], []
            for j in range(ops):
                key = f"user{idx[j]:012d}".encode()
                if coins[j] < 0.5:
                    t0 = time.monotonic()
                    db.put(key, val)
                    upd_lat.append(time.monotonic() - t0)
                else:
                    t0 = time.monotonic()
                    v = db.get(key)
                    read_lat.append(time.monotonic() - t0)
                    assert v is not None

            scan_lat = []
            for i in scan_idx:
                t0 = time.monotonic()
                got = db.scan(f"user{i:012d}".encode(), scan_count)
                scan_lat.append(time.monotonic() - t0)
                assert got
            cache = db.bvcache.stats()
            st = db.stats.snapshot()
        finally:
            cleanup(db, path)

        def us(lat, q=None):
            a = np.array(lat) * 1e6
            return float(np.percentile(a, q)) if q else float(a.mean())

        rec = {
            "bench": "ycsb_a",
            "system": system,
            "wal": wal_mode,
            "insert_us": us(ins_lat),
            "insert_p99_us": us(ins_lat, 99),
            "update_us": us(upd_lat),
            "update_p99_us": us(upd_lat, 99),
            "read_us": us(read_lat),
            "read_p99_us": us(read_lat, 99),
            "scan_us": us(scan_lat),
            "scan_p99_us": us(scan_lat, 99),
            "load_mb_s": records * value_size / 1e6 / load_s,
            "bvcache_hit_rate": cache["hit_rate"],
            "block_cache_hit_rate": st["block_cache_hit_rate"],
        }
        out.append(rec)
        print(
            f"ycsb-a {system:16s}: insert={rec['insert_us']:7.1f}us "
            f"update={rec['update_us']:7.1f}us read={rec['read_us']:7.1f}us "
            f"(p99 {rec['read_p99_us']:7.1f}us) scan={rec['scan_us']:7.1f}us "
            f"bvcache={cache['hit_rate']:.2f} blockcache={rec['block_cache_hit_rate']:.2f}",
            flush=True,
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=5000)
    ap.add_argument("--ops", type=int, default=4000)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.records, args.ops)
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
