"""Paper Fig. 8 — YCSB Workload A (50% update / 50% read, zipfian keys)
against the three systems. Per the paper: 16 B keys, 8 KiB values,
preloaded records; we report insert/update/read mean + p99 latencies.

Read-path dimensions beyond the paper (PR 3): every variant also runs a
short-scan phase (YCSB-E-style ``scan(start, 10)`` from zipfian starts)
and reports the shared block-cache hit rate; the ``bvlsm-blockcache``
variant re-runs BVLSM with ``block_cache_bytes=0`` so the block cache's
contribution to read/scan latency is isolated the same way the BVCache
ablation isolates big-value caching.

``--workload multiget`` (PR 9) runs the batched-read variant instead:
read-only ``multi_get`` batches of 8/64/256 keys over uniform and zipfian
key streams against a preloaded BVLSM store, reporting per-batch p50/p99
latency and keys/s next to a sequential-``get`` baseline over the same
streams. ``--format-version`` pins ``sstable_format_version`` for the
store (any workload), so v2-vs-v4 batched reads are one flag apart.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.sstable import FORMAT_VERSION

from .common import cleanup, gen_value, make_db, zipf_indices


MULTIGET_BATCHES = (8, 64, 256)


def run_multiget(records: int = 5000, ops: int = 4000, value_size: int = 8192,
                 wal: str = "async", format_version: int | None = None) -> list[dict]:
    """Batched-read grid: dist x batch, per-batch p50/p99 + keys/s, with a
    sequential-get baseline row (batch=1) per distribution."""
    out = []
    rng = np.random.default_rng(42)
    overrides = {}
    if format_version is not None:
        overrides["sstable_format_version"] = format_version
    db, path = make_db("bvlsm", wal, **overrides)
    try:
        val = gen_value(value_size, 3)
        for i in range(records):
            db.put(f"user{i:012d}".encode(), val)
        db.flush()
        db.wait_idle()
        streams = {
            "zipfian": zipf_indices(rng, records, ops),
            "uniform": rng.integers(0, records, size=ops),
        }
        for dist, idx in streams.items():
            keys = [f"user{i:012d}".encode() for i in idx]
            for k in keys[: ops // 4]:  # warm both caches identically
                db.get(k)
            # baseline: the same stream, one get per key
            lat = []
            t0 = time.monotonic()
            for k in keys:
                t1 = time.monotonic()
                v = db.get(k)
                lat.append(time.monotonic() - t1)
                assert v is not None
            base_s = time.monotonic() - t0
            base_keys_s = ops / base_s
            rows = [(1, lat, base_keys_s)]
            for batch in MULTIGET_BATCHES:
                lat = []
                t0 = time.monotonic()
                for i in range(0, ops, batch):
                    chunk = keys[i : i + batch]
                    t1 = time.monotonic()
                    got = db.multi_get(chunk)
                    lat.append(time.monotonic() - t1)
                    assert all(v is not None for v in got)
                rows.append((batch, lat, ops / (time.monotonic() - t0)))
            st = db.stats.snapshot()
            for batch, lat, keys_s in rows:
                a = np.array(lat) * 1e6
                rec = {
                    "bench": "ycsb_multiget",
                    "system": "bvlsm",
                    "wal": wal,
                    "format": format_version if format_version is not None else FORMAT_VERSION,
                    "dist": dist,
                    "batch": batch,
                    "batch_p50_us": float(np.percentile(a, 50)),
                    "batch_p99_us": float(np.percentile(a, 99)),
                    "keys_per_s": keys_s,
                    "speedup_vs_get": keys_s / base_keys_s,
                    "block_cache_hit_rate": st["block_cache_hit_rate"],
                }
                out.append(rec)
                label = "get" if batch == 1 else f"multi_get x{batch}"
                print(
                    f"ycsb-mget {dist:8s} {label:14s}: {keys_s:9.0f} keys/s  "
                    f"p50={rec['batch_p50_us']:7.1f}us p99={rec['batch_p99_us']:8.1f}us  "
                    f"({rec['speedup_vs_get']:.2f}x)",
                    flush=True,
                )
    finally:
        cleanup(db, path)
    return out


def run(records: int = 5000, ops: int = 4000, value_size: int = 8192,
        wal: str = "async", systems=("rocksdb", "blobdb", "bvlsm"),
        bvcache_ablation: bool = True, block_cache_ablation: bool = True,
        scan_count: int = 10, format_version: int | None = None) -> list[dict]:
    out = []
    rng = np.random.default_rng(42)
    idx = zipf_indices(rng, records, ops)
    coins = rng.uniform(size=ops)
    scan_idx = zipf_indices(rng, records, max(1, ops // 8))
    val = gen_value(value_size, 3)
    variants = [(s_, wal, {}) for s_ in systems]
    if bvcache_ablation:
        # §III-D ablation in sync mode (no pinned entries → the flag isolates
        # the cache's optimization value on recently-written reads)
        variants.append(("bvlsm_sync+cache", "sync", {}))
        variants.append(("bvlsm_sync-cache", "sync", {"bvcache_enabled": False}))
    if block_cache_ablation:
        # PR-3 ablation: same system, block cache off — read/scan deltas
        # against plain "bvlsm" isolate the shared block cache
        variants.append(("bvlsm-blockcache", wal, {"block_cache_bytes": 0}))
    for system, wal_mode, overrides in variants:
        real_system = system.split("_sync")[0] if "_sync" in system else system
        real_system = real_system.split("-blockcache")[0]
        if format_version is not None:
            overrides = {**overrides, "sstable_format_version": format_version}
        db, path = make_db(real_system, wal_mode, **overrides)
        try:
            ins_lat = []
            t_load0 = time.monotonic()
            for i in range(records):
                t0 = time.monotonic()
                db.put(f"user{i:012d}".encode(), val)
                ins_lat.append(time.monotonic() - t0)
            load_s = time.monotonic() - t_load0
            db.wait_idle()

            upd_lat, read_lat = [], []
            for j in range(ops):
                key = f"user{idx[j]:012d}".encode()
                if coins[j] < 0.5:
                    t0 = time.monotonic()
                    db.put(key, val)
                    upd_lat.append(time.monotonic() - t0)
                else:
                    t0 = time.monotonic()
                    v = db.get(key)
                    read_lat.append(time.monotonic() - t0)
                    assert v is not None

            scan_lat = []
            for i in scan_idx:
                t0 = time.monotonic()
                got = db.scan(f"user{i:012d}".encode(), scan_count)
                scan_lat.append(time.monotonic() - t0)
                assert got
            cache = db.bvcache.stats()
            st = db.stats.snapshot()
        finally:
            cleanup(db, path)

        def us(lat, q=None):
            a = np.array(lat) * 1e6
            return float(np.percentile(a, q)) if q else float(a.mean())

        rec = {
            "bench": "ycsb_a",
            "system": system,
            "wal": wal_mode,
            "insert_us": us(ins_lat),
            "insert_p99_us": us(ins_lat, 99),
            "update_us": us(upd_lat),
            "update_p99_us": us(upd_lat, 99),
            "read_us": us(read_lat),
            "read_p99_us": us(read_lat, 99),
            "scan_us": us(scan_lat),
            "scan_p99_us": us(scan_lat, 99),
            "load_mb_s": records * value_size / 1e6 / load_s,
            "bvcache_hit_rate": cache["hit_rate"],
            "block_cache_hit_rate": st["block_cache_hit_rate"],
        }
        out.append(rec)
        print(
            f"ycsb-a {system:16s}: insert={rec['insert_us']:7.1f}us "
            f"update={rec['update_us']:7.1f}us read={rec['read_us']:7.1f}us "
            f"(p99 {rec['read_p99_us']:7.1f}us) scan={rec['scan_us']:7.1f}us "
            f"bvcache={cache['hit_rate']:.2f} blockcache={rec['block_cache_hit_rate']:.2f}",
            flush=True,
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=5000)
    ap.add_argument("--ops", type=int, default=4000)
    ap.add_argument("--workload", choices=("a", "multiget"), default="a",
                    help="'a' = YCSB-A grid; 'multiget' = batched-read grid")
    ap.add_argument("--format-version", type=int, default=None,
                    help="pin sstable_format_version for the store(s)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.workload == "multiget":
        res = run_multiget(args.records, args.ops, format_version=args.format_version)
    else:
        res = run(args.records, args.ops, format_version=args.format_version)
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
