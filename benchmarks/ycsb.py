"""Paper Fig. 8 — YCSB Workload A (50% update / 50% read, zipfian keys)
against the three systems. Per the paper: 16 B keys, 8 KiB values,
preloaded records; we report insert/update/read mean + p99 latencies.

Read-path dimensions beyond the paper (PR 3): every variant also runs a
short-scan phase (YCSB-E-style ``scan(start, 10)`` from zipfian starts)
and reports the shared block-cache hit rate; the ``bvlsm-blockcache``
variant re-runs BVLSM with ``block_cache_bytes=0`` so the block cache's
contribution to read/scan latency is isolated the same way the BVCache
ablation isolates big-value caching.

``--workload multiget`` (PR 9) runs the batched-read variant instead:
read-only ``multi_get`` batches of 8/64/256 keys over uniform and zipfian
key streams against a preloaded BVLSM store, reporting per-batch p50/p99
latency and keys/s next to a sequential-``get`` baseline over the same
streams. ``--format-version`` pins ``sstable_format_version`` for the
store (any workload), so v2-vs-v4 batched reads are one flag apart.

``--workload sharding`` (PR 10) measures :class:`ShardedDB` scaling:
16 concurrent writers push 64 KiB values at sync-WAL stores of 1 and N
shards (``--shards``), reporting aggregate write throughput, overall and
per-shard p99, and batched ``multi_get`` fan-out keys/s. Each cell runs
twice: once under :class:`DeviceModelEnv` — fsync costs a fixed device
latency and is serialized **per file**, modelling one flash channel per
shard the way the paper's multi-queue analysis assumes independent
BValue queues (§III-C) — and once against the raw filesystem. On a
GIL-bound single box the raw cells mostly show Python overhead, so the
headline ``agg_write_speedup`` comes from the device-model cells where
the benefit of sharding is real fsync-channel parallelism, not thread
scheduling noise.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import DBConfig, ShardedDB
from repro.core.env import Env
from repro.core.sstable import FORMAT_VERSION

from .common import cleanup, gen_keys, gen_value, make_db, zipf_indices


MULTIGET_BATCHES = (8, 64, 256)


class DeviceModelEnv(Env):
    """Every fsync costs ``delay_s`` of device time and fsyncs to the SAME
    file serialize (one flash channel per file); fsyncs to different files
    overlap. A 1-shard store funnels every commit through one WAL channel;
    an N-shard store gets N independent channels — exactly the hardware
    claim sharding makes."""

    def __init__(self, delay_s: float):
        self.delay_s = delay_s
        self._locks: dict = {}
        self._mu = threading.Lock()

    def _lock_for(self, f):
        try:
            name = f.name
        except Exception:
            name = str(f)
        with self._mu:
            lk = self._locks.get(name)
            if lk is None:
                lk = self._locks[name] = threading.Lock()
            return lk

    def fsync(self, f) -> None:
        with self._lock_for(f):
            time.sleep(self.delay_s)
            super().fsync(f)


def _sharding_cell(shards: int, ops: int, value_size: int, threads: int,
                   device_fsync_us: float, read_batch: int) -> dict:
    path = tempfile.mkdtemp(prefix=f"bench_shard{shards}_")
    cfg = DBConfig.bvlsm(
        wal_mode="sync",
        value_threshold=4096,
        memtable_size=8 << 20,
        level1_max_bytes=32 << 20,
        num_bvalue_queues=2,
        bvcache_bytes=8 << 20,
        env=DeviceModelEnv(device_fsync_us * 1e-6) if device_fsync_us else None,
    )
    s = ShardedDB.open(path, shards=shards, config=cfg)
    try:
        keys = gen_keys(ops, "rand", seed=11)
        val = gen_value(value_size, 7)
        sinks: list[list[tuple[bytes, float]]] = [[] for _ in range(threads)]
        errors: list[BaseException] = []

        def worker(part: list[bytes], sink: list) -> None:
            try:
                for k in part:
                    t1 = time.monotonic()
                    s.put(k, val)
                    sink.append((k, time.monotonic() - t1))
            except BaseException as e:
                errors.append(e)

        ts = [
            threading.Thread(target=worker, args=(keys[i::threads], sinks[i]))
            for i in range(threads)
        ]
        t0 = time.monotonic()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        s.flush()
        write_s = time.monotonic() - t0
        if errors:
            raise errors[0]

        by_shard: list[list[float]] = [[] for _ in range(shards)]
        all_lat: list[float] = []
        for sink in sinks:
            for k, lat in sink:
                by_shard[s.shard_of(k)].append(lat)
                all_lat.append(lat)

        rng = np.random.default_rng(5)
        mget_keys = [keys[i] for i in rng.permutation(ops)]
        t0 = time.monotonic()
        for i in range(0, ops, read_batch):
            got = s.multi_get(mget_keys[i : i + read_batch])
            assert all(v is not None for v in got)
        mget_keys_s = ops / (time.monotonic() - t0)
        router = s.stats()["router"]
    finally:
        s.close()
        shutil.rmtree(path, ignore_errors=True)

    def p99(a):
        return float(np.percentile(np.array(a) * 1e3, 99)) if a else 0.0

    return {
        "bench": "ycsb_sharding",
        "shards": shards,
        "threads": threads,
        "device_fsync_us": device_fsync_us,
        "value_size": value_size,
        "ops": ops,
        "write_ops_s": ops / write_s,
        "write_mb_s": ops * value_size / 1e6 / write_s,
        "write_p99_ms": p99(all_lat),
        "per_shard_p99_ms": [p99(a) for a in by_shard],
        "per_shard_ops": [len(a) for a in by_shard],
        "mget_keys_s": mget_keys_s,
        "router": router,
    }


def run_sharding(ops: int = 1600, value_size: int = 64 * 1024,
                 shards: int = 4, threads: int = 16,
                 device_fsync_us: float = 2000.0,
                 read_batch: int = 64) -> dict:
    """Sharding scaling grid: {device-model, raw-fs} x {1, N shards}. The
    gate metric (``agg_write_speedup``) compares aggregate write throughput
    of the N-shard cell to the 1-shard cell under the device model."""
    out = []
    for tau_us, modelled in ((device_fsync_us, True), (0.0, False)):
        base_ops_s = None
        for n in (1, shards):
            cell = _sharding_cell(n, ops, value_size, threads, tau_us,
                                  read_batch)
            if base_ops_s is None:
                base_ops_s = cell["write_ops_s"]
            cell["speedup_vs_1shard"] = cell["write_ops_s"] / base_ops_s
            cell["device_model"] = modelled
            out.append(cell)
            tag = f"tau={tau_us:.0f}us" if modelled else "raw-fs"
            print(
                f"ycsb-shard {tag:12s} shards={n}: "
                f"{cell['write_ops_s']:7.1f} ops/s "
                f"({cell['write_mb_s']:6.1f} MB/s) "
                f"p99={cell['write_p99_ms']:7.1f}ms "
                f"shard-p99={[round(x, 1) for x in cell['per_shard_p99_ms']]} "
                f"mget={cell['mget_keys_s']:7.0f} keys/s "
                f"[{cell['speedup_vs_1shard']:.2f}x]",
                flush=True,
            )
    modelled = [c for c in out if c["device_model"]]
    summary = {
        "shards": shards,
        "agg_write_speedup": modelled[-1]["speedup_vs_1shard"],
        "agg_mget_speedup": modelled[-1]["mget_keys_s"] / modelled[0]["mget_keys_s"],
        "device_fsync_us": device_fsync_us,
    }
    print(
        f"ycsb-shard summary: {shards}-shard aggregate write speedup "
        f"{summary['agg_write_speedup']:.2f}x under the device model",
        flush=True,
    )
    return {"cells": out, "summary": summary}


def run_multiget(records: int = 5000, ops: int = 4000, value_size: int = 8192,
                 wal: str = "async", format_version: int | None = None) -> list[dict]:
    """Batched-read grid: dist x batch, per-batch p50/p99 + keys/s, with a
    sequential-get baseline row (batch=1) per distribution."""
    out = []
    rng = np.random.default_rng(42)
    overrides = {}
    if format_version is not None:
        overrides["sstable_format_version"] = format_version
    db, path = make_db("bvlsm", wal, **overrides)
    try:
        val = gen_value(value_size, 3)
        for i in range(records):
            db.put(f"user{i:012d}".encode(), val)
        db.flush()
        db.wait_idle()
        streams = {
            "zipfian": zipf_indices(rng, records, ops),
            "uniform": rng.integers(0, records, size=ops),
        }
        for dist, idx in streams.items():
            keys = [f"user{i:012d}".encode() for i in idx]
            for k in keys[: ops // 4]:  # warm both caches identically
                db.get(k)
            # baseline: the same stream, one get per key
            lat = []
            t0 = time.monotonic()
            for k in keys:
                t1 = time.monotonic()
                v = db.get(k)
                lat.append(time.monotonic() - t1)
                assert v is not None
            base_s = time.monotonic() - t0
            base_keys_s = ops / base_s
            rows = [(1, lat, base_keys_s)]
            for batch in MULTIGET_BATCHES:
                lat = []
                t0 = time.monotonic()
                for i in range(0, ops, batch):
                    chunk = keys[i : i + batch]
                    t1 = time.monotonic()
                    got = db.multi_get(chunk)
                    lat.append(time.monotonic() - t1)
                    assert all(v is not None for v in got)
                rows.append((batch, lat, ops / (time.monotonic() - t0)))
            st = db.stats.snapshot()
            for batch, lat, keys_s in rows:
                a = np.array(lat) * 1e6
                rec = {
                    "bench": "ycsb_multiget",
                    "system": "bvlsm",
                    "wal": wal,
                    "format": format_version if format_version is not None else FORMAT_VERSION,
                    "dist": dist,
                    "batch": batch,
                    "batch_p50_us": float(np.percentile(a, 50)),
                    "batch_p99_us": float(np.percentile(a, 99)),
                    "keys_per_s": keys_s,
                    "speedup_vs_get": keys_s / base_keys_s,
                    "block_cache_hit_rate": st["block_cache_hit_rate"],
                }
                out.append(rec)
                label = "get" if batch == 1 else f"multi_get x{batch}"
                print(
                    f"ycsb-mget {dist:8s} {label:14s}: {keys_s:9.0f} keys/s  "
                    f"p50={rec['batch_p50_us']:7.1f}us p99={rec['batch_p99_us']:8.1f}us  "
                    f"({rec['speedup_vs_get']:.2f}x)",
                    flush=True,
                )
    finally:
        cleanup(db, path)
    return out


def run(records: int = 5000, ops: int = 4000, value_size: int = 8192,
        wal: str = "async", systems=("rocksdb", "blobdb", "bvlsm"),
        bvcache_ablation: bool = True, block_cache_ablation: bool = True,
        scan_count: int = 10, format_version: int | None = None) -> list[dict]:
    out = []
    rng = np.random.default_rng(42)
    idx = zipf_indices(rng, records, ops)
    coins = rng.uniform(size=ops)
    scan_idx = zipf_indices(rng, records, max(1, ops // 8))
    val = gen_value(value_size, 3)
    variants = [(s_, wal, {}) for s_ in systems]
    if bvcache_ablation:
        # §III-D ablation in sync mode (no pinned entries → the flag isolates
        # the cache's optimization value on recently-written reads)
        variants.append(("bvlsm_sync+cache", "sync", {}))
        variants.append(("bvlsm_sync-cache", "sync", {"bvcache_enabled": False}))
    if block_cache_ablation:
        # PR-3 ablation: same system, block cache off — read/scan deltas
        # against plain "bvlsm" isolate the shared block cache
        variants.append(("bvlsm-blockcache", wal, {"block_cache_bytes": 0}))
    for system, wal_mode, overrides in variants:
        real_system = system.split("_sync")[0] if "_sync" in system else system
        real_system = real_system.split("-blockcache")[0]
        if format_version is not None:
            overrides = {**overrides, "sstable_format_version": format_version}
        db, path = make_db(real_system, wal_mode, **overrides)
        try:
            ins_lat = []
            t_load0 = time.monotonic()
            for i in range(records):
                t0 = time.monotonic()
                db.put(f"user{i:012d}".encode(), val)
                ins_lat.append(time.monotonic() - t0)
            load_s = time.monotonic() - t_load0
            db.wait_idle()

            upd_lat, read_lat = [], []
            for j in range(ops):
                key = f"user{idx[j]:012d}".encode()
                if coins[j] < 0.5:
                    t0 = time.monotonic()
                    db.put(key, val)
                    upd_lat.append(time.monotonic() - t0)
                else:
                    t0 = time.monotonic()
                    v = db.get(key)
                    read_lat.append(time.monotonic() - t0)
                    assert v is not None

            scan_lat = []
            for i in scan_idx:
                t0 = time.monotonic()
                got = list(db.range(f"user{i:012d}".encode(), limit=scan_count))
                scan_lat.append(time.monotonic() - t0)
                assert got
            cache = db.bvcache.stats()
            st = db.stats.snapshot()
        finally:
            cleanup(db, path)

        def us(lat, q=None):
            a = np.array(lat) * 1e6
            return float(np.percentile(a, q)) if q else float(a.mean())

        rec = {
            "bench": "ycsb_a",
            "system": system,
            "wal": wal_mode,
            "insert_us": us(ins_lat),
            "insert_p99_us": us(ins_lat, 99),
            "update_us": us(upd_lat),
            "update_p99_us": us(upd_lat, 99),
            "read_us": us(read_lat),
            "read_p99_us": us(read_lat, 99),
            "scan_us": us(scan_lat),
            "scan_p99_us": us(scan_lat, 99),
            "load_mb_s": records * value_size / 1e6 / load_s,
            "bvcache_hit_rate": cache["hit_rate"],
            "block_cache_hit_rate": st["block_cache_hit_rate"],
        }
        out.append(rec)
        print(
            f"ycsb-a {system:16s}: insert={rec['insert_us']:7.1f}us "
            f"update={rec['update_us']:7.1f}us read={rec['read_us']:7.1f}us "
            f"(p99 {rec['read_p99_us']:7.1f}us) scan={rec['scan_us']:7.1f}us "
            f"bvcache={cache['hit_rate']:.2f} blockcache={rec['block_cache_hit_rate']:.2f}",
            flush=True,
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=5000)
    ap.add_argument("--ops", type=int, default=4000)
    ap.add_argument("--workload", choices=("a", "multiget", "sharding"),
                    default="a",
                    help="'a' = YCSB-A grid; 'multiget' = batched-read grid; "
                         "'sharding' = ShardedDB write-scaling grid")
    ap.add_argument("--format-version", type=int, default=None,
                    help="pin sstable_format_version for the store(s)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for the sharding workload's N-shard cell")
    ap.add_argument("--threads", type=int, default=16,
                    help="writer threads for the sharding workload")
    ap.add_argument("--device-fsync-us", type=float, default=2000.0,
                    help="modelled per-fsync device latency (sharding workload)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.workload == "multiget":
        res = run_multiget(args.records, args.ops, format_version=args.format_version)
    elif args.workload == "sharding":
        res = run_sharding(args.ops, shards=args.shards, threads=args.threads,
                           device_fsync_us=args.device_fsync_us)
    else:
        res = run(args.records, args.ops, format_version=args.format_version)
    if args.out:
        json.dump(res, open(args.out, "w"), indent=2)


if __name__ == "__main__":
    main()
