"""Render the §Roofline table from the dry-run JSON artifacts
(artifacts/dryrun/*.json) — per (arch × shape × mesh): the three terms,
dominant bottleneck, MODEL_FLOPS/HLO ratio, memory fit."""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_LIMIT = 16 * 2**30


def load(art_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def render(recs: list[dict], mesh: str | None = None) -> str:
    rows = []
    hdr = (
        f"{'arch':24s} {'shape':12s} {'mesh':10s} {'variant':18s} {'st':4s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'dominant':>12s} "
        f"{'useful%':>8s} {'mem/dev':>9s} {'fits':>5s}"
    )
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in sorted(
        recs, key=lambda r: (r["mesh"], r["arch"], r["shape"], r.get("variant", ""))
    ):
        if mesh and r["mesh"] != mesh:
            continue
        var = r.get("variant", "baseline") or "baseline"
        if r.get("status") == "skip":
            rows.append(
                f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} {var:18s} skip  {r['reason']}"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} {var:18s} ERR   {r.get('error','')[:60]}"
            )
            continue
        rf = r["roofline"]
        mem = r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]
        fits = "yes" if mem <= HBM_LIMIT else "NO"
        rows.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} {var:18s} ok   "
            f"{rf['compute_s']:10.4f} {rf['memory_s']:10.4f} {rf['collective_s']:10.4f} "
            f"{rf['dominant'].replace('_s',''):>12s} "
            f"{100*r['cost'].get('useful_flops_ratio',0):7.1f}% "
            f"{mem/2**30:8.2f}G {fits:>5s}"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    print(render(load(args.dir), args.mesh))


if __name__ == "__main__":
    main()
