"""Benchmark suite entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out artifacts/bench]

Prints ``name,us_per_call,derived`` CSV lines at the end per the harness
contract, plus the human-readable section output as it runs.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from . import db_bench, multiqueue, roofline, stability, ycsb

    results: dict[str, list] = {}
    csv: list[tuple[str, float, str]] = []

    mb = 16 if args.quick else 48
    secs = 6.0 if args.quick else 20.0
    records = 1500 if args.quick else 5000
    ops = 1000 if args.quick else 4000

    print("== Fig.6: random writes × WAL modes × value sizes ==", flush=True)
    results["fig6_random"] = db_bench.run("random", mb=mb)
    print("\n== Fig.7: sequential writes ==", flush=True)
    results["fig7_seq"] = db_bench.run("seq", mb=mb)
    print("\n== Fig.8: YCSB-A latencies ==", flush=True)
    results["fig8_ycsb"] = ycsb.run(records=records, ops=ops)
    print("\n== Fig.9: sustained-write stability ==", flush=True)
    results["fig9_stability"] = stability.engine_stability(seconds=secs)
    results["fig9_ckpt_jitter"] = stability.checkpoint_jitter(
        steps=40 if args.quick else 60
    )
    print("\n== Fig.10: multi-queue scaling ==", flush=True)
    results["fig10_multiqueue"] = multiqueue.run(total_mb=mb)

    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=2)

    # ---- derived headline numbers (vs the paper's claims) ----
    def ratio(bench, wal, vs, a, b):
        recs = {r["system"]: r for r in results[bench] if r["wal"] == wal and r["value_size"] == vs}
        if a in recs and b in recs and recs[b]["mb_per_s"]:
            return recs[a]["mb_per_s"] / recs[b]["mb_per_s"]
        return float("nan")

    print("\n== headline ratios (paper: R-WA 64K → 7.6× vs rocksdb, 1.9× vs blobdb) ==")
    for wal, vs in (("async", 65536), ("sync", 65536), ("off", 65536), ("async", 4096)):
        rv = ratio("fig6_random", wal, vs, "bvlsm", "rocksdb")
        bv = ratio("fig6_random", wal, vs, "bvlsm", "blobdb")
        print(f"  R-{wal:5s} {vs//1024}K: bvlsm/rocksdb={rv:5.2f}x  bvlsm/blobdb={bv:5.2f}x")
        csv.append((f"fig6_ratio_rocksdb_{wal}_{vs}", 0.0, f"{rv:.3f}"))
        csv.append((f"fig6_ratio_blobdb_{wal}_{vs}", 0.0, f"{bv:.3f}"))

    ly = {r["system"]: r for r in results["fig8_ycsb"]}
    if "bvlsm" in ly and "rocksdb" in ly:
        for op in ("insert_us", "update_us", "read_us"):
            frac = ly["bvlsm"][op] / ly["rocksdb"][op]
            print(f"  ycsb {op}: bvlsm at {100*frac:.1f}% of rocksdb (paper: 27.2/28.4/19.7%)")
            csv.append((f"ycsb_{op}_fraction", ly["bvlsm"][op], f"{frac:.3f}"))

    st = {r["system"]: r for r in results["fig9_stability"]}
    for s, r in st.items():
        csv.append((f"stability_cv_{s}", 0.0, f"{r['cv']:.4f}"))
    if "bvlsm" in st:
        best = min(st, key=lambda s: st[s]["cv"])
        print(f"  stability: lowest CV = {best} (paper: bvlsm)")

    mq = [r for r in results["fig10_multiqueue"] if r["bench"] == "multiqueue_async"]
    by = {(r["value_size"], r["queues"]): r["mb_per_s"] for r in mq}
    for vs in (4096, 65536):
        if (vs, 1) in by and (vs, 4) in by and by[(vs, 1)]:
            g = by[(vs, 4)] / by[(vs, 1)]
            print(f"  multiqueue {vs//1024}K 4q/1q: {g:.2f}x (paper: +40-60%)")
            csv.append((f"multiqueue_gain_{vs}", 0.0, f"{g:.3f}"))

    # per-op CSV (benchmark contract)
    print("\nname,us_per_call,derived")
    for rec in results["fig6_random"]:
        us = 1e6 / rec["ops_per_s"] if rec["ops_per_s"] else 0.0
        print(f"fig6_{rec['system']}_{rec['wal']}_{rec['value_size']},{us:.2f},{rec['mb_per_s']:.1f}MB/s")
    for rec in results["fig8_ycsb"]:
        print(f"ycsb_{rec['system']}_read,{rec['read_us']:.2f},p99={rec['read_p99_us']:.1f}us")
        print(f"ycsb_{rec['system']}_update,{rec['update_us']:.2f},p99={rec['update_p99_us']:.1f}us")
    for name, us, derived in csv:
        print(f"{name},{us:.2f},{derived}")

    # roofline table if artifacts exist
    art = "artifacts/dryrun"
    if os.path.isdir(art) and os.listdir(art):
        print("\n== Roofline (from dry-run artifacts) ==")
        print(roofline.render(roofline.load(art)))


if __name__ == "__main__":
    main()
