"""Fault tolerance: exact resume (params + optimizer + data cursor),
preemption checkpointing, straggler detection, pipeline determinism."""
import shutil

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig

CFG = get_config("llama3-8b").reduced(d_model=64, n_layers=2, vocab=512, vocab_pad_multiple=64)


def _tcfg(tmp, steps, interval=5, async_=True):
    return TrainerConfig(
        steps=steps,
        global_batch=2,
        seq_len=32,
        ckpt_dir=tmp,
        ckpt_interval=interval,
        ckpt_async=async_,
        log_every=10_000,
        train=TrainConfig(opt=OptimizerConfig(warmup_steps=2, total_steps=100)),
    )


def _params_equal(a, b):
    flat_a = jax.tree.leaves(a)
    flat_b = jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(flat_a, flat_b))


def test_pipeline_deterministic_resume():
    p1 = TokenPipeline(512, 4, 16, seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    p2 = TokenPipeline(512, 4, 16, seed=3)
    p2.load_state_dict({"seed": 3, "step": 3, "host": 0, "num_hosts": 1})
    b3 = p2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])


def test_exact_resume_matches_uninterrupted(tmp_path):
    """train 10 straight  ==  train 5, 'crash', resume to 10 — bitwise."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    t_full = Trainer(CFG, _tcfg(d1, steps=10, interval=100))
    t_full.run()
    full_params = jax.device_get(t_full.state["params"])
    t_full.close()

    t_half = Trainer(CFG, _tcfg(d2, steps=5, interval=5, async_=False))
    t_half.run()
    t_half.close()  # process "dies" here
    t_resume = Trainer(CFG, _tcfg(d2, steps=10, interval=100))
    res = t_resume.run()
    assert res["step"] == 10
    resumed_params = jax.device_get(t_resume.state["params"])
    t_resume.close()
    assert _params_equal(full_params, resumed_params)


def test_preemption_checkpoints_and_resumes(tmp_path):
    d = str(tmp_path / "p")
    tr = Trainer(CFG, _tcfg(d, steps=20, interval=100))
    orig = tr.pipeline.next_batch
    n = {"v": 0}

    def wrapped():
        n["v"] += 1
        if n["v"] == 7:
            tr._preempted = True  # SIGTERM equivalent
        return orig()

    tr.pipeline.next_batch = wrapped
    res = tr.run()
    tr.close()
    assert res["status"] == "preempted" and res["step"] == 7

    tr2 = Trainer(CFG, _tcfg(d, steps=20, interval=100))
    res2 = tr2.run()
    tr2.close()
    assert res2["status"] == "done" and res2["step"] == 20


def test_straggler_detection(tmp_path):
    import time

    d = str(tmp_path / "s")
    events = []
    tr = Trainer(CFG, _tcfg(d, steps=15, interval=100), straggler_cb=lambda *a: events.append(a))
    orig = tr.pipeline.next_batch
    n = {"v": 0}

    def slow():
        n["v"] += 1
        if n["v"] == 12:
            time.sleep(1.0)  # inject a straggler step
        return orig()

    tr.pipeline.next_batch = slow
    tr.run()
    tr.close()
    assert tr.straggler_events >= 1
    assert events
