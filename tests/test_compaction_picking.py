"""Write-amp-aware compaction: overlap-ratio scoring, trivial moves,
adaptive subcompaction shard counts, the grandparent-aware pending-debt
estimate, the unified foreground/background I/O budget, and sliced GC."""
import os

import numpy as np
import pytest

from repro.core import DB, DBConfig
from repro.core.compaction import Compactor
from repro.core.manifest import Version
from repro.core.ratelimiter import PRI_FG, PRI_LOW, RateLimiter
from repro.core.sstable import FileMetadata
from repro.core.stats import EngineStats


def _db(tmp, **kw):
    cfg = dict(
        separation_mode="wal",
        wal_mode="sync",
        memtable_size=64 << 10,
        value_threshold=4096,
        level1_max_bytes=128 << 10,
        l0_compaction_trigger=2,
        background_threads=2,
        subcompaction_min_bytes=32 << 10,
    )
    cfg.update(kw)
    return DB(tmp, DBConfig(**cfg))


def _fill(db, n, value_size=512, seed=0, prefix="k"):
    rng = np.random.default_rng(seed)
    vals = {}
    for i in range(n):
        k = f"{prefix}{i:06d}".encode()
        v = rng.bytes(value_size)
        db.put(k, v)
        vals[k] = v
    return vals


class _FakeVersions:
    def __init__(self, v):
        self.current = v
        self.compaction_ptr = {}


def _fake_db(v, **cfg_kw):
    db = type("_FakeDB", (), {})()
    db.cfg = DBConfig(**cfg_kw)
    db.versions = _FakeVersions(v)
    db.stats = EngineStats()
    return db


def _meta(no, size, smallest, largest):
    return FileMetadata(no, size, smallest, largest, 10)


# ---------------------------------------------------------------------------
# trivial moves
# ---------------------------------------------------------------------------
def test_trivial_move_promotes_without_rewrite(tmp_db_dir):
    # trigger=100 keeps the scheduler away while we build exactly one L0
    # file; lowering the trigger to 1 then makes that lone file pickable —
    # L1 is empty, so the job must be a pure manifest-edit promotion
    db = _db(tmp_db_dir, l0_compaction_trigger=100)
    try:
        vals = _fill(db, 200, value_size=256)
        db.flush()
        v = db.versions.current
        assert len(v.levels[0]) == 1 and not v.levels[1]
        moved_no = v.levels[0][0].file_no
        db.cfg.l0_compaction_trigger = 1
        db.compact_all()
        st = db.stats.snapshot()
        assert st["trivial_moves"] >= 1, st
        assert st["trivial_move_bytes"] > 0
        # zero bytes rewritten: no compaction merge ran
        assert st["compaction_bytes_written"] == 0, st
        v = db.versions.current
        assert not v.levels[0]
        assert moved_no in {f.file_no for lv in v.levels[1:] for f in lv}
        # the same physical table serves reads from its new level
        for k, val in vals.items():
            assert db.get(k) == val, k
        out = list(db.range(limit=1000))
        keys = [k for k, _ in out]
        assert keys == sorted(keys) and len(keys) == 200
    finally:
        db.close()


def test_trivial_move_survives_crash_reopen(tmp_db_dir):
    db = _db(tmp_db_dir, l0_compaction_trigger=100)
    vals = _fill(db, 150, value_size=256)
    db.flush()
    db.cfg.l0_compaction_trigger = 1
    db.compact_all()
    assert db.stats.snapshot()["trivial_moves"] >= 1
    db.close(crash=True)
    db2 = _db(tmp_db_dir, l0_compaction_trigger=100)
    try:
        # manifest replay lands the moved file at its new level, the table
        # is still on disk (a move must never unlink), and reads hold
        live = {f.file_no for lv in db2.versions.current.levels for f in lv}
        on_disk = {int(f[:-4]) for f in os.listdir(tmp_db_dir) if f.endswith(".sst")}
        assert live == on_disk
        for k, val in vals.items():
            assert db2.get(k) == val, k
        keys = [k for k, _ in db2.range(limit=1000)]
        assert keys == sorted(keys) and len(keys) == 150
    finally:
        db2.close()


def test_trivial_move_respects_grandparent_cap():
    # L1 file with zero L2 overlap but a huge L3 (grandparent) overlap:
    # parking it would make the future L2→L3 job worse than the rewrite
    v = Version(7)
    f = _meta(1, 10 << 10, b"m", b"n")
    v.levels[1] = [f]
    v.levels[3] = [_meta(2, 100 << 20, b"a", b"z")]
    db = _fake_db(v, trivial_move_max_gp_bytes=1 << 20)
    comp = Compactor(db)
    assert comp._maybe_trivial_move(1, [f], []) is False
    db.cfg.trivial_move = False  # ablation switch blocks the path outright
    db.cfg.trivial_move_max_gp_bytes = 0
    assert comp._maybe_trivial_move(1, [f], []) is False


# ---------------------------------------------------------------------------
# overlap-ratio scoring
# ---------------------------------------------------------------------------
def _two_level_version():
    """L1 is the fuller level but its only job drags a huge L2 overlap;
    L2 is over target too and holds a file with zero L3 overlap."""
    v = Version(7)
    v.levels[1] = [_meta(1, 200 << 10, b"b", b"c")]  # cap 100K → fullness 2.0
    v.levels[2] = [
        _meta(2, 1 << 20, b"a", b"d"),  # overlaps ALL of L1's file
        _meta(3, 300 << 10, b"x", b"y"),  # cheap: no L3 overlap
    ]  # cap 1M → fullness ~1.3
    return v


def test_overlap_scoring_prefers_cheaper_level():
    v = _two_level_version()
    db = _fake_db(
        v, level1_max_bytes=100 << 10, level_size_multiplier=10, compaction_pick_policy="overlap"
    )
    picked = Compactor(db).pick()
    assert picked is not None
    level, inputs, overlaps = picked
    # fullness alone would send L1's file through a 1 MiB rewrite; per byte
    # actually moved, L2's zero-overlap files clear more urgency (both L2
    # files are ratio-0 ties — either is an optimal, rewrite-free pick)
    assert level == 2
    assert [f.file_no for f in inputs] in ([2], [3])
    assert overlaps == []


def test_fullness_policy_still_picks_hottest_level():
    v = _two_level_version()
    db = _fake_db(
        v, level1_max_bytes=100 << 10, level_size_multiplier=10,
        compaction_pick_policy="fullness",
    )
    picked = Compactor(db).pick()
    assert picked is not None
    level, inputs, _overlaps = picked
    assert level == 1 and inputs[0].file_no == 1


def test_overlap_scoring_picks_min_ratio_file_within_level():
    v = Version(7)
    v.levels[1] = [
        _meta(1, 100 << 10, b"a", b"b"),  # overlaps 900K at L2
        _meta(2, 100 << 10, b"m", b"n"),  # overlaps 50K at L2
    ]
    # keep L2 under its 640K cap so only L1 is a candidate level
    v.levels[2] = [_meta(3, 500 << 10, b"a", b"c"), _meta(4, 50 << 10, b"m", b"z")]
    db = _fake_db(v, level1_max_bytes=64 << 10, compaction_pick_policy="overlap")
    level, inputs, overlaps = Compactor(db).pick()
    assert level == 1
    assert inputs[0].file_no == 2
    assert [f.file_no for f in overlaps] == [4]
    # locked-out cheap file: the expensive one still makes progress
    level, inputs, overlaps = Compactor(db).pick(locked={2})
    assert inputs[0].file_no == 1


# ---------------------------------------------------------------------------
# adaptive subcompaction shard count
# ---------------------------------------------------------------------------
def test_adaptive_shards_degrade_to_one_on_tiny_inputs():
    db = _fake_db(
        Version(7), max_subcompactions=4, subcompaction_min_bytes=256 << 10,
        subcompaction_target_seconds=0.5,
    )
    comp = Compactor(db)
    assert comp._choose_shards(100 << 10) == 1  # below the floor: no fan-out
    assert comp._choose_shards(2 << 20) == 4  # big input: full budget
    assert comp._choose_shards(600 << 10) == 2  # proportional in between
    # history raises the per-shard target: a fast merge pipeline means a
    # 2 MiB job no longer deserves 4 shards
    comp._shard_bytes_per_s = 100e6
    assert comp._choose_shards(2 << 20) == 1
    assert comp._choose_shards(400 << 20) == 4
    # ablation: fixed fan-out restores the old behavior
    db.cfg.subcompaction_adaptive = False
    assert comp._choose_shards(100 << 10) == 4
    db.cfg.max_subcompactions = 1
    assert comp._choose_shards(1 << 30) == 1


def test_shard_rate_ewma_updates_from_runs(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        _fill(db, 1500, value_size=512)
        _fill(db, 1500, value_size=512, seed=1)
        db.flush()
        db.compact_all()
        comp = db.bg.compactor
        assert comp._shard_bytes_per_s > 0.0
        assert db.stats.snapshot()["gauges"].get("subcompaction_bytes_per_s", 0) > 0
    finally:
        db.close()


# ---------------------------------------------------------------------------
# grandparent-aware pending debt
# ---------------------------------------------------------------------------
def test_pending_debt_counts_grandparent_overlap(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        v = Version(db.cfg.num_levels)
        # L1 is 300K over a 128K cap; L2 holds 1 MiB the excess must merge
        # through; L3 holds more the cascade will eventually drag along
        v.levels[1] = [_meta(901, 428 << 10, b"a", b"m")]
        v.levels[2] = [_meta(902, 1 << 20, b"a", b"z")]
        v.levels[3] = [_meta(903, 4 << 20, b"a", b"z")]
        real = db.versions.current
        db.versions.current = v
        db.cfg.pending_debt_overlap_aware = False
        legacy = db._pending_compaction_bytes()
        db.cfg.pending_debt_overlap_aware = True
        aware = db._pending_compaction_bytes()
        db.versions.current = real
        assert legacy == (428 << 10) - (128 << 10)
        # the overlap-aware estimate sees the same displaced bytes plus the
        # L2 bytes they rewrite and the knock-on L2→L3 debt — strictly more
        assert aware > legacy * 2, (aware, legacy)
    finally:
        db.close()


# ---------------------------------------------------------------------------
# unified I/O budget
# ---------------------------------------------------------------------------
def test_fg_priority_never_blocks_but_shrinks_background_refill():
    rl = RateLimiter(1 << 20, refill_period_s=0.002)  # 1 MiB/s
    import time

    t0 = time.monotonic()
    for _ in range(50):
        rl.request(1 << 20, PRI_FG)  # 50 MiB of foreground: never blocks
    assert time.monotonic() - t0 < 0.5
    # sustained FG traffic must leave LOW a floored-but-positive refill:
    # a small LOW request completes (slowly), it is not wedged forever
    t0 = time.monotonic()
    rl.request(8 << 10, PRI_LOW)
    assert time.monotonic() - t0 < 10.0
    assert rl.fg_rate_estimate() > 0.0


def test_foreground_separation_charges_unified_budget(tmp_db_dir):
    db = _db(tmp_db_dir, bg_io_bytes_per_sec=64 << 20, value_threshold=1024)
    try:
        for i in range(10):
            db.put(f"big{i:03d}".encode(), b"V" * 4096)
        st = db.stats.snapshot()
        assert st["rate_limiter_fg_bytes"] >= 10 * 4096, st
        db.cfg  # unified by default
    finally:
        db.close()


def test_unified_budget_disabled_charges_nothing(tmp_db_dir):
    db = _db(
        tmp_db_dir, bg_io_bytes_per_sec=64 << 20, value_threshold=1024,
        unified_io_budget=False,
    )
    try:
        for i in range(10):
            db.put(f"big{i:03d}".encode(), b"V" * 4096)
        assert db.stats.snapshot()["rate_limiter_fg_bytes"] == 0
    finally:
        db.close()


# ---------------------------------------------------------------------------
# sliced GC
# ---------------------------------------------------------------------------
def test_sliced_gc_collects_across_slices(tmp_db_dir):
    db = _db(tmp_db_dir, value_threshold=512, bvalue_max_file_bytes=16 << 10)
    try:
        for i in range(40):
            db.put(f"g{i:03d}".encode(), b"A" * 2048)
        for i in range(40):
            db.put(f"g{i:03d}".encode(), b"B" * 2048)
        db.flush()
        db.compact_all()
        collected = 0
        for _ in range(64):  # each slice rewrites ≤ ~2 values then yields
            res = db.bg.run_gc(0.3, max_rewrite_bytes=4096)
            collected += res["collected_files"]
            if not res["sliced"] and res["collected_files"] == 0:
                break
        assert collected >= 1
        assert db.stats.snapshot()  # engine still healthy
        for i in range(40):
            assert db.get(f"g{i:03d}".encode()) == b"B" * 2048
    finally:
        db.close()


def test_sliced_gc_never_resurrects_concurrent_overwrite(tmp_db_dir):
    db = _db(tmp_db_dir, value_threshold=512, bvalue_max_file_bytes=16 << 10)
    try:
        for i in range(40):
            db.put(f"g{i:03d}".encode(), b"A" * 2048)
        for i in range(40):
            if i != 7:
                db.put(f"g{i:03d}".encode(), b"B" * 2048)
        db.flush()
        db.compact_all()
        # g007 still points at its old "A" value, so a slice will try to
        # rewrite it. Interleave a foreground overwrite between the slice's
        # value read and its conditional re-insert: the precondition must
        # drop the stale rewrite — across EVERY slice, not just one pass.
        real_get = db.bvalue.get
        raced = {"done": False}

        def racing_get(voff, **kw):
            v = real_get(voff, **kw)
            if v == b"A" * 2048 and not raced["done"]:
                raced["done"] = True
                db.put(b"g007", b"C" * 2048)
            return v

        db.bvalue.get = racing_get
        try:
            for _ in range(64):
                res = db.bg.run_gc(0.0, max_rewrite_bytes=4096)
                if not res["sliced"] and res["collected_files"] == 0:
                    break
        finally:
            db.bvalue.get = real_get
        assert raced["done"]
        assert db.get(b"g007") == b"C" * 2048
        for i in range(40):
            if i != 7:
                assert db.get(f"g{i:03d}".encode()) == b"B" * 2048
    finally:
        db.close()


def test_auto_gc_slices_still_drain_via_scheduler(tmp_db_dir):
    # tiny slice budget: reclamation must complete through repeated
    # scheduled slices (completion-edge rescheduling), and the slice
    # counter must show the pass actually yielded at least once
    db = _db(
        tmp_db_dir,
        value_threshold=512,
        bvalue_max_file_bytes=16 << 10,
        gc_auto=True,
        gc_dead_ratio_trigger=0.4,
        gc_slice_bytes=4096,
    )
    try:
        import time

        vals = {}
        rng = np.random.default_rng(0)
        for _round in range(3):
            for i in range(120):
                k = f"k{i:04d}".encode()
                v = rng.bytes(2048)
                db.put(k, v)
                vals[k] = v
        db.flush()
        db.compact_all()
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            st = db.stats.snapshot()
            if st["job_gc_count"] >= 2 and st["gc_slices"] >= 1:
                break
            db.wait_idle()
            time.sleep(0.01)
        st = db.stats.snapshot()
        assert st["job_gc_count"] >= 2, st["job_gc_count"]
        assert st["gc_slices"] >= 1, st["gc_slices"]
        for k, v in vals.items():
            assert db.get(k) == v, k
    finally:
        db.close()


# ---------------------------------------------------------------------------
# end-to-end: the policy pays off
# ---------------------------------------------------------------------------
def test_overlap_policy_writes_fewer_compaction_bytes(tmp_db_dir):
    """Same workload, both policies: overlap scoring + trivial moves must
    not write MORE compaction bytes than the fullness baseline (the
    benchmark gates the strict win at larger scale)."""
    import shutil

    written = {}
    for policy, trivial in (("overlap", True), ("fullness", False)):
        path = os.path.join(tmp_db_dir, policy)
        db = _db(
            path, compaction_pick_policy=policy, trivial_move=trivial,
            memtable_size=32 << 10, level1_max_bytes=64 << 10,
        )
        try:
            _fill(db, 2000, value_size=256, seed=3)
            db.flush()
            db.compact_all()
            st = db.stats.snapshot()
            written[policy] = st["compaction_bytes_written"]
            if policy == "overlap":
                assert st["trivial_moves"] >= 1, st
        finally:
            db.close()
            shutil.rmtree(path, ignore_errors=True)
    assert written["overlap"] <= written["fullness"], written
