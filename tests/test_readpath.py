"""Read-path stack (PR 3): SSTable block format v2 (restart points +
intra-block binary search), v1 backward compatibility, the shared block
cache, lazy merged scans, bloom mask probes, and fresh-DB stats ratios."""
import os

import pytest

from repro.core import DB, DBConfig
from repro.core.blockcache import BlockCache
from repro.core.bloom import BloomFilter, _hash2
from repro.core.sstable import (
    _FOOTER_V1,
    _FOOTER_V2,
    _MAGIC_V1,
    FORMAT_VERSION,
    SSTableReader,
    SSTableWriter,
    zstandard,
)

SMALL = dict(
    memtable_size=64 << 10,
    level1_max_bytes=256 << 10,
    value_threshold=512,
    bvcache_bytes=64 << 10,
    l0_compaction_trigger=2,
)


def mk(tmp, **kw):
    return DB(tmp, DBConfig(separation_mode="wal", wal_mode="sync", **{**SMALL, **kw}))


# ---------------------------------------------------------------------------
# block format v2
# ---------------------------------------------------------------------------

ITEMS = [(f"k{i:05d}".encode(), i + 1, 1, bytes([i % 251]) * (i % 97)) for i in range(400)]


def _write_table(path, *, format_version, compression=False, restart_interval=16,
                 block_size=256, items=ITEMS):
    w = SSTableWriter(path, block_size=block_size, compression=compression,
                      format_version=format_version, restart_interval=restart_interval)
    for k, s, t, v in items:
        w.add(k, s, t, v)
    return w.finish(1)


@pytest.mark.parametrize("compression", [False, True])
@pytest.mark.parametrize("restart_interval", [1, 3, 16])
def test_v2_roundtrip(tmp_path, compression, restart_interval):
    path = str(tmp_path / "t.sst")
    meta = _write_table(path, format_version=2, compression=compression,
                        restart_interval=restart_interval)
    assert meta.entries == len(ITEMS)
    r = SSTableReader(path)
    assert r.format_version == 2
    for k, s, t, v in ITEMS:
        assert r.get(k) == (True, s, t, v)
    assert [it for it in r] == [tuple(it) for it in ITEMS]
    assert [k for k, *_ in r.iter_from(b"k00123")] == [k for k, *_ in ITEMS[123:]]
    r.close()


def test_v2_restart_binary_search_positions(tmp_path):
    """Hits on the first/middle/last entry of a block, plus absent keys that
    fall before, between, and after entries (bloom removed so the block
    search itself is exercised)."""
    path = str(tmp_path / "t.sst")
    # huge block_size → ONE block containing every entry
    items = [(f"k{i:05d}".encode(), i + 1, 1, b"v%d" % i) for i in range(0, 100, 2)]
    _write_table(path, format_version=2, block_size=1 << 20, restart_interval=7,
                 items=items)
    r = SSTableReader(path)
    assert len(r.index) == 1
    r.bloom.bits = bytearray(b"\xff" * len(r.bloom.bits))  # force may_contain=True
    first, mid, last = items[0], items[len(items) // 2], items[-1]
    for k, s, t, v in (first, mid, last):
        assert r.get(k) == (True, s, t, v)
    for absent in (b"a", b"k00001", b"k00051", b"zzz"):  # before/interior/after
        assert r.get(absent) == (False, 0, 0, b"")
    r.close()


def test_v1_backward_compat_table(tmp_path):
    """A table written in the pre-PR-3 layout (v1 footer, no restart
    trailer) must read back byte-exact under the new reader."""
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=1, compression=True)
    with open(path, "rb") as f:
        buf = f.read()
    # byte-level guard: the v1 footer is the seed's 40-byte struct
    *_, magic = _FOOTER_V1.unpack(buf[-_FOOTER_V1.size:])
    assert magic == _MAGIC_V1
    assert _FOOTER_V2.size != _FOOTER_V1.size
    r = SSTableReader(path)
    assert r.format_version == 1
    for k, s, t, v in ITEMS[::7]:
        assert r.get(k) == (True, s, t, v)
    assert r.get(b"nope") == (False, 0, 0, b"")
    assert [k for k, *_ in r.iter_from(b"k00150")] == [k for k, *_ in ITEMS[150:]]
    r.close()


def test_unknown_format_version_rejected(tmp_path):
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=2)
    with open(path, "r+b") as f:
        f.seek(-_FOOTER_V2.size, os.SEEK_END)
        footer = bytearray(f.read(_FOOTER_V2.size))
        footer[32:40] = (FORMAT_VERSION + 1).to_bytes(8, "little")  # version field
        f.seek(-_FOOTER_V2.size, os.SEEK_END)
        f.write(bytes(footer))
    with pytest.raises(IOError, match="newer than this build"):
        SSTableReader(path)


def test_v1_db_directory_compat(tmp_db_dir):
    """A DB directory written ENTIRELY by v1-emitting code (the PR-2
    on-disk layout) opens under the new engine and serves gets/scans; new
    writes then land as v2 tables in the same directory."""
    vals = {}
    db = mk(tmp_db_dir, sstable_format_version=1)
    try:
        for i in range(400):
            k = f"k{i:04d}".encode()
            v = bytes([i % 251]) * (64 if i % 3 else 1024)  # mix inline + separated
            db.put(k, v)
            vals[k] = v
        db.delete(b"k0007")
        del vals[b"k0007"]
        db.flush()
        db.compact_all()
    finally:
        db.close()

    db = mk(tmp_db_dir)  # defaults: v2 writer, cache on
    try:
        assert any(
            SSTableReader(os.path.join(tmp_db_dir, f)).format_version == 1
            for f in os.listdir(tmp_db_dir) if f.endswith(".sst")
        )
        for k, v in vals.items():
            assert db.get(k) == v
        assert db.get(b"k0007") is None
        got = list(db.range(b"k0100", limit=20))
        assert [k for k, _ in got] == sorted(k for k in vals if k >= b"k0100")[:20]
        assert [v for _, v in got] == [vals[k] for k, _ in got]
        # mixed-version directory: new flushes are v2, old v1 files still serve
        for i in range(400, 500):
            k = f"k{i:04d}".encode()
            db.put(k, b"new" * 40)
            vals[k] = b"new" * 40
        db.flush()
        for k, v in list(vals.items())[::17]:
            assert db.get(k) == v
    finally:
        db.close()


@pytest.mark.skipif(zstandard is None, reason="zstandard unavailable")
def test_v2_compressed_blocks_actually_compress(tmp_path):
    path = str(tmp_path / "t.sst")
    items = [(f"k{i:05d}".encode(), i + 1, 1, b"a" * 500) for i in range(100)]
    meta_c = _write_table(path, format_version=2, compression=True, items=items,
                          block_size=4096)
    path2 = str(tmp_path / "u.sst")
    meta_u = _write_table(path2, format_version=2, compression=False, items=items,
                          block_size=4096)
    assert meta_c.size < meta_u.size
    r = SSTableReader(path)
    for k, s, t, v in items[::9]:
        assert r.get(k) == (True, s, t, v)
    r.close()


# ---------------------------------------------------------------------------
# block cache
# ---------------------------------------------------------------------------

def test_block_cache_lru_and_stats():
    class FakeBlock:
        def __init__(self, charge):
            self.charge = charge

    c = BlockCache(1000, shards=1)
    for i in range(10):
        c.put((1, i), FakeBlock(300))  # 300B each → at most 3 fit
    st = c.stats()
    assert st["block_cache_bytes"] <= 1000
    assert st["block_cache_evictions"] >= 7
    assert c.get((1, 9)) is not None  # MRU survives
    assert c.get((1, 0)) is None  # LRU evicted
    assert c.stats()["block_cache_hits"] == 1
    c.evict_file(1)
    assert c.stats()["block_cache_bytes"] == 0


def test_block_cache_recharges_materialized_blocks(tmp_path):
    """A cached block that materializes its parsed entries (second hit)
    must re-charge the cache with the larger footprint — the byte budget
    tracks live memory, not just decoded payload bytes."""
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=2)
    cache = BlockCache(1 << 20, shards=1)
    r = SSTableReader(path, 1, cache)
    k = ITEMS[0][0]
    assert r.get(k)[0]
    lazy_bytes = cache.size_bytes
    assert r.get(k)[0]  # second hit -> materialize -> recharge
    assert cache.size_bytes > lazy_bytes
    # accounting stays exact across eviction: drop everything, bytes -> 0
    cache.evict_file(1)
    assert cache.size_bytes == 0
    r.close()


def test_block_cache_peek_no_promote_no_count():
    """Bypass streams (compaction) peek: resident blocks are returned but
    neither promoted to MRU nor counted as hits/misses."""
    class FakeBlock:
        def __init__(self, charge):
            self.charge = charge

    c = BlockCache(1000, shards=1)
    c.put((1, 0), FakeBlock(300))
    c.put((1, 1), FakeBlock(300))
    c.put((1, 2), FakeBlock(300))
    assert c.peek((1, 0)) is not None  # LRU-most; peek must NOT promote it
    assert c.peek((9, 9)) is None
    st = c.stats()
    assert st["block_cache_hits"] == 0 and st["block_cache_misses"] == 0
    c.put((1, 3), FakeBlock(300))  # forces one eviction
    assert c.peek((1, 0)) is None  # un-promoted LRU victim was evicted
    assert c.peek((1, 1)) is not None


def test_block_cache_disabled_is_noop():
    class FakeBlock:
        charge = 100

    c = BlockCache(0, shards=4)
    c.put((1, 1), FakeBlock())
    assert c.get((1, 1)) is None
    assert c.stats()["block_cache_hit_rate"] == 0.0


def test_warm_gets_do_zero_preads(tmp_db_dir, monkeypatch):
    """Once the working set is cached, repeated point gets must not touch
    the disk at all: count os.pread calls issued by the sstable module."""
    db = mk(tmp_db_dir, block_cache_bytes=8 << 20)
    try:
        keys = []
        for i in range(300):
            k = f"k{i:04d}".encode()
            db.put(k, b"x" * 64)
            keys.append(k)
        db.flush()
        db.compact_all()
        for k in keys:  # warm-up: every touched block lands in the cache
            assert db.get(k) is not None

        import repro.core.sstable as sstable_mod

        calls = []
        real_pread = os.pread
        monkeypatch.setattr(
            sstable_mod.os, "pread",
            lambda *a, **kw: (calls.append(a), real_pread(*a, **kw))[1],
        )
        for k in keys:
            assert db.get(k) is not None
        assert calls == []
        assert db.stats.snapshot()["block_cache_hit_rate"] > 0.5
    finally:
        db.close()


def test_cache_disabled_preads_every_get(tmp_db_dir, monkeypatch):
    db = mk(tmp_db_dir, block_cache_bytes=0)
    try:
        assert db.block_cache is None
        keys = []
        for i in range(300):
            k = f"k{i:04d}".encode()
            db.put(k, b"x" * 64)
            keys.append(k)
        db.flush()
        db.compact_all()
        for k in keys:
            assert db.get(k) is not None

        import repro.core.sstable as sstable_mod

        calls = []
        real_pread = os.pread
        monkeypatch.setattr(
            sstable_mod.os, "pread",
            lambda *a, **kw: (calls.append(a), real_pread(*a, **kw))[1],
        )
        for k in keys[:50]:
            assert db.get(k) is not None
        assert len(calls) >= 50
    finally:
        db.close()


def test_scan_correct_with_and_without_cache(tmp_db_dir):
    for cache_bytes in (8 << 20, 0):
        path = os.path.join(tmp_db_dir, f"c{cache_bytes}")
        db = mk(path, block_cache_bytes=cache_bytes)
        try:
            expect = {}
            for i in range(500):
                k = f"k{i:04d}".encode()
                v = bytes([i % 251]) * 80
                db.put(k, v)
                expect[k] = v
            db.flush()
            db.compact_all()
            got = list(db.range(b"k0100", limit=50))
            want = sorted(k for k in expect if k >= b"k0100")[:50]
            assert [k for k, _ in got] == want
            assert all(v == expect[k] for k, v in got)
            # re-scan hits the now-cached blocks and must agree
            assert list(db.range(b"k0100", limit=50)) == got
        finally:
            db.close()


def test_lazy_scan_opens_few_files(tmp_db_dir, monkeypatch):
    """A short scan must open O(levels + L0) per-file iterators, not one
    per live file: the L1+ concatenating iterator defers files until the
    merge cursor reaches them. Compaction rolls output at >= 4 MiB, so a
    many-files-per-level LSM is hand-built through the manifest here."""
    from repro.core.record import kTypeValue
    from repro.core.sstable import table_path

    db = mk(tmp_db_dir)
    try:
        def add_file(level, lo, hi, seq, val):
            fno = db.versions.new_file_no()
            w = SSTableWriter(table_path(db.path, fno), block_size=512)
            for i in range(lo, hi):
                w.add(f"k{i:05d}".encode(), seq, kTypeValue, val)
            meta = w.finish(fno)
            db.versions.log_and_apply(
                {"add": [(level, meta.to_wire())], "last_seq": seq}
            )

        for j in range(8):  # 8 disjoint L1 files, 100 keys each
            add_file(1, j * 100, (j + 1) * 100, seq=100, val=b"new")
        for j in range(4):  # 4 wider, older L2 files underneath
            add_file(2, j * 200, (j + 1) * 200, seq=1, val=b"old")
        # hand-built files bypassed the write path, so mirror what recovery
        # does with the manifest's last_seq: scan cursors pin visibility at
        # the engine's current sequence, and entries "from the future"
        # would (correctly) be invisible
        db._seq = db.versions.last_seq
        version = db.versions.current
        total_files = sum(len(lv) for lv in version.levels)
        assert total_files == 12 and not version.levels[0]

        opened = []
        real = SSTableReader.iter_from

        def counting_iter_from(self, start, *a, **kw):
            opened.append(self.file_no)
            return real(self, start, *a, **kw)

        monkeypatch.setattr(SSTableReader, "iter_from", counting_iter_from)
        out = list(db.range(b"k00250", limit=10))
        assert [k for k, _ in out] == [f"k{i:05d}".encode() for i in range(250, 260)]
        assert all(v == b"new" for _, v in out)  # L1 shadows L2
        # one file per populated level (L1 + L2), +2 slack for a concat
        # iterator stepping into its next file — far below all 12 files
        assert len(opened) <= 4 < total_files
    finally:
        db.close()


# ---------------------------------------------------------------------------
# bloom filter (pow2 mask probes + legacy compat)
# ---------------------------------------------------------------------------

def test_bloom_pow2_mask():
    keys = [f"key{i}".encode() for i in range(500)]
    bf = BloomFilter.build(keys)
    assert bf.nbits & (bf.nbits - 1) == 0  # power of two
    assert bf._mask == bf.nbits - 1
    assert all(bf.may_contain(k) for k in keys)
    fp = sum(bf.may_contain(f"other{i}".encode()) for i in range(1000))
    assert fp < 50
    bf2 = BloomFilter.decode(bf.encode())
    assert bf2._mask == bf.nbits - 1
    assert all(bf2.may_contain(k) for k in keys)


def test_bloom_legacy_non_pow2_decodes():
    """Filters serialized by the pre-PR-3 builder used nbits = n*10 (not a
    power of two); the self-describing header must keep them readable, with
    probes falling back to `%`."""
    keys = [f"key{i}".encode() for i in range(100)]
    nbits = 10 * len(keys)  # 1000 — not a power of two
    k = 6
    bits = bytearray((nbits + 7) // 8)
    for key in keys:  # replicate the seed's build loop
        h1, h2 = _hash2(key)
        for i in range(k):
            b = (h1 + i * h2) % nbits
            bits[b >> 3] |= 1 << (b & 7)
    legacy = BloomFilter(k, nbits, bits)
    assert legacy._mask is None
    decoded = BloomFilter.decode(legacy.encode())
    assert decoded.nbits == nbits and decoded._mask is None
    assert all(decoded.may_contain(key) for key in keys)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

def test_fresh_db_stats_ratios_are_zero(tmp_db_dir):
    """A fresh DB with zero reads/writes must report every derived ratio as
    0.0 (never ZeroDivisionError) and carry the block-cache counters."""
    db = mk(tmp_db_dir)
    try:
        st = db.stats.snapshot()
        assert st["fsyncs_per_write"] == 0.0
        assert st["avg_group_size"] == 0.0
        assert st["write_amp"] == 0.0
        assert st["block_cache_hit_rate"] == 0.0
        for key in ("block_cache_hits", "block_cache_misses",
                    "block_cache_evictions", "block_cache_bytes",
                    "block_cache_entries"):
            assert st[key] == 0
        assert db.stats.fsyncs_per_write == 0.0
        assert db.stats.avg_group_size == 0.0
        assert db.stats.block_cache_hit_rate == 0.0
    finally:
        db.close()


def test_stats_count_cache_traffic(tmp_db_dir):
    db = mk(tmp_db_dir)
    try:
        for i in range(300):
            db.put(f"k{i:04d}".encode(), b"z" * 64)
        db.flush()
        db.compact_all()
        for _ in range(3):
            for i in range(0, 300, 10):
                db.get(f"k{i:04d}".encode())
        st = db.stats.snapshot()
        assert st["block_cache_misses"] > 0
        assert st["block_cache_hits"] > st["block_cache_misses"]
        assert 0.0 < st["block_cache_hit_rate"] <= 1.0
    finally:
        db.close()
