"""Optimizers, schedules, clipping, and gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.training.compression import (
    compressed_psum,
    dequantize_int8,
    ef_compress_leaf,
    init_error_state,
    quantize_int8,
)
from repro.training.optimizer import (
    OptimizerConfig,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
    opt_init,
    opt_update,
)


def test_lr_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=100, total_steps=1000, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(50))) - 5e-4) < 1e-9
    assert abs(float(lr_schedule(cfg, jnp.asarray(100))) - 1e-3) < 1e-6
    end = float(lr_schedule(cfg, jnp.asarray(1000)))
    assert abs(end - 1e-4) < 1e-6  # min_lr_ratio * lr


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    # under the limit: unchanged
    g2 = {"a": jnp.ones((4,)) * 0.01}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01, rtol=1e-6)


def test_adamw_matches_reference_math():
    cfg = OptimizerConfig(name="adamw", lr=0.1, warmup_steps=0, total_steps=10**9,
                          min_lr_ratio=1.0, b1=0.9, b2=0.99, weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, 2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    st_ = opt_init(cfg, p)
    new_p, st2, lr = opt_update(cfg, g, st_, p)
    # step 1: mhat = g, vhat = g², update = g/(|g|+eps) = sign(g)
    expect = np.asarray([[1.0, 2.0]]) - 0.1 * np.sign([[0.5, -0.5]])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, atol=1e-4)


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=10**9, min_lr_ratio=1.0,
                          weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st_ = opt_init(cfg, p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        p, st_, _ = opt_update(cfg, g, st_, p)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.1


def test_adafactor_shapes_and_convergence():
    cfg = OptimizerConfig(name="adafactor", lr=0.05, warmup_steps=0, total_steps=10**9,
                          min_lr_ratio=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((256, 256)) * 2.0, "b": jnp.asarray([1.0])}
    st_ = opt_init(cfg, p)
    assert st_["f"]["w"]["vr"].shape == (256,)
    assert st_["f"]["w"]["vc"].shape == (256,)
    assert st_["f"]["b"]["v"].shape == (1,)
    for _ in range(100):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, st_, _ = opt_update(cfg, g, st_, p)
    assert float(jnp.mean(jnp.abs(p["w"]))) < 1.0


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2000), st.floats(0.01, 100.0))
def test_quantize_roundtrip_bound(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(0, scale, size=(n,)), jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    # per-block max error ≤ scale/2 where scale = blockmax/127
    err = np.abs(np.asarray(back - x))
    blockmax = float(jnp.max(jnp.abs(x)))
    assert err.max() <= blockmax / 127.0 * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """EF: accumulated transmitted signal ≈ accumulated true gradient."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(512,)), jnp.float32) * 0.01
    err = jnp.zeros_like(g_true)
    sent = jnp.zeros_like(g_true)
    for _ in range(50):
        q, s, err = ef_compress_leaf(g_true, err)
        sent = sent + dequantize_int8(q, s, g_true.shape)
    bias = float(jnp.max(jnp.abs(sent / 50 - g_true)))
    naive_q, naive_s = quantize_int8(g_true)
    naive_bias = float(jnp.max(jnp.abs(dequantize_int8(naive_q, naive_s, g_true.shape) - g_true)))
    assert bias < naive_bias * 0.2 + 1e-7  # EF beats plain quantization


def test_compressed_psum_single_axis():
    """shard_map with axis size 1: compressed psum == identity(+quant noise),
    error feedback captures exactly the residual."""
    from jax.sharding import PartitionSpec as P

    from repro.dist import shard_map
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1), axes=("pod", "model"))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(64,)), jnp.float32)}
    e = init_error_state(g)

    def f(g, e):
        return compressed_psum(g, e, "pod")

    out, new_e = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False
    )(g, e)
    np.testing.assert_allclose(
        np.asarray(out["w"] + 0 * new_e["w"]),
        np.asarray(g["w"] - new_e["w"]),
        atol=1e-6,
    )
