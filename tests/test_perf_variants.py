"""§Perf variants must be mathematically equivalent to the baseline paths.

Multi-device equivalence (sharded decode, MoE local dispatch) runs in a
subprocess with 8 host devices — the same code path as the 512-device
dry-run variants.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.perf import PerfConfig, perf_context
from repro.models.attention import full_attention


def test_causal_chunk_growth_matches_baseline():
    rng = np.random.default_rng(0)
    B, T, H, K, hd = 1, 512, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, K, hd)), jnp.float32)
    base = full_attention(q, k, v, causal=True, q_chunk=128)
    with perf_context(PerfConfig(causal_chunk_growth=True)):
        opt = full_attention(q, k, v, causal=True, q_chunk=128)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), atol=2e-5)
    # windowed variant too
    base_w = full_attention(q, k, v, causal=True, window=100, q_chunk=128)
    with perf_context(PerfConfig(causal_chunk_growth=True)):
        opt_w = full_attention(q, k, v, causal=True, window=100, q_chunk=128)
    np.testing.assert_allclose(np.asarray(opt_w), np.asarray(base_w), atol=2e-5)


SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    import numpy as np
    from repro.configs import get_config
    from repro.dist import mesh_context
    from repro.dist.perf import PerfConfig, perf_context
    from repro.models import build_model

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((2, 4))
    out = {}

    # ---- sharded decode equivalence (kv_seq over model) ----
    cfg = get_config("llama3-8b").reduced(d_model=64, n_layers=2, n_heads=8,
                                          n_kv_heads=4, head_dim=8, d_ff=128,
                                          vocab=256, vocab_pad_multiple=64,
                                          dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
    with mesh_context(mesh):
        logits_p, cache = jax.jit(lambda p, t: model.prefill(p, t, pad_to=32))(params, tokens)
        base, _ = jax.jit(lambda p, c, t: model.decode_step(p, c, t))(params, cache, tokens[:, :1])
    with perf_context(PerfConfig(sharded_decode_attn=True)), mesh_context(mesh):
        logits_p2, cache2 = jax.jit(lambda p, t: model.prefill(p, t, pad_to=32))(params, tokens)
        opt, _ = jax.jit(lambda p, c, t: model.decode_step(p, c, t))(params, cache2, tokens[:, :1])
    out["decode_diff"] = float(jnp.max(jnp.abs(base - opt)))

    # ---- MoE local dispatch: loss finite and close to global dispatch ----
    cfg = get_config("qwen2-moe-a2.7b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    with mesh_context(mesh):
        base_loss, _ = jax.jit(lambda p, b: model.loss(p, b, remat=False))(params, batch)
    with perf_context(PerfConfig(moe_local_dispatch=True)), mesh_context(mesh):
        opt_loss, _ = jax.jit(lambda p, b: model.loss(p, b, remat=False))(params, batch)
        # grads must flow through the shard_map dispatch
        g = jax.jit(jax.grad(lambda p, b: model.loss(p, b, remat=False)[0]))(params, batch)
        gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    out["moe_base_loss"] = float(base_loss)
    out["moe_opt_loss"] = float(opt_loss)
    out["moe_gnorm"] = gnorm
    print(json.dumps(out))
    """
)


def test_variants_equivalent_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # sharded flash-decode matches dense decode up to bf16-cache rounding
    # (the cache itself is bf16; combine/accumulation are fp32)
    assert out["decode_diff"] < 5e-3, out
    # local-dispatch MoE differs only via per-shard capacity truncation:
    # C = int(N*k/E * factor) + 1 over N/2 local tokens drops a different
    # token set than the global dispatch, and this test batch is tiny
    # (128 tokens), so the loss gap is visible but bounded
    assert abs(out["moe_base_loss"] - out["moe_opt_loss"]) < 0.15, out
    assert np.isfinite(out["moe_gnorm"]) and out["moe_gnorm"] > 0, out
