"""Fault-injection + crash-recovery matrix (PR 6).

Covers the failure subsystem end to end: the pluggable Env and its fault
rules, torn-WAL-tail truncation, the keep-logs-until-flush durability fix,
severity-classified background retries, read-only degradation + resume,
CRC corruption detection + file quarantine, the integrity scrub, and a
drop-unsynced crash matrix over every pipeline edge × {sync, async} WAL.
"""
from __future__ import annotations

import errno
import os
import struct
import time
import zlib

import pytest

from repro.core import (
    DB,
    DBConfig,
    CorruptionError,
    DBReadOnlyError,
    FaultInjectionEnv,
    SnapshotUnstableError,
)
from repro.core.record import WAL_HEADER_SIZE
from repro.core.scheduler import JobScheduler
from repro.testing.crash_harness import run_crash_loop, run_iteration


def _cfg(env=None, wal_mode="sync", **kw):
    cfg = DBConfig.bvlsm(
        wal_mode=wal_mode,
        value_threshold=kw.pop("value_threshold", 64),
        memtable_size=kw.pop("memtable_size", 8192),
        num_bvalue_queues=2,
        **kw,
    )
    cfg.env = env
    cfg.bg_error_backoff_ms = 1.0
    return cfg


def _fill(db, n, prefix="k", size=60):
    data = {}
    for i in range(n):
        k = f"{prefix}{i:05d}".encode()
        v = (f"v{i}_".encode() * 32)[:size]
        db.put(k, v)
        data[k] = v
    return data


def _wait_latched(db, timeout=5.0):
    deadline = time.monotonic() + timeout
    while db.errors.error is None and time.monotonic() < deadline:
        time.sleep(0.01)
    return db.errors.error is not None


# ---------------------------------------------------------------------------
# Env unit tests
# ---------------------------------------------------------------------------
class TestFaultInjectionEnv:
    def test_counted_fault_fires_then_clears(self, tmp_path):
        env = FaultInjectionEnv()
        p = str(tmp_path / "f.bin")
        env.add_fault(op="write", path_substr="f.bin", count=1, error=errno.EIO)
        f = env.open(p, "wb")
        with pytest.raises(OSError) as ei:
            f.write(b"x")
        assert ei.value.errno == errno.EIO
        f.write(b"after")  # count exhausted: next write succeeds
        f.close()

    def test_probability_zero_never_fires(self, tmp_path):
        env = FaultInjectionEnv()
        env.add_fault(op="write", count=100, probability=0.0)
        with env.open(str(tmp_path / "p.bin"), "wb") as f:
            for _ in range(50):
                f.write(b"y")

    def test_drop_unsynced_rewinds_to_fsync_point(self, tmp_path):
        env = FaultInjectionEnv()
        p = str(tmp_path / "d.bin")
        f = env.open(p, "wb")
        f.write(b"durable")
        env.fsync(f)
        f.write(b"-volatile")
        f.close()
        env.drop_unsynced()
        with open(p, "rb") as f:
            assert f.read() == b"durable"

    def test_drop_unsynced_undoes_overwrites_of_synced_bytes(self, tmp_path):
        env = FaultInjectionEnv()
        p = str(tmp_path / "u.bin")
        fd = env.open_fd(p, os.O_RDWR | os.O_CREAT)
        env.pwrite(fd, b"AAAA", 0)
        os.fsync(fd)
        env._note_sync(p)
        env.pwrite(fd, b"BB", 1)  # overwrite inside the synced prefix
        env.close_fd(fd)
        env.drop_unsynced()
        with open(p, "rb") as f:
            assert f.read() == b"AAAA"

    def test_crash_point_blocks_mutations_not_reads(self, tmp_path):
        env = FaultInjectionEnv()
        p = str(tmp_path / "c.bin")
        with env.open(p, "wb") as f:
            f.write(b"z")
        env.set_crash_after(0)
        with pytest.raises(OSError):
            env.open(p, "wb")
        with env.open(p, "rb") as f:  # reads survive the "crash"
            assert f.read() == b"z"
        env.disarm_crash()
        with env.open(p, "ab") as f:
            f.write(b"more")

    def test_corrupt_flips_bytes(self, tmp_path):
        env = FaultInjectionEnv()
        p = str(tmp_path / "x.bin")
        with open(p, "wb") as f:
            f.write(b"\x00" * 8)
        env.corrupt(p, 3, 2)
        with open(p, "rb") as f:
            assert f.read() == b"\x00\x00\x00\xff\xff\x00\x00\x00"


# ---------------------------------------------------------------------------
# WAL torn tail + recovery-log lifetime
# ---------------------------------------------------------------------------
class TestWALRecovery:
    def test_torn_tail_truncated_and_counted(self, tmp_db_dir):
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        data = _fill(db, 20)
        db.close(crash=True)
        wals = [f for f in os.listdir(tmp_db_dir) if f.startswith("wal_")]
        assert wals
        path = os.path.join(tmp_db_dir, wals[0])
        good = os.path.getsize(path)
        with open(path, "ab") as f:  # simulate a torn half-written frame
            f.write(struct.pack("<II", 9999, zlib.crc32(b"junk")) + b"ju")
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        assert db.stats.snapshot()["wal_truncated_bytes"] == WAL_HEADER_SIZE + 2
        assert os.path.getsize(path) == good  # file physically truncated
        for k, v in data.items():
            assert db.get(k) == v
        db.close()

    def test_crc_mismatch_tail_truncated(self, tmp_db_dir):
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        data = _fill(db, 10)
        db.close(crash=True)
        wals = [f for f in os.listdir(tmp_db_dir) if f.startswith("wal_")]
        path = os.path.join(tmp_db_dir, wals[0])
        payload = b"garbage-payload"
        with open(path, "ab") as f:  # framed but wrong CRC
            f.write(struct.pack("<II", len(payload), 0xDEADBEEF) + payload)
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        assert db.stats.snapshot()["wal_truncated_bytes"] > 0
        for k, v in data.items():
            assert db.get(k) == v
        db.close()

    def test_second_crash_before_flush_keeps_data(self, tmp_db_dir, monkeypatch):
        """Regression for the recovery durability hole: replayed WAL logs
        must survive until the recovered memtable is flushed — a second
        crash right after reopen used to lose every acked write."""
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        data = _fill(db, 30)
        db.close(crash=True)
        # reopen with background flushes disabled: recovery replays the
        # logs but nothing ever flushes them to L0
        monkeypatch.setattr(JobScheduler, "submit", lambda *a, **k: False)
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        for k, v in data.items():
            assert db.get(k) == v
        assert any(f.startswith("wal_") for f in os.listdir(tmp_db_dir)), (
            "recovery deleted the WAL logs before the data was flushed"
        )
        db.close(crash=True)  # second crash: nothing was flushed
        monkeypatch.undo()
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        for k, v in data.items():
            assert db.get(k) == v, "second crash lost acked writes"
        db.close()

    def test_recovery_logs_deleted_after_flush(self, tmp_db_dir):
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        data = _fill(db, 30)
        db.close(crash=True)
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        db.flush()
        db.wait_idle()
        leftovers = [
            f
            for f in os.listdir(tmp_db_dir)
            if f.startswith("wal_") and os.path.getsize(os.path.join(tmp_db_dir, f))
        ]
        assert leftovers == [], f"replayed logs not cleaned up: {leftovers}"
        for k, v in data.items():
            assert db.get(k) == v
        db.close()


# ---------------------------------------------------------------------------
# transient vs hard background errors
# ---------------------------------------------------------------------------
class TestErrorClassification:
    def test_transient_flush_error_is_retried(self, tmp_db_dir):
        env = FaultInjectionEnv()
        db = DB(tmp_db_dir, _cfg(env, memtable_size=4096))
        env.add_fault(op="write", path_substr=".sst", count=1, error=errno.EIO)
        data = _fill(db, 200)
        db.flush()
        db.wait_idle()
        s = db.stats.snapshot()
        assert s["bg_retries"] >= 1
        assert db.errors.error is None, "one transient error bricked the DB"
        for k, v in list(data.items())[:20]:
            assert db.get(k) == v
        db.close()

    def test_hard_enospc_goes_read_only_and_resumes(self, tmp_db_dir):
        env = FaultInjectionEnv()
        db = DB(tmp_db_dir, _cfg(env, memtable_size=4096))
        data = _fill(db, 60)
        env.add_fault(op="write", path_substr=".sst", count=10_000, error=errno.ENOSPC)
        with pytest.raises(RuntimeError):
            for i in range(2000):
                db.put(f"fill{i:05d}".encode(), b"x" * 60)
                if db.errors.error is not None and i % 10 == 0:
                    db.flush()  # surface the latch if puts keep landing
        assert _wait_latched(db)
        assert db.errors.read_only
        with pytest.raises(DBReadOnlyError):
            db.put(b"nope", b"nope")
        for k, v in list(data.items())[:10]:  # reads still serve
            assert db.get(k) == v
        env.clear_faults()
        db.resume()
        assert not db.errors.read_only
        db.put(b"recovered", b"yes")
        db.flush()
        db.wait_idle()
        assert db.get(b"recovered") == b"yes"
        assert db.stats.snapshot()["resumes"] == 1
        db.close()

    def test_resume_refuses_while_cause_persists(self, tmp_db_dir):
        env = FaultInjectionEnv()
        db = DB(tmp_db_dir, _cfg(env, memtable_size=4096))
        env.add_fault(op="write", path_substr=".sst", count=10_000, error=errno.ENOSPC)
        try:
            for i in range(2000):
                db.put(f"f{i:05d}".encode(), b"y" * 60)
        except RuntimeError:
            pass
        assert _wait_latched(db)
        # the "disk" is still full: the resume probe itself must fail
        env.add_fault(op="sync", path_substr="RESUME_PROBE", count=1,
                      error=errno.ENOSPC)
        with pytest.raises(OSError):
            db.resume()
        assert db.errors.read_only
        env.clear_faults()
        db.resume()
        assert not db.errors.read_only
        db.close()

    def test_scan_snapshot_error_is_typed(self, tmp_db_dir, monkeypatch):
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        _fill(db, 10)
        db.flush()
        db.wait_idle()

        calls = {"n": 0}
        real = DB._scan_attempts

        def flaky(self, start, count):
            calls["n"] += 1
            return None  # every attempt lands on a "torn" snapshot

        monkeypatch.setattr(DB, "_scan_attempts", flaky)
        with pytest.raises(SnapshotUnstableError):
            with pytest.warns(DeprecationWarning):
                db.scan(b"", 10)
        assert calls["n"] == 2, "expected one bounded backoff round"
        monkeypatch.setattr(DB, "_scan_attempts", real)
        # the deprecated shim still returns the same rows range() streams
        with pytest.warns(DeprecationWarning):
            legacy = db.scan(b"", 10)
        assert legacy == list(db.range(limit=10)) and len(legacy) == 10
        db.close()


# ---------------------------------------------------------------------------
# corruption detection + quarantine
# ---------------------------------------------------------------------------
class TestCorruption:
    def _mk_corrupt_sst(self, tmp_db_dir):
        env = FaultInjectionEnv()
        db = DB(tmp_db_dir, _cfg(env, memtable_size=1 << 20, value_threshold=1 << 20))
        data = _fill(db, 100)
        db.flush()
        db.wait_idle()
        fno = db.versions.current.levels[0][0].file_no
        db.close()
        env.corrupt(os.path.join(tmp_db_dir, f"{fno:06d}.sst"), 30, 4)
        return data, fno, env

    def test_paranoid_get_raises_and_quarantines(self, tmp_db_dir):
        data, fno, env = self._mk_corrupt_sst(tmp_db_dir)
        cfg = _cfg(env, memtable_size=1 << 20, value_threshold=1 << 20)
        cfg.paranoid_checks = True
        db = DB(tmp_db_dir, cfg)
        with pytest.raises(IOError):  # CorruptionError is an IOError
            for k in data:
                db.get(k)
        assert fno in db.versions.quarantined_files()
        s = db.stats.snapshot()
        assert s["corruptions_detected"] == 1 and s["files_quarantined"] == 1
        db.close()

    def test_quarantined_file_excluded_from_compaction(self, tmp_db_dir):
        data, fno, env = self._mk_corrupt_sst(tmp_db_dir)
        cfg = _cfg(env, memtable_size=1 << 20, value_threshold=1 << 20)
        cfg.paranoid_checks = True
        db = DB(tmp_db_dir, cfg)
        try:
            for k in data:
                db.get(k)
        except IOError:
            pass
        assert fno in db.versions.quarantined_files()
        picked = db.bg.compactor.pick(
            db.versions.locked_files() | db.versions.quarantined_files()
        )
        if picked is not None:
            _level, inputs, overlaps = picked
            assert fno not in {f.file_no for f in inputs + overlaps}
        db.close()
        # quarantine survives reopen (manifest-logged)
        db = DB(tmp_db_dir, _cfg(env, memtable_size=1 << 20, value_threshold=1 << 20))
        assert fno in db.versions.quarantined_files()
        db.close()

    def test_scrub_finds_and_quarantines_block_rot(self, tmp_db_dir):
        data, fno, env = self._mk_corrupt_sst(tmp_db_dir)
        db = DB(tmp_db_dir, _cfg(env, memtable_size=1 << 20, value_threshold=1 << 20))
        rep = db.verify_integrity()
        assert rep["corruptions"], "scrub missed a flipped block"
        assert fno in db.versions.quarantined_files()
        db.close()

    def test_bvalue_corruption_quarantines_value_file(self, tmp_db_dir):
        env = FaultInjectionEnv()
        cfg = _cfg(env, memtable_size=1 << 20)
        cfg.paranoid_checks = True
        db = DB(tmp_db_dir, cfg)
        big = b"B" * 300  # over value_threshold=64: separated
        db.put(b"bigkey", big)
        db.flush()
        db.wait_idle()
        vfile = os.path.join(tmp_db_dir, "bvalue", "bv_000000.val")
        env.corrupt(vfile, 10, 3)
        db.bvcache.clear() if hasattr(db.bvcache, "clear") else None
        db.close()
        db = DB(tmp_db_dir, cfg)
        with pytest.raises(IOError):
            db.get(b"bigkey")
        assert 0 in db.versions.quarantined_bvalues
        # GC must never rewrite through (or unlink) the quarantined file
        db.put(b"bigkey", b"C" * 300)  # kill the old value
        res = db.gc_collect(threshold=0.0)
        assert os.path.exists(vfile), "GC removed a quarantined value file"
        assert res is not None
        db.close()

    def test_scrub_clean_db_reports_no_corruption(self, tmp_db_dir):
        db = DB(tmp_db_dir, _cfg(memtable_size=1 << 20))
        _fill(db, 80, size=120)  # over the threshold: separated values
        db.flush()
        db.wait_idle()
        rep = db.verify_integrity()
        assert rep["corruptions"] == []
        assert rep["blocks_verified"] > 0 and rep["values_verified"] > 0
        db.close()


# ---------------------------------------------------------------------------
# drop-unsynced crash matrix: every pipeline edge × {sync, async} WAL
# ---------------------------------------------------------------------------
EDGES = [
    ("wal-write", ("write",), "wal_"),
    ("wal-sync", ("sync",), "wal_"),
    ("value-queue", ("write",), "bvalue"),
    ("flush-sst", ("write",), ".sst"),
    ("manifest", ("write",), "MANIFEST"),
    ("unlink", ("unlink",), None),
]


@pytest.mark.parametrize("wal_mode", ["sync", "async"])
@pytest.mark.parametrize("edge", EDGES, ids=[e[0] for e in EDGES])
def test_crash_matrix(tmp_db_dir, wal_mode, edge):
    """Kill the DB (drop-unsynced semantics) at one pipeline edge; reopen;
    sync-acked writes must read back exactly, async state must be a legal
    per-key prefix, and the reopened DB must be writable."""
    _name, ops, substr = edge
    env = FaultInjectionEnv(seed=7)
    db = DB(tmp_db_dir, _cfg(env, wal_mode=wal_mode, memtable_size=4096))
    acked: dict[bytes, bytes | None] = {}
    history: dict[bytes, set] = {}
    env.set_crash_after(60, ops=ops, path_substr=substr)
    for i in range(600):
        k = f"m{i % 25:03d}".encode()
        v = (f"val{i}_".encode() * 20)[: 30 if i % 3 else 200]
        try:
            if i % 11 == 10:
                db.delete(k)
                acked[k] = None
                history.setdefault(k, {None}).add(None)
            else:
                db.put(k, v)
                acked[k] = v
                history.setdefault(k, {None}).add(v)
        except Exception:
            break
    try:
        db.close(crash=True)
    except Exception:
        pass
    env.drop_unsynced()
    env.disarm_crash()
    env.clear_faults()
    env.reset_tracking()
    db = DB(tmp_db_dir, _cfg(env, wal_mode=wal_mode, memtable_size=4096))
    for k, want in acked.items():
        got = db.get(k)
        if wal_mode == "sync":
            assert got == want, f"lost acked sync write {k!r}"
        else:
            assert got in history[k], f"resurrected/garbage value for {k!r}"
    db.put(b"probe", b"alive")
    assert db.get(b"probe") == b"alive"
    db.close()


def test_crash_loop_smoke():
    """A slice of the randomized crash loop runs in tier-1 every time; CI's
    fault shard and the acceptance run turn the count up via env var."""
    iters = int(os.environ.get("CRASH_LOOP_ITERS", "6"))
    rep = run_crash_loop(iters=iters, seed=42)
    assert rep["failures"] == [], rep["failures"]


def test_crash_iteration_is_deterministic(tmp_path):
    a = run_iteration(123, "sync", str(tmp_path / "a"))
    b = run_iteration(123, "sync", str(tmp_path / "b"))
    assert (a["acked"], a["violations"]) == (b["acked"], b["violations"])


# ---------------------------------------------------------------------------
# PR 7 edges: range-tombstone WAL replay and checkpoint commit ordering
# ---------------------------------------------------------------------------

def _kill_and_reopen(db, env, tmp, **cfg_kw):
    try:
        db.close(crash=True)
    except Exception:
        pass
    env.drop_unsynced()
    env.disarm_crash()
    env.clear_faults()
    env.reset_tracking()
    return DB(tmp, _cfg(env, **cfg_kw))


def test_acked_range_delete_survives_crash(tmp_db_dir):
    """Sync WAL: a delete_range that returned must replay from the WAL —
    covered keys stay deleted after the crash, the boundary key survives."""
    env = FaultInjectionEnv(seed=5)
    db = DB(tmp_db_dir, _cfg(env))
    for k in (b"a", b"b", b"c", b"d"):
        db.put(k, b"v_" + k)
    db.delete_range(b"a", b"c")  # acked; never flushed
    db = _kill_and_reopen(db, env, tmp_db_dir)
    try:
        assert db.get(b"a") is None
        assert db.get(b"b") is None
        assert db.get(b"c") == b"v_c"
        assert db.get(b"d") == b"v_d"
    finally:
        db.close()


def test_crash_during_range_delete_wal_append_loses_only_that_op(tmp_db_dir):
    """Kill exactly at the range tombstone's WAL append: the op never acked,
    so after recovery the covered keys are still present and intact."""
    env = FaultInjectionEnv(seed=5)
    db = DB(tmp_db_dir, _cfg(env))
    for k in (b"a", b"b", b"c"):
        db.put(k, b"v_" + k)
    db.flush()
    env.set_crash_after(0, ops=("write",), path_substr="wal_")
    with pytest.raises(Exception):
        db.delete_range(b"a", b"c")
    db = _kill_and_reopen(db, env, tmp_db_dir)
    try:
        for k in (b"a", b"b", b"c"):
            assert db.get(k) == b"v_" + k, k
    finally:
        db.close()


def test_checkpoint_crash_before_manifest_rename_leaves_non_db(tmp_path):
    """The MANIFEST rename is the checkpoint's commit marker. A crash after
    the hard-links but before the rename must leave a directory that is
    simply not a DB — and the source DB fully intact."""
    main = str(tmp_path / "db")
    ck = str(tmp_path / "ckdir")
    env = FaultInjectionEnv(seed=5)
    db = DB(main, _cfg(env))
    data = _fill(db, 30, size=120)
    env.set_crash_after(0, ops=("rename",), path_substr="ckdir")
    with pytest.raises(Exception):
        db.checkpoint(ck)
    assert not os.path.exists(os.path.join(ck, "MANIFEST"))
    db = _kill_and_reopen(db, env, main)
    try:
        for k, v in data.items():
            assert db.get(k) == v, k
        # a retried checkpoint to a fresh dir commits cleanly
        ck2 = str(tmp_path / "ck2")
        db.checkpoint(ck2)
        cdb = DB(ck2, _cfg(None))
        assert len(list(cdb.range())) == len(data)
        cdb.close()
    finally:
        db.close()


def test_checkpoint_opens_clean_after_source_crash(tmp_path):
    """A committed checkpoint is an independent durable image: crashing the
    source DB afterwards (dropping all its unsynced state) must not corrupt
    the checkpoint — the hard-linked files share inodes, so the fault model
    has to keep one consistent durable state per inode."""
    main = str(tmp_path / "db")
    ck = str(tmp_path / "ckdir")
    env = FaultInjectionEnv(seed=5)
    db = DB(main, _cfg(env, memtable_size=4096))
    data = _fill(db, 40, size=120)  # separated values: .val files get linked
    db.checkpoint(ck)
    # keep writing so the shared value files' sync state moves on
    for i in range(40):
        db.put(f"post{i:03d}".encode(), b"P" * 120)
    db = _kill_and_reopen(db, env, main, memtable_size=4096)
    db.close()
    cdb = DB(ck, _cfg(None))
    try:
        for k, v in data.items():
            assert cdb.get(k) == v, k
        rep = cdb.verify_integrity()
        assert rep["corruptions"] == [], rep["corruptions"]
    finally:
        cdb.close()


def test_crash_matrix_checkpoint_link_edge(tmp_path):
    """Matrix-style kill at the hard-link fan-out: whatever state the crash
    leaves, the source DB reopens and every committed checkpoint opens."""
    main = str(tmp_path / "db")
    env = FaultInjectionEnv(seed=9)
    db = DB(main, _cfg(env, memtable_size=4096))
    committed = []
    env.set_crash_after(25, ops=("link",))
    for i in range(200):
        try:
            db.put(f"k{i % 20:03d}".encode(), (f"v{i}_".encode() * 20)[:150])
            if i % 30 == 29:
                ck = str(tmp_path / f"ck{i}")
                db.checkpoint(ck)
                committed.append(ck)
        except Exception:
            break
    db = _kill_and_reopen(db, env, main, memtable_size=4096)
    list(db.range())
    db.close()
    for ck in committed:
        cdb = DB(ck, _cfg(None))
        list(cdb.range())
        cdb.close()


# ---------------------------------------------------------------------------
# bounded undo log + reset()  (PR 8)
# ---------------------------------------------------------------------------
class TestUndoLogBound:
    def test_repeated_overwrites_do_not_grow_undo(self, tmp_path):
        """The drop-unsynced undo log keeps at most one entry per synced
        byte: overwriting the same synced region N times must cost O(region),
        not O(N * region) — the regression that made long harness loops
        balloon memory."""
        env = FaultInjectionEnv()
        p = str(tmp_path / "f.bin")
        fd = env.open_fd(p, os.O_RDWR | os.O_CREAT)
        env.pwrite(fd, b"A" * 4096, 0)
        env.fsync(fd)
        env.pwrite(fd, b"B" * 4096, 0)
        first = env.undo_bytes
        assert first <= 4096
        for i in range(200):
            env.pwrite(fd, bytes([i % 256]) * 4096, 0)
        assert env.undo_bytes == first  # interval already covered: no growth
        env.drop_unsynced()
        with env.open(p, "rb") as f:
            assert f.read() == b"A" * 4096  # rewound to the synced image
        env.close_fd(fd)

    def test_reset_clears_all_tracking(self, tmp_path):
        env = FaultInjectionEnv(seed=3)
        p = str(tmp_path / "g.bin")
        fd = env.open_fd(p, os.O_RDWR | os.O_CREAT)
        env.pwrite(fd, b"x" * 1024, 0)
        env.fsync(fd)
        env.pwrite(fd, b"y" * 1024, 0)
        env.set_crash_after(100, ops=("write",))
        env.set_transport_faults(drop=0.5)
        env.close_fd(fd)
        assert env.undo_bytes > 0
        env.reset()
        assert env.undo_bytes == 0
        assert not env.crashed
        assert env._transport_faults == (0.0, 0.0, 0.0, 0.0)
        assert env.op_counts == {} or all(v == 0 for v in env.op_counts.values())
        # dropping after reset must not rewind the (now untracked) file
        env.drop_unsynced()
        with env.open(p, "rb") as f:
            assert f.read() == b"y" * 1024


# ---------------------------------------------------------------------------
# resume() idempotency  (PR 8)
# ---------------------------------------------------------------------------
class TestResumeIdempotent:
    def test_double_resume_is_noop(self, tmp_db_dir):
        env = FaultInjectionEnv()
        db = DB(tmp_db_dir, _cfg(env, memtable_size=4096))
        _fill(db, 40)
        env.add_fault(op="write", path_substr=".sst", count=10_000,
                      error=errno.ENOSPC)
        try:
            for i in range(2000):
                db.put(f"f{i:05d}".encode(), b"z" * 60)
        except RuntimeError:
            pass
        assert _wait_latched(db)
        env.clear_faults()
        db.resume()
        wals_after_first = sorted(
            n for n in os.listdir(tmp_db_dir) if n.startswith("wal_")
        )
        db.resume()  # second call: not latched -> strict no-op
        wals_after_second = sorted(
            n for n in os.listdir(tmp_db_dir) if n.startswith("wal_")
        )
        assert wals_after_first == wals_after_second  # no double rotation
        assert db.stats.snapshot()["resumes"] == 1
        db.put(b"after", b"ok")
        assert db.get(b"after") == b"ok"
        db.close()

    def test_resume_on_healthy_db_is_noop(self, tmp_db_dir):
        db = DB(tmp_db_dir, _cfg(None))
        db.put(b"a", b"1")
        db.resume()
        db.resume()
        assert db.stats.snapshot().get("resumes", 0) == 0
        assert db.get(b"a") == b"1"
        db.close()
