"""BValue garbage collection (beyond-paper extension): dead-value tracking,
space reclamation, read correctness across GC, crash safety."""
import os

import numpy as np
import pytest

from repro.core import DB, DBConfig


def _db(tmp, **kw):
    cfg = dict(
        separation_mode="wal",
        wal_mode="sync",
        memtable_size=64 << 10,
        value_threshold=512,
        level1_max_bytes=256 << 10,
        l0_compaction_trigger=2,
        bvalue_max_file_bytes=32 << 10,  # small files → several GC candidates
        bvcache_bytes=32 << 10,
    )
    cfg.update(kw)
    return DB(tmp, DBConfig(**cfg))


def _bvalue_disk_bytes(path):
    d = os.path.join(path, "bvalue")
    return sum(os.path.getsize(os.path.join(d, f)) for f in os.listdir(d))


def test_overwrites_tracked_dead(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        val = b"X" * 2048
        for i in range(20):
            db.put(f"k{i}".encode(), val)
        for i in range(20):
            db.put(f"k{i}".encode(), b"Y" * 2048)  # supersede all
        db.flush()
        dead = sum(db.dead_tracker.dead_bytes.values())
        assert dead >= 20 * 2048
    finally:
        db.close()


def test_gc_reclaims_space_and_preserves_reads(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        rng = np.random.default_rng(0)
        vals = {}
        for i in range(120):
            k = f"k{i:04d}".encode()
            v = rng.bytes(2048)
            db.put(k, v)
            vals[k] = v
        # supersede everything twice: early sealed files become fully dead
        for _round in range(2):
            for i in range(120):
                k = f"k{i:04d}".encode()
                v = rng.bytes(2048)
                db.put(k, v)
                vals[k] = v
        db.flush()
        db.compact_all()
        before = _bvalue_disk_bytes(tmp_db_dir)
        stats = db.gc_collect(threshold=0.5)
        after = _bvalue_disk_bytes(tmp_db_dir)
        assert stats["collected_files"] >= 1, stats
        assert after < before, (before, after)
        for k, v in vals.items():
            assert db.get(k) == v, k
    finally:
        db.close()


def test_gc_survives_reopen(tmp_db_dir):
    db = _db(tmp_db_dir)
    rng = np.random.default_rng(1)
    vals = {}
    for i in range(80):
        k = f"g{i:04d}".encode()
        db.put(k, rng.bytes(2048))
        v = rng.bytes(2048)
        db.put(k, v)  # supersede immediately
        vals[k] = v
    db.flush()
    db.compact_all()
    db.gc_collect(threshold=0.3)
    db.close()

    db2 = _db(tmp_db_dir)
    try:
        for k, v in vals.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


def test_gc_never_touches_active_tail(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        db.put(b"fresh", b"Z" * 2048)  # lives in an active tail file
        stats = db.gc_collect(threshold=0.0)  # aggressive threshold
        assert db.get(b"fresh") == b"Z" * 2048
    finally:
        db.close()
