"""BValue garbage collection (beyond-paper extension): dead-value tracking,
space reclamation, read correctness across GC, crash safety."""
import os

import numpy as np
import pytest

from repro.core import DB, DBConfig


def _db(tmp, **kw):
    cfg = dict(
        separation_mode="wal",
        wal_mode="sync",
        memtable_size=64 << 10,
        value_threshold=512,
        level1_max_bytes=256 << 10,
        l0_compaction_trigger=2,
        bvalue_max_file_bytes=32 << 10,  # small files → several GC candidates
        bvcache_bytes=32 << 10,
    )
    cfg.update(kw)
    return DB(tmp, DBConfig(**cfg))


def _bvalue_disk_bytes(path):
    d = os.path.join(path, "bvalue")
    return sum(os.path.getsize(os.path.join(d, f)) for f in os.listdir(d))


def test_overwrites_tracked_dead(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        val = b"X" * 2048
        for i in range(20):
            db.put(f"k{i}".encode(), val)
        for i in range(20):
            db.put(f"k{i}".encode(), b"Y" * 2048)  # supersede all
        db.flush()
        dead = sum(db.dead_tracker.dead_bytes.values())
        assert dead >= 20 * 2048
    finally:
        db.close()


def test_gc_reclaims_space_and_preserves_reads(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        rng = np.random.default_rng(0)
        vals = {}
        for i in range(120):
            k = f"k{i:04d}".encode()
            v = rng.bytes(2048)
            db.put(k, v)
            vals[k] = v
        # supersede everything twice: early sealed files become fully dead
        for _round in range(2):
            for i in range(120):
                k = f"k{i:04d}".encode()
                v = rng.bytes(2048)
                db.put(k, v)
                vals[k] = v
        db.flush()
        db.compact_all()
        before = _bvalue_disk_bytes(tmp_db_dir)
        stats = db.gc_collect(threshold=0.5)
        after = _bvalue_disk_bytes(tmp_db_dir)
        assert stats["collected_files"] >= 1, stats
        assert after < before, (before, after)
        for k, v in vals.items():
            assert db.get(k) == v, k
    finally:
        db.close()


def test_gc_survives_reopen(tmp_db_dir):
    db = _db(tmp_db_dir)
    rng = np.random.default_rng(1)
    vals = {}
    for i in range(80):
        k = f"g{i:04d}".encode()
        db.put(k, rng.bytes(2048))
        v = rng.bytes(2048)
        db.put(k, v)  # supersede immediately
        vals[k] = v
    db.flush()
    db.compact_all()
    db.gc_collect(threshold=0.3)
    db.close()

    db2 = _db(tmp_db_dir)
    try:
        for k, v in vals.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


def test_gc_never_touches_active_tail(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        db.put(b"fresh", b"Z" * 2048)  # lives in an active tail file
        stats = db.gc_collect(threshold=0.0)  # aggressive threshold
        assert db.get(b"fresh") == b"Z" * 2048
    finally:
        db.close()


# ---------------------------------------------------------------------------
# GC × snapshots (PR 7): a value shadowed by an overwrite or range delete
# must stay readable while a live snapshot can still reach it
# ---------------------------------------------------------------------------

def test_gc_preserves_snapshot_reads_of_superseded_values(tmp_db_dir):
    """While a snapshot pins the OLD values, they are never even reported
    dead (apply/compaction retain them), so GC has nothing to reclaim and
    the pinned reads keep resolving. After release, a compaction pass drops
    the retained versions and GC reclaims the space."""
    db = _db(tmp_db_dir)
    try:
        vals = {}
        for i in range(40):
            k = f"s{i:03d}".encode()
            vals[k] = b"OLD" + bytes([i]) * 2045
            db.put(k, vals[k])
        snap = db.snapshot()  # pins every OLD value
        for i in range(40):
            db.put(f"s{i:03d}".encode(), b"NEW" + bytes([i]) * 2045)
        db.flush()
        db.compact_all()
        db.gc_collect(threshold=0.0)  # aggressive: must still be a no-harm op
        for k, v in vals.items():
            assert db.get(k, snapshot=snap) == v, k
        snap.release()
        # pin gone: the NEXT real merge drops the retained stripe (a lone
        # bottom file has nothing to merge with, so feed it a fresh flush)
        before = _bvalue_disk_bytes(tmp_db_dir)
        db.put(b"zz", b"x")
        db.flush()
        db.compact_all()
        stats = db.gc_collect(threshold=0.2)
        assert stats["collected_files"] >= 1, stats
        assert _bvalue_disk_bytes(tmp_db_dir) < before
        for i in range(40):
            k = f"s{i:03d}".encode()
            assert db.get(k) == b"NEW" + bytes([i]) * 2045, k
    finally:
        db.close()


def test_gc_snapshot_deferred_stat(tmp_db_dir):
    """A fully-dead candidate file is NOT unlinked while a snapshot older
    than the current seq is live — the pass defers and says so."""
    db = _db(tmp_db_dir)
    try:
        for i in range(40):
            k = f"d{i:03d}".encode()
            db.put(k, b"A" * 2048)
            db.put(k, b"B" * 2048)  # supersede: dead is tracked (no snaps)
        db.flush()
        db.compact_all()
        snap = db.snapshot()
        db.put(b"later", b"x")  # advance seq past the snapshot
        stats = db.gc_collect(threshold=0.2)
        assert stats["snapshot_deferred"] >= 1, stats
        snap.release()
        stats2 = db.gc_collect(threshold=0.2)
        assert stats2["collected_files"] >= 1, stats2
        for i in range(40):
            assert db.get(f"d{i:03d}".encode()) == b"B" * 2048
    finally:
        db.close()


def test_gc_defers_for_snapshot_over_range_delete(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        vals = {}
        for i in range(40):
            k = f"r{i:03d}".encode()
            vals[k] = bytes([65 + (i % 26)]) * 2048
            db.put(k, vals[k])
        snap = db.snapshot()
        db.delete_range(b"r", b"s")  # shadows every value
        db.flush()
        db.compact_all()
        db.gc_collect(threshold=0.0)
        for k, v in vals.items():
            assert db.get(k, snapshot=snap) == v, k
            assert db.get(k) is None, k
        snap.release()
    finally:
        db.close()


def test_gc_unblocked_by_fresh_snapshot(tmp_db_dir):
    """A snapshot taken AFTER the rewrites sees only fresh pointers and
    must not block reclamation."""
    db = _db(tmp_db_dir)
    try:
        for i in range(40):
            k = f"f{i:03d}".encode()
            db.put(k, b"A" * 2048)
            db.put(k, b"B" * 2048)  # supersede
        db.flush()
        db.compact_all()
        snap = db.snapshot()  # post-supersede: never pins the A values
        stats = db.gc_collect(threshold=0.2)
        assert stats["collected_files"] >= 1, stats
        for i in range(40):
            k = f"f{i:03d}".encode()
            assert db.get(k, snapshot=snap) == b"B" * 2048, k
        snap.release()
    finally:
        db.close()
