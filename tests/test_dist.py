"""Logical-axis sharding rules: divisibility fallbacks, axis-conflict
resolution, tree shardings, and a subprocess 8-host-device lowering that
exercises the same code path as the 512-device dry-run."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import Axes, default_rules, logical_to_spec, mesh_context, tree_shardings
from repro.launch.mesh import make_host_mesh


def _mesh22():
    # 1-device 'mesh' can't test divisibility; build specs against a FAKE
    # mesh object exposing .shape — logical_to_spec only reads that.
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        size = 256

    return FakeMesh()


def test_divisible_dims_shard():
    spec = logical_to_spec(("batch", "seq"), (256, 4096), _mesh22(), default_rules())
    assert spec == P("data", None)
    spec = logical_to_spec(
        ("layers", "param_embed", "heads"), (32, 4096, 4096), _mesh22(), default_rules()
    )
    assert spec == P(None, "data", "model")


def test_non_divisible_falls_back_to_replicate():
    # phi3: 40 heads × 128 = 5120 divides 16; but 10 kv-heads × 128 = 1280 → 80 ✓;
    # a truly non-divisible dim (e.g. 49155 vocab) must replicate.
    spec = logical_to_spec(("vocab", "param_embed"), (49155, 1024), _mesh22(), default_rules())
    assert spec[0] is None  # 49155 % 16 != 0 → replicated
    assert spec[1] == "data"


def test_axis_conflict_first_dim_wins():
    # (E, d, ff): experts→model wins; mlp can't reuse model → falls back
    spec = logical_to_spec(
        ("experts", "param_embed", "mlp"), (32, 1024, 512), _mesh22(), default_rules()
    )
    assert spec == P("model", "data", None)
    # 60 experts don't divide 16 → mlp gets model instead
    spec = logical_to_spec(
        ("experts", "param_embed", "mlp"), (60, 2048, 1408), _mesh22(), default_rules()
    )
    assert spec == P(None, "data", "model")


def test_multipod_batch_rule():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        size = 512

    spec = logical_to_spec(("batch", "seq"), (256, 4096), FakeMesh(), default_rules())
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): nothing divides → replicated
    spec = logical_to_spec(("batch", None), (1, 1), FakeMesh(), default_rules())
    assert spec == P(None, None)


def test_tree_shardings_structure():
    mesh = make_host_mesh((1, 1))
    sds = {"a": jax.ShapeDtypeStruct((8, 8), "float32"), "b": [jax.ShapeDtypeStruct((4,), "int32")]}
    axes = {"a": Axes("batch", "embed"), "b": [Axes("batch")]}
    sh = tree_shardings(mesh, sds, axes)
    # size-1 axes still "shard" formally (≡ replication on a 1-device mesh)
    assert sh["a"].spec == P("data", None)
    assert jax.tree.structure(sh) == jax.tree.structure(sds)


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp

    from repro.dist import constrain

    x = jnp.ones((4, 4))
    with mesh_context(None):
        assert constrain(x, ("batch", "embed")) is x


SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    from repro.configs import get_config
    from repro.configs.base import ShapeCell
    from repro.dist import mesh_context
    from repro.launch.specs import build_cell
    from repro.training.train_step import TrainConfig

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((2, 4))
    cfg = get_config("llama3-8b").reduced(d_model=128, n_layers=2, n_heads=8,
                                          n_kv_heads=4, head_dim=16, d_ff=256,
                                          vocab=512, vocab_pad_multiple=64)
    cell = ShapeCell("t", 64, 8, "train")
    with mesh_context(mesh):
        r = build_cell(cfg, cell, mesh, TrainConfig())
        c = jax.jit(r.fn, in_shardings=r.in_shardings,
                    donate_argnums=r.donate_argnums).lower(*r.args).compile()
    ca = c.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    print(json.dumps({"ok": True, "flops": ca.get("flops", 0)}))
    """
)


def test_multidevice_lowering_subprocess():
    """Same build_cell path as the dry-run, on an 8-host-device mesh."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
