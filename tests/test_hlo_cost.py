"""The loop-attributed HLO cost analyzer: crafted-module unit tests plus an
end-to-end check that scan trip counts multiply FLOPs correctly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, parse_hlo

CRAFTED = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %y)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_crafted_while_trip_count():
    res = analyze(CRAFTED)
    # 10 iterations × (2·8·8·8) flops
    assert res["flops"] == 10 * 2 * 8 * 8 * 8


def test_parse_handles_comments_and_tuples():
    comps, entry = parse_hlo(CRAFTED.replace("f32[8,8])", "f32[8,8] /*index=5*/)"))
    assert entry == "main"
    assert "body" in comps and "cond" in comps


def _flops_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze(txt)["flops"], txt


def test_scan_flops_scale_with_trip_count():
    w = jnp.ones((32, 32))

    def once(x):
        return x @ w

    def scan5(x):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=5)
        return out

    f1, _ = _flops_of(once, jnp.ones((4, 32)))
    f5, _ = _flops_of(scan5, jnp.ones((4, 32)))
    assert f1 > 0
    # XLA may pad/fuse; require ≈5× within 20%
    assert 0.8 * 5 <= f5 / f1 <= 1.2 * 5, (f1, f5)


def test_matmul_flops_exact():
    a = jnp.ones((16, 64))
    b = jnp.ones((64, 32))
    f, txt = _flops_of(lambda a, b: a @ b, a, b)
    assert f == 2 * 16 * 64 * 32, txt[:500]


def test_nested_scan_multiplies():
    w = jnp.ones((16, 16))

    def nested(x):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    f, _ = _flops_of(nested, jnp.ones((4, 16)))
    expect = 12 * 2 * 4 * 16 * 16
    assert 0.8 * expect <= f <= 1.25 * expect, f


def test_bytes_positive_and_bounded():
    a = jnp.ones((256, 256))
    res_f, txt = _flops_of(lambda x: jnp.tanh(x @ x), a)
    res = analyze(txt)
    assert res["bytes"] >= 3 * 256 * 256 * 4  # at least in+out+weight
    assert res["bytes"] < 100 * 256 * 256 * 4  # not absurdly inflated
