"""Batched read path (PR 9): DB.multi_get, vectorized bloom probes,
SSTable format v4 (prefix-compressed keys), the v1-v4 compat matrix, 2Q
scan-resistant cache admission, and compaction read metering."""
import os
import random

import pytest

from repro.core import DB, DBConfig
from repro.core.blockcache import BlockCache
from repro.core.bloom import BloomFilter, _hash2
from repro.core.sstable import (
    FORMAT_VERSION,
    SSTableReader,
    SSTableWriter,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:  # container ships without hypothesis; seeded fallback below
    HAVE_HYPOTHESIS = False

SMALL = dict(
    memtable_size=64 << 10,
    level1_max_bytes=256 << 10,
    value_threshold=512,
    bvcache_bytes=64 << 10,
    l0_compaction_trigger=2,
)


def mk(tmp, **kw):
    cfg = {"separation_mode": "wal", "wal_mode": "sync", **SMALL, **kw}
    return DB(tmp, DBConfig(**cfg))


# ---------------------------------------------------------------------------
# format v4: prefix-compressed keys
# ---------------------------------------------------------------------------

ITEMS = [(f"user{i:06d}".encode(), i + 1, 1, bytes([i % 251]) * (i % 97)) for i in range(400)]


def _write_table(path, *, format_version, items=ITEMS, block_size=256,
                 restart_interval=16, compression=False):
    w = SSTableWriter(path, block_size=block_size, compression=compression,
                      format_version=format_version, restart_interval=restart_interval)
    for k, s, t, v in items:
        w.add(k, s, t, v)
    return w.finish(1)


@pytest.mark.parametrize("restart_interval", [1, 2, 7, 16])
def test_v4_roundtrip(tmp_path, restart_interval):
    path = str(tmp_path / "t.sst")
    meta = _write_table(path, format_version=4, restart_interval=restart_interval)
    assert meta.entries == len(ITEMS)
    r = SSTableReader(path)
    assert r.format_version == 4
    for k, s, t, v in ITEMS:
        assert r.get(k) == (True, s, t, v)
    assert [tuple(e) for e in r] == [tuple(e) for e in ITEMS]
    assert [k for k, *_ in r.iter_from(b"user000123")] == [k for k, *_ in ITEMS[123:]]
    r.close()


def test_v4_actually_compresses_shared_prefixes(tmp_path):
    """The point of v4: long-common-prefix key sets must shrink on disk."""
    items = [(f"tenant/alpha/user/{i:08d}".encode(), i + 1, 1, b"v") for i in range(500)]
    p3, p4 = str(tmp_path / "a.sst"), str(tmp_path / "b.sst")
    m3 = _write_table(p3, format_version=3, items=items)
    m4 = _write_table(p4, format_version=4, items=items)
    assert m4.size < m3.size * 0.8, (m3.size, m4.size)
    r = SSTableReader(p4)
    for k, s, t, v in items[::13]:
        assert r.get(k) == (True, s, t, v)
    r.close()


def test_v4_restart_boundary_edge_keys(tmp_path):
    """Keys ON restart boundaries carry shared=0 (self-parseable); probes
    for the restart key itself, its immediate prefix-sharing neighbours,
    and absent keys that sort just before/after a restart must all resolve
    through the restart binary search."""
    # one block, restart every 4 entries → entries 0,4,8,... are restarts
    items = [(b"pfx" + bytes([65 + i // 10]) + f"{i:04d}".encode(), i + 1, 1, b"v%d" % i)
             for i in range(64)]
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=4, items=items, block_size=1 << 20,
                 restart_interval=4)
    r = SSTableReader(path)
    assert len(r.index) == 1
    r.bloom.bits = bytearray(b"\xff" * len(r.bloom.bits))  # exercise the block search
    for i, (k, s, t, v) in enumerate(items):
        assert r.get(k) == (True, s, t, v), (i, k)
    for i in (0, 4, 8, 60):  # absent keys hugging restart entries
        k = items[i][0]
        assert r.get(k[:-1] + b"!")[0] is False  # sorts before (ord('!')<ord('0'))
        assert r.get(k + b"x")[0] is False  # sorts just after
    assert r.get(b"a")[0] is False and r.get(b"zzz")[0] is False
    # iter_from landing mid-interval must rebuild keys from the restart
    for start_i in (1, 3, 5, 7, 63):
        got = [k for k, *_ in r.iter_from(items[start_i][0])]
        assert got == [k for k, *_ in items[start_i:]], start_i
    r.close()


def test_v4_multiversion_runs(tmp_path):
    """(user_key asc, seq desc) duplicate runs under prefix compression:
    consecutive identical keys share their whole prefix; newest must win on
    point gets, get_at must reach the older version."""
    items = []
    for i in range(40):
        k = f"dup{i:04d}".encode()
        items.append((k, 1000 - i * 2, 1, b"new%d" % i))
        items.append((k, 500 - i * 2, 1, b"old%d" % i))
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=4, items=items, block_size=128,
                 restart_interval=3)
    r = SSTableReader(path)
    for i in range(40):
        k = f"dup{i:04d}".encode()
        assert r.get(k) == (True, 1000 - i * 2, 1, b"new%d" % i)
        assert r.get_at(k, 700 - i * 2) == (True, 500 - i * 2, 1, b"old%d" % i)
    r.close()


def test_v4_empty_and_single_key_tables(tmp_path):
    # empty table: zero entries, still a valid file
    p = str(tmp_path / "empty.sst")
    w = SSTableWriter(p, format_version=4)
    meta = w.finish(1)
    assert meta.entries == 0
    r = SSTableReader(p)
    assert r.get(b"anything") == (False, 0, 0, b"")
    assert list(r) == []
    assert r.get_many([b"a", b"b"]) == {}
    r.close()
    # single-key table; also the single-entry-per-block degenerate case
    p2 = str(tmp_path / "one.sst")
    _write_table(p2, format_version=4, items=[(b"only", 7, 1, b"val")],
                 block_size=1, restart_interval=1)
    r = SSTableReader(p2)
    assert r.get(b"only") == (True, 7, 1, b"val")
    assert r.get(b"onl")[0] is False and r.get(b"onlyx")[0] is False
    assert r.get_many([b"only", b"nope"]) == {b"only": (7, 1, b"val")}
    r.close()


@pytest.mark.parametrize("fmt", [1, 2, 3, 4])
def test_compat_matrix_roundtrip(tmp_path, fmt):
    """Every supported format round-trips the same entry set through the
    same reader surface (get / iterate / iter_from / get_many)."""
    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=fmt)
    r = SSTableReader(path)
    assert r.format_version == fmt
    for k, s, t, v in ITEMS[::7]:
        assert r.get(k) == (True, s, t, v)
    assert [tuple(e) for e in r] == [tuple(e) for e in ITEMS]
    probe = [k for k, *_ in ITEMS[::11]] + [b"absent%d" % i for i in range(10)]
    got = r.get_many(probe)
    assert got == {k: (s, t, v) for k, s, t, v in ITEMS[::11]}
    r.close()


def test_compat_matrix_cross_version_directory(tmp_db_dir):
    """A DB directory accreting tables from v1, v2, v3 and v4 writers must
    serve every key under the current (v4-writing) engine — the on-disk
    compat rule in practice."""
    vals = {}
    for fmt in (1, 2, 3, 4):
        # high trigger: keep each format's table alive (no L0 rewrite)
        db = mk(tmp_db_dir, sstable_format_version=fmt, l0_compaction_trigger=100)
        try:
            for i in range(120):
                k = f"f{fmt}k{i:04d}".encode()
                v = bytes([(fmt * 40 + i) % 251]) * (48 if i % 3 else 700)
                db.put(k, v)
                vals[k] = v
            db.flush()
        finally:
            db.close()
    db = mk(tmp_db_dir, l0_compaction_trigger=100)  # default writer (v4)
    try:
        versions = {
            SSTableReader(os.path.join(tmp_db_dir, f)).format_version
            for f in os.listdir(tmp_db_dir) if f.endswith(".sst")
        }
        assert {1, 4} <= versions, versions  # oldest + newest coexist
        for k, v in vals.items():
            assert db.get(k) == v, k
        # batched path agrees across the mixed-format directory
        probe = sorted(vals)[::5] + [b"zz-absent"]
        assert db.multi_get(probe) == [vals.get(k) for k in probe]
        db.compact_all()  # rewrites into v4; everything still serves
        for k, v in list(vals.items())[::11]:
            assert db.get(k) == v
    finally:
        db.close()


def test_writer_rejects_unknown_version(tmp_path):
    with pytest.raises(ValueError, match="unsupported sstable format_version"):
        SSTableWriter(str(tmp_path / "t.sst"), format_version=FORMAT_VERSION + 1)


# ---------------------------------------------------------------------------
# vectorized bloom probes
# ---------------------------------------------------------------------------

def _legacy_filter(keys, nbits=1000, k=6):
    """Replicate the pre-PR-3 builder: arbitrary (non-pow2) nbits, % probes."""
    bits = bytearray((nbits + 7) // 8)
    for key in keys:
        h1, h2 = _hash2(key)
        for i in range(k):
            b = (h1 + i * h2) % nbits
            bits[b >> 3] |= 1 << (b & 7)
    return BloomFilter(k, nbits, bits)


def _assert_batch_matches_scalar(bf, probe):
    got = bf.may_contain_many(probe)
    want = [bf.may_contain(k) for k in probe]
    assert list(got) == want


def test_bloom_vectorized_equals_scalar_seeded():
    """Exhaustive seeded sweep of the property
    ``may_contain_many(keys) == [may_contain(k) for k in keys]`` across
    pow2 and legacy %-sized encodings, member and non-member keys, and
    batch sizes 0/1/2/odd/large."""
    rng = random.Random(0xB70011)
    for trial in range(25):
        members = [rng.randbytes(rng.randint(1, 40)) for _ in range(rng.randint(1, 300))]
        filters = [
            BloomFilter.build(members, bits_per_key=rng.choice([4, 10, 16])),
            _legacy_filter(members, nbits=rng.choice([1000, 777, 4097]), k=rng.randint(1, 8)),
            BloomFilter.decode(BloomFilter.build(members).encode()),
        ]
        probe = members[:: max(1, len(members) // 7)] + [
            rng.randbytes(rng.randint(1, 40)) for _ in range(30)
        ]
        rng.shuffle(probe)
        for bf in filters:
            for batch in ([], probe[:1], probe[:2], probe[:13], probe):
                _assert_batch_matches_scalar(bf, batch)
            assert all(bf.may_contain_many(members))  # no false negatives


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        members=hyp_st.lists(hyp_st.binary(min_size=1, max_size=32), min_size=1, max_size=80),
        probe=hyp_st.lists(hyp_st.binary(min_size=1, max_size=32), max_size=60),
        nbits=hyp_st.integers(min_value=64, max_value=5000),
        k=hyp_st.integers(min_value=1, max_value=10),
    )
    def test_bloom_vectorized_equals_scalar_property(members, probe, nbits, k):
        for bf in (BloomFilter.build(members), _legacy_filter(members, nbits=nbits, k=k)):
            _assert_batch_matches_scalar(bf, probe + members)


def test_get_many_coalesces_block_reads(tmp_path, monkeypatch):
    """N keys in the same block must cost ONE pread, not N."""
    import repro.core.sstable as sstable_mod

    path = str(tmp_path / "t.sst")
    _write_table(path, format_version=4, block_size=1 << 20)  # one data block
    r = SSTableReader(path)  # no cache: every block read is a pread
    assert len(r.index) == 1
    calls = []
    real_pread = os.pread
    monkeypatch.setattr(
        sstable_mod.os, "pread",
        lambda *a, **kw: (calls.append(a), real_pread(*a, **kw))[1],
    )
    probe = [k for k, *_ in ITEMS[::3]]
    got = r.get_many(probe)
    assert len(got) == len(probe)
    assert len(calls) == 1, len(calls)  # one block fetch for the whole batch
    r.close()


# ---------------------------------------------------------------------------
# 2Q scan-resistant admission
# ---------------------------------------------------------------------------

class _FakeBlock:
    def __init__(self, charge=300):
        self.charge = charge


def test_2q_scan_does_not_flush_hot_set():
    """Hot (re-referenced → Am) blocks must survive a long one-shot sweep;
    the sweep churns only the probationary A1in fraction."""
    c = BlockCache(10_000, shards=1, policy="2q", a1_fraction=0.25)
    hot = [(1, i) for i in range(10)]
    for k in hot:
        c.put(k, _FakeBlock(300))
    for k in hot:
        assert c.get(k) is not None  # re-reference → promoted to Am
    st = c.stats()
    assert st["block_cache_promotions"] == len(hot)
    for i in range(200):  # a cursor-sweep's worth of one-shot blocks
        c.put((2, i), _FakeBlock(300))
    for k in hot:
        assert c.peek(k) is not None, k  # the working set survived
    st = c.stats()
    assert st["block_cache_bytes"] <= 10_000
    # sweep blocks lived and died in probation: none earned Am, and the
    # survivors occupy exactly the probationary (A1in) bytes
    resident_sweep = sum(c.peek((2, i)) is not None for i in range(200))
    assert resident_sweep * 300 == st["block_cache_a1_bytes"]
    assert resident_sweep <= (10_000 - len(hot) * 300) // 300


def test_lru_policy_is_flushed_by_scan():
    """Contrast case: plain LRU loses the hot set to the same sweep — the
    behavior 2Q exists to fix."""
    c = BlockCache(10_000, shards=1, policy="lru")
    hot = [(1, i) for i in range(10)]
    for k in hot:
        c.put(k, _FakeBlock(300))
        assert c.get(k) is not None
    for i in range(200):
        c.put((2, i), _FakeBlock(300))
    assert all(c.peek(k) is None for k in hot)


def test_2q_ghost_readmission_promotes():
    """A block evicted from probation whose key is still remembered by the
    A1out ghost list must be admitted straight to Am on re-insert."""
    c = BlockCache(3_000, shards=1, policy="2q", a1_fraction=0.5)
    c.put((1, 0), _FakeBlock(1000))
    for i in range(1, 6):  # push (1,0) out of A1in into the ghost
        c.put((1, i), _FakeBlock(1000))
    assert c.peek((1, 0)) is None
    c.put((1, 0), _FakeBlock(1000))  # readmission while ghost-remembered
    st = c.stats()
    assert st["block_cache_ghost_hits"] >= 1
    # now in Am: a fresh sweep can't evict it before A1in drains
    c.put((3, 1), _FakeBlock(1000))
    c.put((3, 2), _FakeBlock(1000))
    assert c.peek((1, 0)) is not None


def test_2q_accounting_exact_across_paths():
    """size_bytes must return to exactly zero after mixed put/get/promote/
    evict/evict_file traffic — byte-accounting drift is permanent."""
    rng = random.Random(7)
    c = BlockCache(8_000, shards=2, policy="2q")
    for step in range(2000):
        op = rng.random()
        key = (rng.randint(1, 5), rng.randint(0, 30))
        if op < 0.5:
            c.put(key, _FakeBlock(rng.randint(50, 900)))
        elif op < 0.8:
            c.get(key)
        else:
            c.evict_file(key[0])
    st = c.stats()
    assert st["block_cache_bytes"] <= 8_000
    for f in range(1, 6):
        c.evict_file(f)
    assert c.size_bytes == 0
    assert c.stats()["block_cache_a1_bytes"] == 0


def test_recharge_after_concurrent_evict_is_noop():
    """Regression (satellite): recharging a block that evict_file already
    dropped must NOT re-apply its delta — the lock-held identity check
    keeps size_bytes exact instead of permanently inflated."""
    c = BlockCache(100_000, shards=1, policy="2q")
    blk = _FakeBlock(500)
    c.put((1, 0), blk)
    c.put((2, 0), _FakeBlock(400))
    before = c.size_bytes
    assert before == 900
    c.evict_file(1)  # concurrent eviction wins the race
    blk.charge = 50_000  # block materialized meanwhile
    c.recharge((1, 0), blk)  # stale recharge: must be a no-op
    assert c.size_bytes == 400
    # same for a replaced entry: the key is resident but holds ANOTHER block
    blk2 = _FakeBlock(500)
    c.put((2, 0), blk2)  # replaces the 400-byte entry; size is now 500
    old = _FakeBlock(999)
    c.recharge((2, 0), old)  # stale: different block object under that key
    assert c.size_bytes == 500
    # a LEGITIMATE recharge still applies (and still evicts if over budget)
    blk2.charge = 700
    c.recharge((2, 0), blk2)
    assert c.size_bytes == 700


def test_blockcache_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown block cache policy"):
        BlockCache(1000, policy="arc")


# ---------------------------------------------------------------------------
# DB.multi_get
# ---------------------------------------------------------------------------

def test_multi_get_equals_sequential_gets(tmp_db_dir):
    """Differential: multi_get must agree with [get(k) for k] across
    memtable hits, flushed tables, point deletes, range deletes, separated
    values, and absent keys — with compaction churn in between."""
    rng = random.Random(1234)
    db = mk(tmp_db_dir)
    try:
        model = {}
        keys = [f"k{i:05d}".encode() for i in range(1500)]
        for i in range(5000):
            k = rng.choice(keys)
            r = rng.random()
            if r < 0.72:
                v = (b"v%d" % i) * rng.choice([1, 2, 120])  # inline + separated
                db.put(k, v)
                model[k] = v
            elif r < 0.88:
                db.delete(k)
                model.pop(k, None)
            else:
                lo = rng.choice(keys)
                hi = lo + b"\x7f"
                db.delete_range(lo, hi)
                for mk_ in [m for m in model if lo <= m < hi]:
                    del model[mk_]
            if i == 2500:
                db.flush()
        probe = rng.sample(keys, 400) + [b"zz%04d" % i for i in range(40)]
        rng.shuffle(probe)
        got = db.multi_get(probe)
        assert got == [db.get(k) for k in probe]
        assert got == [model.get(k) for k in probe]
        db.flush()
        db.compact_all()
        assert db.multi_get(probe) == [model.get(k) for k in probe]
    finally:
        db.close()


def test_multi_get_snapshot_reads(tmp_db_dir):
    db = mk(tmp_db_dir)
    try:
        keys = [f"s{i:04d}".encode() for i in range(200)]
        for k in keys:
            db.put(k, b"before-" + k)
        db.flush()
        snap = db.snapshot()
        for k in keys[:100]:
            db.put(k, b"after")
        db.delete(keys[150])
        db.flush()
        db.compact_all()
        got = db.multi_get(keys, snapshot=snap)
        assert got == [b"before-" + k for k in keys]
        assert got == [db.get(k, snapshot=snap) for k in keys]
        latest = db.multi_get(keys)
        assert latest[:100] == [b"after"] * 100
        assert latest[150] is None
    finally:
        db.close()


def test_multi_get_duplicates_order_and_chunking(tmp_db_dir):
    """Output aligns with the input order, duplicates resolve consistently,
    and batches larger than multi_get_max_batch split transparently."""
    db = mk(tmp_db_dir, multi_get_max_batch=16)
    try:
        for i in range(100):
            db.put(b"c%03d" % i, b"val%03d" % i)
        db.flush()
        probe = [b"c%03d" % (i % 50) for i in range(90)] + [b"missing"] * 3
        got = db.multi_get(probe)
        assert got == [db.get(k) for k in probe]
        assert db.multi_get([]) == []
        st = db.stats.snapshot()
        assert st["multi_gets"] >= 1
        assert st["multi_get_keys"] >= len(probe)
    finally:
        db.close()


def test_multi_get_vs_format_matrix(tmp_db_dir):
    """multi_get over a directory mixing every table format."""
    vals = {}
    for fmt in (2, 3, 4):
        db = mk(tmp_db_dir, sstable_format_version=fmt)
        try:
            for i in range(150):
                k = f"m{fmt}-{i:04d}".encode()
                vals[k] = b"x" * (i % 60 + 1)
                db.put(k, vals[k])
            db.flush()
        finally:
            db.close()
    db = mk(tmp_db_dir)
    try:
        probe = sorted(vals)[::3]
        assert db.multi_get(probe) == [vals[k] for k in probe]
    finally:
        db.close()


# ---------------------------------------------------------------------------
# compaction read metering
# ---------------------------------------------------------------------------

def _churn(db, n=3000, val=b"y" * 64):
    for i in range(n):
        db.put(b"w%05d" % (i % 1000), val)
    db.flush()
    db.compact_all()


def test_compaction_reads_metered_at_pri_low(tmp_db_dir):
    db = mk(tmp_db_dir, wal_mode="off", bg_io_bytes_per_sec=500 << 20)
    try:
        _churn(db)
        st = db.stats.snapshot()
        assert st.get("compaction_count", 0) >= 1
        metered = st.get("compaction_read_metered_bytes", 0)
        assert metered > 0
        # sanity: metered reads can't exceed what compaction reports reading
        assert metered <= st["compaction_read_bytes"] * 1.1 + (256 << 10)
    finally:
        db.close()


def test_compaction_read_metering_off_by_knob(tmp_db_dir):
    db = mk(tmp_db_dir, wal_mode="off", bg_io_bytes_per_sec=500 << 20,
            compaction_read_metering=False)
    try:
        _churn(db)
        st = db.stats.snapshot()
        assert st.get("compaction_count", 0) >= 1
        assert st.get("compaction_read_metered_bytes", 0) == 0
    finally:
        db.close()


def test_compaction_read_metering_noop_without_budget(tmp_db_dir):
    """With the limiter disabled (rate 0) the meter must not engage at all."""
    db = mk(tmp_db_dir, wal_mode="off")
    try:
        _churn(db, n=1500)
        assert db.stats.snapshot().get("compaction_read_metered_bytes", 0) == 0
    finally:
        db.close()
