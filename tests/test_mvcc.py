"""MVCC surface: snapshots, cursors, range deletes — proven against the
dict-of-versions reference model (`repro.testing.model_db`).

Two layers of evidence:

* deterministic regression tests for every visibility rule the engine
  implements (and one for each bug the differential harness caught);
* the randomized differential driver itself — plain ``random`` here so it
  runs in the hypothesis-free container, plus a hypothesis stateful machine
  that layers minimizing shrinkage on top where the dependency exists.
"""
import threading

import pytest

from repro.core import DB, DBConfig
from repro.testing.model_db import LATEST, ModelDB, run_differential, run_example

try:
    from hypothesis import settings
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        initialize,
        invariant,
        precondition,
        rule,
    )
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # container ships without hypothesis
    HAVE_HYPOTHESIS = False


def _db(tmp, **kw):
    cfg = dict(
        separation_mode="wal",
        memtable_size=4 << 10,  # tiny: tests exercise flux, not capacity
        value_threshold=64,
        l0_compaction_trigger=2,
    )
    cfg.update(kw)
    return DB(tmp, DBConfig(**cfg))


# ---------------------------------------------------------------------------
# snapshot isolation
# ---------------------------------------------------------------------------

def test_snapshot_pins_point_reads(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        db.put(b"a", b"v1")
        with db.snapshot() as snap:
            db.put(b"a", b"v2")
            db.delete(b"a")
            assert db.get(b"a") is None
            assert db.get(b"a", snapshot=snap) == b"v1"
    finally:
        db.close()


def test_snapshot_survives_flush_and_compaction(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        big = b"B" * 200  # separated: exercises BValue reachability too
        db.put(b"a", big)
        db.put(b"b", b"small")
        snap = db.snapshot()
        db.put(b"a", b"w" * 200)
        db.delete(b"b")
        db.flush()
        db.compact_all()
        assert db.get(b"a", snapshot=snap) == big
        assert db.get(b"b", snapshot=snap) == b"small"
        assert db.get(b"b") is None
        snap.release()
    finally:
        db.close()


def test_snapshot_release_is_idempotent_and_limited(tmp_db_dir):
    db = _db(tmp_db_dir, max_snapshots=2)
    try:
        s1, s2 = db.snapshot(), db.snapshot()
        with pytest.raises(RuntimeError):
            db.snapshot()
        s1.release()
        s1.release()  # second release is a no-op, not a double-decrement
        s3 = db.snapshot()
        s2.release()
        s3.release()
    finally:
        db.close()


def test_snapshot_sees_through_batch_boundary(tmp_db_dir):
    """A snapshot taken between two batches sees exactly the first."""
    db = _db(tmp_db_dir)
    try:
        from repro.core import WriteBatch

        wb = WriteBatch()
        wb.put(b"x", b"1")
        wb.put(b"y", b"1")
        db.write(wb)
        snap = db.snapshot()
        wb2 = WriteBatch()
        wb2.delete(b"x")
        wb2.put(b"y", b"2")
        db.write(wb2)
        assert db.get(b"x", snapshot=snap) == b"1"
        assert db.get(b"y", snapshot=snap) == b"1"
        assert db.get(b"x") is None
        assert db.get(b"y") == b"2"
        snap.release()
    finally:
        db.close()


# ---------------------------------------------------------------------------
# cursors
# ---------------------------------------------------------------------------

def test_cursor_ordering_across_flush_and_compaction(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        keys = [f"k{i:03d}".encode() for i in range(40)]
        for k in keys:
            db.put(k, b"v_" + k)
        with db.iterator() as cur:
            seen = []
            ok = cur.seek(b"")
            for step in range(len(keys)):
                assert ok
                seen.append(cur.key)
                if step == 5:
                    # mutate + reorganize mid-iteration: the cursor's view
                    # is pinned, so none of this may perturb the walk
                    db.delete(keys[20])
                    db.put(keys[30], b"overwritten")
                    db.put(b"zzz", b"new")
                    db.flush()
                    db.compact_all()
                ok = cur.next()
            assert seen == keys
            assert not cur.next()
    finally:
        db.close()


def test_cursor_prev_and_seek(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        keys = [f"k{i:02d}".encode() for i in range(10)]
        for k in keys:
            db.put(k, k)
        db.flush()
        db.delete(keys[4])
        with db.iterator() as cur:
            assert cur.seek(b"k05") and cur.key == b"k05"
            assert cur.prev() and cur.key == b"k03"  # k04 deleted
            assert cur.prev() and cur.key == b"k02"
            assert cur.next() and cur.key == b"k03"  # direction flip
            # prev from an exhausted cursor = seek-to-last
            while cur.next():
                pass
            assert not cur.valid
            assert cur.prev() and cur.key == keys[-1]
            # prev below the first key invalidates
            assert cur.seek(b"") and cur.key == keys[0]
            assert not cur.prev()
    finally:
        db.close()


def test_cursor_honors_snapshot(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        db.put(b"a", b"1")
        db.put(b"c", b"1")
        snap = db.snapshot()
        db.put(b"b", b"late")
        db.delete(b"c")
        with db.iterator(snap) as cur:
            got = []
            ok = cur.seek(b"")
            while ok:
                got.append(cur.key)
                ok = cur.next()
            assert got == [b"a", b"c"]
        snap.release()
    finally:
        db.close()


def test_scan_streams_from_cursor(tmp_db_dir):
    """`scan` keeps its list signature but is a thin wrapper over Cursor."""
    db = _db(tmp_db_dir)
    try:
        for i in range(30):
            db.put(f"k{i:03d}".encode(), f"v{i}".encode())
        db.flush()
        got = list(db.range(b"k010", limit=5))
        assert [k for k, _ in got] == [f"k{i:03d}".encode() for i in range(10, 15)]
        assert got[0][1] == b"v10"
    finally:
        db.close()


# ---------------------------------------------------------------------------
# range deletes
# ---------------------------------------------------------------------------

def test_range_tombstone_visibility(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        for k in (b"a", b"b", b"c", b"d"):
            db.put(k, b"v_" + k)
        snap = db.snapshot()
        db.delete_range(b"b", b"d")  # covers b, c; d is exclusive
        assert db.get(b"a") == b"v_a"
        assert db.get(b"b") is None
        assert db.get(b"c") is None
        assert db.get(b"d") == b"v_d"
        # the pre-delete snapshot still sees everything
        for k in (b"a", b"b", b"c", b"d"):
            assert db.get(k, snapshot=snap) == b"v_" + k
        # visibility is identical after the tombstone reaches SSTables
        db.flush()
        db.compact_all()
        assert db.get(b"b") is None
        assert db.get(b"b", snapshot=snap) == b"v_b"
        assert [k for k, _ in db.range(limit=10)] == [b"a", b"d"]
        snap.release()
    finally:
        db.close()


def test_range_tombstone_does_not_cover_same_batch_puts(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        from repro.core import WriteBatch

        db.put(b"k1", b"old")
        wb = WriteBatch()
        wb.delete_range(b"k0", b"k9")
        wb.put(b"k1", b"new")  # same seq as the tombstone → not covered
        db.write(wb)
        assert db.get(b"k1") == b"new"
        db.flush()
        db.compact_all()
        assert db.get(b"k1") == b"new"
    finally:
        db.close()


def test_delete_range_validation(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        with pytest.raises(ValueError):
            db.delete_range(b"b", b"a")
        with pytest.raises(ValueError):
            db.delete_range(b"a", b"a")
    finally:
        db.close()
    db2 = _db(tmp_db_dir + "_v2", sstable_format_version=2)
    try:
        with pytest.raises(ValueError):
            db2.delete_range(b"a", b"b")
    finally:
        db2.close()


def test_covering_tombstone_uses_oldest_not_newest(tmp_db_dir):
    """Regression (differential seed 7000038): an entry covered by an
    in-stripe tombstone AND a newer cross-stripe one must be dropped with
    the in-stripe tombstone — testing only the newest covering seq kept
    the value while the bottom pass dropped its tombstone, resurrecting
    the value under the pinned snapshot."""
    db = _db(tmp_db_dir)
    try:
        db.put(b"k", b"v1")          # seq 1
        db.delete_range(b"a", b"z")  # seq 2 — covers k
        snap = db.snapshot()         # pins seq 2 (sees the tombstone)
        db.flush()                   # L0 file A: k@1 + tombstone@2
        db.delete_range(b"a", b"z")  # seq 3 — newer, cross-stripe tombstone
        db.flush()                   # L0 file B: tombstone@3
        db.compact_all()             # real merge (two inputs, no trivial move)
        assert db.get(b"k") is None
        assert db.get(b"k", snapshot=snap) is None
        snap.release()
    finally:
        db.close()


def test_range_tombstone_survives_reopen(tmp_db_dir):
    db = _db(tmp_db_dir, wal_mode="sync")
    try:
        db.put(b"a", b"1")
        db.put(b"m", b"1")
        db.delete_range(b"a", b"m")  # WAL-only: no flush before reopen
    finally:
        db.close()
    db = _db(tmp_db_dir, wal_mode="sync")
    try:
        assert db.get(b"a") is None
        assert db.get(b"m") == b"1"
    finally:
        db.close()


# ---------------------------------------------------------------------------
# differential harness (plain random — runs everywhere)
# ---------------------------------------------------------------------------

def test_differential_smoke(tmp_path):
    out = run_differential(examples=20, seed=900, n_ops=50)
    assert out["failures"] == [], out["failures"]


def test_differential_known_bad_seed(tmp_path):
    # the seed that caught the covering-tombstone bug stays pinned forever
    assert run_example(7000038, str(tmp_path), 60) == []


def test_concurrent_readers_never_tear(tmp_db_dir):
    """Cursors + gets race flush/compaction from another thread; every
    observed state must be internally consistent (no torn reads)."""
    db = _db(tmp_db_dir)
    errors = []
    stop = threading.Event()

    def churn():
        i = 0
        while not stop.is_set():
            db.put(f"k{i % 50:03d}".encode(), f"v{i}".encode() * 8)
            if i % 40 == 0:
                db.flush()
            i += 1

    t = threading.Thread(target=churn)
    t.start()
    try:
        for _ in range(30):
            with db.iterator() as cur:
                prev = None
                ok = cur.seek(b"")
                while ok:
                    if prev is not None and not (prev < cur.key):
                        errors.append(f"order violated: {prev} !< {cur.key}")
                    prev = cur.key
                    ok = cur.next()
    finally:
        stop.set()
        t.join()
        db.close()
    assert errors == []


# ---------------------------------------------------------------------------
# hypothesis stateful machine (skipped where hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    _KEYS = st.sampled_from([f"k{i:02d}".encode() for i in range(16)])

    @settings(max_examples=25, stateful_step_count=30, deadline=None)
    class MVCCMachine(RuleBasedStateMachine):
        """Differential stateful test: every rule mutates both the engine
        and the model; the invariant re-checks full visible state at the
        latest read point and at every live snapshot."""

        @initialize(target=st.none())
        def setup(self):
            import tempfile

            self._dir = tempfile.mkdtemp(prefix="mvccsm_")
            self.db = _db(self._dir + "/db")
            self.model = ModelDB()
            self.snaps = []

        def teardown(self):
            for s, _ in self.snaps:
                s.release()
            self.db.close()
            import shutil

            shutil.rmtree(self._dir, ignore_errors=True)

        @rule(k=_KEYS, v=st.binary(min_size=1, max_size=200))
        def put(self, k, v):
            self.db.put(k, v)
            self.model.put(k, v)

        @rule(k=_KEYS)
        def delete(self, k):
            self.db.delete(k)
            self.model.delete(k)

        @rule(a=_KEYS, b=_KEYS)
        def delete_range(self, a, b):
            a, b = sorted((a, b))
            if a == b:
                b = b + b"\x00"
            self.db.delete_range(a, b)
            self.model.delete_range(a, b)

        @precondition(lambda self: len(self.snaps) < 3)
        @rule()
        def take_snapshot(self):
            self.snaps.append((self.db.snapshot(), self.model.snapshot()))

        @precondition(lambda self: self.snaps)
        @rule()
        def release_snapshot(self):
            s, _ = self.snaps.pop(0)
            s.release()

        @rule()
        def flush(self):
            self.db.flush()

        @rule()
        def compact(self):
            self.db.compact_all()

        @invariant()
        def states_agree(self):
            for snap, mseq in [(None, None)] + self.snaps:
                want = self.model.items_at(LATEST if mseq is None else mseq)
                got = []
                with self.db.iterator(snap) as cur:
                    ok = cur.seek(b"")
                    while ok:
                        got.append((cur.key, cur.value))
                        ok = cur.next()
                assert got == want, f"@{mseq}: {got} != {want}"

    TestMVCCMachine = MVCCMachine.TestCase
