"""Per-arch smoke tests (reduced same-family configs, one forward/train step
on CPU: output shapes + finite values) and the decode-consistency invariant
(decode_step at position T == teacher-forced forward on T+1 tokens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig, init_state, make_train_step

B, T = 2, 32


def _batch(cfg, key=1):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.n_vision_patches, cfg.d_model)), jnp.float32
        )
    if cfg.family == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.enc_len, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    batch = _batch(cfg)
    params = model.init(jax.random.key(0))

    aux_in = batch.get("vision_embeds", batch.get("enc_embeds"))
    logits, _aux = jax.jit(lambda p, b, a: model.forward(p, b["tokens"], a))(
        params, batch, aux_in
    )
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one full train step (loss + grads + optimizer update)
    tc = TrainConfig(opt=OptimizerConfig(warmup_steps=1, total_steps=10))
    state = init_state(model, jax.random.key(0), tc.opt)
    step = jax.jit(make_train_step(model, tc))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.sum(jnp.abs(p.astype(jnp.float32) - q.astype(jnp.float32)))),
            state["params"] if False else jax.tree.map(lambda x: x, state2["params"]),
            state2["params"],
        ),
        0.0,
    )
    # (self-compare is zero; compare against a fresh init instead)
    fresh = init_state(model, jax.random.key(0), tc.opt)["params"]
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.sum(jnp.abs(p.astype(jnp.float32) - q.astype(jnp.float32)))),
            fresh,
            state2["params"],
        ),
        0.0,
    )
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    model = build_model(cfg)
    batch = _batch(cfg)
    params = model.init(jax.random.key(0))
    tokens = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k in ("vision_embeds", "enc_embeds")}

    if cfg.family == "audio":
        _, cache = jax.jit(lambda p, t, e: model.prefill(p, t, e, pad_to=T + 4))(
            params, tokens, batch["enc_embeds"]
        )
    elif cfg.family in ("ssm", "hybrid"):
        _, cache = jax.jit(lambda p, t: model.prefill(p, t))(params, tokens)
    else:
        _, cache = jax.jit(lambda p, t: model.prefill(p, t, pad_to=T + 4))(params, tokens)

    logits_d, _ = jax.jit(lambda p, c, t: model.decode_step(p, c, t))(
        params, cache, tokens[:, :1]
    )
    toks2 = jnp.concatenate([tokens, tokens[:, :1]], axis=1)
    if cfg.family == "audio":
        full, _ = jax.jit(lambda p, t, e: model.forward(p, t, e))(params, toks2, batch["enc_embeds"])
    elif cfg.family == "vlm":
        full, _ = jax.jit(lambda p, t, v: model.forward(p, t, v))(params, toks2, batch["vision_embeds"])
        logits_d2 = logits_d  # vlm prefill path has no vision in this test; compare plain
        full_plain, _ = jax.jit(lambda p, t: model.forward(p, t))(params, toks2)
        full = full_plain
    else:
        full, _ = jax.jit(lambda p, t: model.forward(p, t))(params, toks2)
    err = float(jnp.max(jnp.abs(full[:, -1] - logits_d)))
    assert err < 5e-2, f"{arch}: decode/forward mismatch {err}"


def test_vocab_padding_never_predicted():
    cfg = get_config("granite-moe-1b-a400m").reduced()  # 49155-style odd vocab
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    logits, _ = model.forward(params, _batch(cfg)["tokens"])
    pad_region = logits[..., cfg.vocab :]
    assert bool(jnp.all(pad_region <= -1e29))


def test_moe_aux_loss_finite_and_positive():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    loss, metrics = model.loss(params, _batch(cfg), remat=False)
    assert bool(jnp.isfinite(metrics["aux_loss"]))
    assert float(metrics["aux_loss"]) >= 0.99  # ≥1 by construction (E·Σf·P ≥ 1)


def test_param_counts_match_published_sizes():
    expect = {
        "command-r-plus-104b": 104e9,
        "phi3-medium-14b": 14e9,
        "llama3-8b": 8e9,
        "qwen3-4b": 4e9,
        "mamba2-1.3b": 1.3e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).params_count()
        assert abs(got - n) / n < 0.12, (arch, got)


def test_moe_active_params():
    cfg = get_config("granite-moe-1b-a400m")
    assert 0.3e9 < cfg.active_params_count() < 0.55e9
    cfg = get_config("qwen2-moe-a2.7b")
    assert 2.0e9 < cfg.active_params_count() < 3.3e9
