"""ShardedDB router internals: placement, the cross-shard batch commit
protocol (intent log, crash replay, truncation), merged-cursor ordering
across shard boundaries, manifest mismatch detection, per-shard cache
budgets. The KVStore-level behaviour shared with ``DB`` lives in
``test_api.py``; the randomized differential proof in
``repro.testing.model_db --shards N``."""
import os

import pytest

from repro.core import (
    DB,
    DBConfig,
    HashPartitioner,
    RangePartitioner,
    ShardedDB,
    WriteBatch,
)
from repro.core.sharded import ROUTER_LOG_NAME, ROUTER_NAME, _RouterLog
from repro.core.env import DEFAULT_ENV


def _cfg(**kw) -> DBConfig:
    base = dict(
        value_threshold=128,
        memtable_size=256 << 10,
        num_bvalue_queues=2,
        block_cache_bytes=4 << 20,
        bvcache_bytes=4 << 20,
    )
    base.update(kw)
    return DBConfig.bvlsm(**base)


def _fill(s, n=60, prefix="k"):
    data = {}
    for i in range(n):
        k = f"{prefix}{i:04d}".encode()
        v = f"v{i}".encode() * (40 if i % 7 == 0 else 1)
        s.put(k, v)
        data[k] = v
    return data


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------
class TestPartitioners:
    def test_hash_is_deterministic_and_spreads(self):
        p = HashPartitioner(4)
        keys = [f"user{i}".encode() for i in range(400)]
        homes = [p.shard_of(k) for k in keys]
        assert homes == [p.shard_of(k) for k in keys]
        counts = [homes.count(i) for i in range(4)]
        assert all(c > 40 for c in counts), counts  # roughly uniform
        # an interval scatters: every shard gets the full range
        assert p.shards_for_range(b"a", b"z") == [
            (i, b"a", b"z") for i in range(4)
        ]

    def test_range_shard_of_and_clipping(self):
        p = RangePartitioner([b"g", b"p"])
        assert p.num_shards == 3
        assert p.shard_of(b"a") == 0
        assert p.shard_of(b"g") == 1  # boundary belongs to the right shard
        assert p.shard_of(b"zz") == 2
        assert p.shards_for_range(b"a", b"c") == [(0, b"a", b"c")]
        assert p.shards_for_range(b"e", b"r") == [
            (0, b"e", b"g"), (1, b"g", b"p"), (2, b"p", b"r"),
        ]
        # end exactly on a boundary: the right-hand shard gets nothing
        assert p.shards_for_range(b"e", b"g") == [(0, b"e", b"g")]
        assert p.shards_for_range(b"g", b"p") == [(1, b"g", b"p")]

    def test_range_boundary_validation(self):
        with pytest.raises(ValueError):
            RangePartitioner([b"p", b"g"])  # unsorted
        with pytest.raises(ValueError):
            ShardedDB.open("unused", shards=3, partitioner="range",
                           boundaries=[b"m"])  # needs shards-1 boundaries
        with pytest.raises(ValueError):
            ShardedDB.open("unused", shards=2, partitioner="nope")

    def test_routing_matches_placement(self, tmp_path):
        s = ShardedDB.open(str(tmp_path / "s"), shards=3, config=_cfg())
        data = _fill(s, 60)
        for k, v in data.items():
            home = s.shard_of(k)
            assert s.shards[home].get(k) == v
            for i, shard in enumerate(s.shards):
                if i != home:
                    assert shard.get(k) is None
        s.close()


# ---------------------------------------------------------------------------
# range-partitioned stores
# ---------------------------------------------------------------------------
class TestRangePartitioned:
    def test_order_and_clipped_delete_range(self, tmp_path):
        s = ShardedDB.open(
            str(tmp_path / "s"), shards=3, config=_cfg(),
            partitioner="range", boundaries=[b"k0020", b"k0040"],
        )
        data = _fill(s, 60)
        assert [k for k, _ in s.range()] == sorted(data)
        # spans shards 1 and 2; shard 0 must see no tombstone at all
        s.delete_range(b"k0030", b"k0050")
        survivors = [k for k in sorted(data)
                     if not (b"k0030" <= k < b"k0050")]
        assert [k for k, _ in s.range()] == survivors
        assert s.shards[0].stats()["user_writes"] == 20  # puts only, no tomb
        # reopen restores the persisted boundaries
        s.close()
        s = ShardedDB.open(str(tmp_path / "s"))
        assert isinstance(s.partitioner, RangePartitioner)
        assert s.partitioner.boundaries == [b"k0020", b"k0040"]
        assert [k for k, _ in s.range()] == survivors
        s.close()

    def test_merged_cursor_walks_across_boundaries(self, tmp_path):
        s = ShardedDB.open(
            str(tmp_path / "s"), shards=2, config=_cfg(),
            partitioner="range", boundaries=[b"k0010"],
        )
        keys = sorted(_fill(s, 20))
        with s.iterator() as cur:
            # forward across the shard boundary
            assert cur.seek(b"k0008") and cur.key == b"k0008"
            walked = [cur.key]
            while len(walked) < 6 and cur.next():
                walked.append(cur.key)
            assert walked == keys[8:14]
            # reverse back across it
            assert cur.prev() and cur.key == b"k0012"
            assert cur.prev() and cur.key == b"k0011"
            assert cur.prev() and cur.key == b"k0010"
            assert cur.prev() and cur.key == b"k0009"
        s.close()


# ---------------------------------------------------------------------------
# cross-shard batch protocol
# ---------------------------------------------------------------------------
class TestCrossShardBatches:
    def test_single_shard_batch_skips_the_log(self, tmp_path):
        s = ShardedDB.open(str(tmp_path / "s"), shards=3, config=_cfg())
        k = b"solo-key"
        wb = WriteBatch().put(k, b"1").put(k, b"2").delete(k)
        s.write(wb)
        st = s.stats()
        assert st["router"]["single_shard_batches"] == 1
        assert st["router"]["cross_shard_batches"] == 0
        assert st["router_log_bytes"] == 0
        s.close()

    def test_torn_batch_completed_at_reopen(self, tmp_path):
        path = str(tmp_path / "s")
        s = ShardedDB.open(path, shards=3, config=_cfg())
        _fill(s, 30)
        # crash between apply and commit: one shard's write dies, the
        # intent is durable, no commit record follows
        victim = s.shards[2]
        victim.write = lambda batch: (_ for _ in ()).throw(
            RuntimeError("simulated crash mid fan-out")
        )
        wb = WriteBatch()
        for i in range(30):
            wb.put(f"k{i:04d}".encode(), b"TORN")
        with pytest.raises(RuntimeError):
            s.write(wb)
        s.close(crash=True)

        s = ShardedDB.open(path, config=_cfg())
        assert s.stats()["router"]["replayed_batches"] == 1
        for i in range(30):
            assert s.get(f"k{i:04d}".encode()) == b"TORN", i
        assert s.stats()["router_log_bytes"] == 0  # truncated after replay
        s.close()
        # second reopen: nothing left to replay
        s = ShardedDB.open(path, config=_cfg())
        assert s.stats()["router"]["replayed_batches"] == 0
        s.close()

    def test_intent_without_commit_in_raw_log(self, tmp_path):
        """Belt and braces: hand-write an intent record (no commit) into
        ROUTER_LOG and check open() applies it — the replay path does not
        depend on how the intent got there."""
        path = str(tmp_path / "s")
        s = ShardedDB.open(path, shards=2, config=_cfg())
        targets = {i: s.shard_of(f"x{i}".encode()) for i in range(12)}
        assert set(targets.values()) == {0, 1}, "want keys on both shards"
        s.close()
        log = _RouterLog(os.path.join(path, ROUTER_LOG_NAME), DEFAULT_ENV)
        ops: dict[int, list] = {}
        for i, shard in targets.items():
            ops.setdefault(shard, []).append([1, b"x%d" % i, b"injected"])
        log.append(
            {"t": "i", "id": 77, "ops": sorted(ops.items())}, sync=True
        )
        log.close()
        s = ShardedDB.open(path, config=_cfg())
        assert s.stats()["router"]["replayed_batches"] == 1
        for i in range(12):
            assert s.get(b"x%d" % i) == b"injected"
        s.close()

    def test_torn_tail_of_log_is_dropped(self, tmp_path):
        path = str(tmp_path / "s")
        s = ShardedDB.open(path, shards=2, config=_cfg())
        s.close()
        with open(os.path.join(path, ROUTER_LOG_NAME), "ab") as f:
            f.write(b"\x01\x02\x03")  # garbage shorter than a frame header
        s = ShardedDB.open(path, config=_cfg())  # must not raise
        assert s.stats()["router"]["replayed_batches"] == 0
        s.put(b"k", b"v")
        assert s.get(b"k") == b"v"
        s.close()

    def test_log_truncates_past_budget(self, tmp_path):
        cfg = _cfg(router_log_max_bytes=2048)
        s = ShardedDB.open(str(tmp_path / "s"), shards=3, config=cfg)
        for round_ in range(8):
            wb = WriteBatch()
            for i in range(30):
                wb.put(f"k{i:04d}".encode(), b"r%d" % round_ + b"x" * 64)
            s.write(wb)
        st = s.stats()
        assert st["router"]["log_truncations"] >= 1
        assert st["router_log_bytes"] <= 2048 + 4096  # at most one batch over
        for i in range(30):
            assert s.get(f"k{i:04d}".encode()).startswith(b"r7")
        s.close()

    def test_async_wal_mode_batches(self, tmp_path):
        s = ShardedDB.open(
            str(tmp_path / "s"), shards=3, config=_cfg(wal_mode="async")
        )
        wb = WriteBatch()
        for i in range(40):
            wb.put(f"k{i:04d}".encode(), b"async")
        s.write(wb)
        assert all(v == b"async" for v in s.multi_get(
            [f"k{i:04d}".encode() for i in range(40)]
        ))
        s.close()


# ---------------------------------------------------------------------------
# manifest / lifecycle
# ---------------------------------------------------------------------------
class TestManifest:
    def test_open_without_shards_on_fresh_path_raises(self, tmp_path):
        with pytest.raises(ValueError, match="pass shards"):
            ShardedDB.open(str(tmp_path / "nope"))

    def test_shard_count_mismatch(self, tmp_path):
        path = str(tmp_path / "s")
        ShardedDB.open(path, shards=4, config=_cfg()).close()
        with pytest.raises(ValueError, match="shard-count mismatch"):
            ShardedDB.open(path, shards=2)
        s = ShardedDB.open(path)  # unspecified adopts the manifest
        assert s.num_shards == 4
        s.close()
        s = ShardedDB.open(path, shards=4)  # matching is fine
        s.close()

    def test_partitioner_mismatch(self, tmp_path):
        path = str(tmp_path / "s")
        ShardedDB.open(path, shards=4, config=_cfg()).close()
        with pytest.raises(ValueError, match="partitioner mismatch"):
            ShardedDB.open(path, partitioner="range", boundaries=None)

    def test_checkpoint_image_is_a_sharded_store(self, tmp_path):
        s = ShardedDB.open(str(tmp_path / "s"), shards=3, config=_cfg())
        data = _fill(s, 40)
        ck = str(tmp_path / "ck")
        s.checkpoint(ck)
        assert os.path.exists(os.path.join(ck, ROUTER_NAME))
        assert not os.path.exists(os.path.join(ck, ROUTER_LOG_NAME))
        s.put(b"later", b"not in image")
        copy = ShardedDB.open(ck)
        assert dict(copy.range()) == data
        copy.close()
        s.close()

    def test_cache_budget_division(self, tmp_path):
        cfg = _cfg(block_cache_bytes=8 << 20, bvcache_bytes=4 << 20)
        s = ShardedDB.open(str(tmp_path / "a"), shards=4, config=cfg)
        assert all(
            sh.cfg.block_cache_bytes == 2 << 20
            and sh.cfg.bvcache_bytes == 1 << 20
            for sh in s.shards
        )
        assert cfg.block_cache_bytes == 8 << 20  # caller's config untouched
        s.close()
        cfg2 = _cfg(shard_divide_cache_budget=False, block_cache_bytes=8 << 20)
        s = ShardedDB.open(str(tmp_path / "b"), shards=4, config=cfg2)
        assert all(sh.cfg.block_cache_bytes == 8 << 20 for sh in s.shards)
        s.close()

    def test_maintenance_fanout(self, tmp_path):
        s = ShardedDB.open(str(tmp_path / "s"), shards=2, config=_cfg())
        for i in range(40):
            s.put(f"k{i:04d}".encode(), b"v" * 300)  # separated values
        for i in range(0, 40, 2):
            s.delete(f"k{i:04d}".encode())
        s.flush()
        s.compact_all()
        s.wait_idle()
        rep = s.gc_collect(threshold=0.01)
        assert len(rep["per_shard"]) == 2
        assert [k for k, _ in s.range()] == [
            f"k{i:04d}".encode() for i in range(1, 40, 2)
        ]
        st = s.stats()
        assert st["aggregate"]["user_writes"] == sum(
            p["user_writes"] for p in st["per_shard"]
        )
        s.close()

    def test_serial_fanout_mode(self, tmp_path):
        s = ShardedDB.open(
            str(tmp_path / "s"), shards=3,
            config=_cfg(router_parallel_fanout=False),
        )
        assert s._pool is None
        data = _fill(s, 30)
        wb = WriteBatch()
        for k in data:
            wb.put(k, b"serial")
        s.write(wb)
        assert all(v == b"serial" for v in s.multi_get(list(data)))
        s.close()
