"""BVLSM checkpoint store: roundtrip, incremental reuse, retention,
corruption detection, elastic resharding, and commit-protocol crash
consistency."""
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.bvstore import BVCheckpointStore
from repro.checkpoint.manager import CheckpointManager


def _state(seed=0, scale=1.0):
    k = jax.random.key(seed)
    return {
        "params": {
            "w1": jax.random.normal(k, (64, 128)) * scale,
            "emb": jax.random.normal(jax.random.fold_in(k, 1), (1000, 32)) * scale,
        },
        "opt": {"m": jnp.zeros((64, 128)), "count": jnp.zeros((), jnp.int32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_load_roundtrip(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    try:
        st = _state()
        store.save(10, st, {"pipeline": {"step": 10, "seed": 0}})
        out, meta = store.load(template=st)
        assert meta["step"] == 10
        assert meta["extra"]["pipeline"]["step"] == 10
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), st, out)
    finally:
        store.close()


def test_latest_and_multiple_steps(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    try:
        for s in (5, 10, 15):
            store.save(s, _state(s))
        assert store.steps() == [5, 10, 15]
        assert store.latest_step() == 15
        out, meta = store.load(10, template=_state())
        assert meta["step"] == 10
    finally:
        store.close()


def test_incremental_reuse(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    try:
        st = _state()
        h1 = store.save(1, st)
        st2 = {**st, "step": jnp.asarray(8, jnp.int32)}  # params unchanged
        store.save(2, st2, prev_hashes=h1)
        meta2 = store.load_meta(2)
        reused = [e for e in meta2["manifest"] if "reuse_step" in e]
        assert len(reused) >= 2  # the unchanged big tensors
        out, _ = store.load(2, template=st2)
        np.testing.assert_array_equal(np.asarray(out["params"]["w1"]), np.asarray(st["params"]["w1"]))
        assert int(out["step"]) == 8
    finally:
        store.close()


def test_corruption_detected_on_read(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    st = _state()
    store.save(1, st)
    store.close()
    # flip a byte in a BValue file
    bdir = os.path.join(str(tmp_path / "ck"), "bvalue")
    target = sorted(
        (os.path.join(bdir, f) for f in os.listdir(bdir)),
        key=os.path.getsize,
    )[-1]
    with open(target, "r+b") as f:
        f.seek(100)
        b = f.read(1)
        f.seek(100)
        f.write(bytes([b[0] ^ 0xFF]))
    # reopen (cold BVCache) with CRC verification on
    store2 = BVCheckpointStore(str(tmp_path / "ck"))
    store2.db.cfg.paranoid_checks = True
    try:
        with pytest.raises(IOError):
            store2.load(1, template=st)
    finally:
        store2.close()


def test_retention_keeps_referenced_chunks(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    mgr = CheckpointManager(store, interval_steps=1, keep_last=2, async_save=False, incremental=True)
    try:
        st = _state()
        for s in range(1, 6):
            st = {**st, "step": jnp.asarray(s, jnp.int32)}
            mgr.save_now(s, st)
        steps = store.steps()
        assert steps[-2:] == [4, 5]
        out, _ = store.load(5, template=st)  # chunks may live in step 1 (reused)
        assert int(out["step"]) == 5
    finally:
        mgr.close()
        store.close()


@pytest.mark.skipif(
    importlib.util.find_spec("repro.dist") is None,
    reason="repro.dist missing from the seed",
)
def test_elastic_reshard_roundtrip(tmp_path):
    """Save on the 'old mesh' (host), restore sharded onto a 1-device mesh."""
    from repro.dist import Axes
    from repro.launch.mesh import make_host_mesh

    store = BVCheckpointStore(str(tmp_path / "ck"))
    try:
        st = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        axes = {"w": Axes("param_embed", "mlp")}
        store.save(3, st)
        mesh = make_host_mesh((1, 1))
        out, meta = store.load_distributed(mesh, st, axes)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(st["w"]))
        assert out["w"].sharding.mesh.shape == dict(mesh.shape)
    finally:
        store.close()


def test_commit_protocol_crash_before_meta(tmp_path):
    """Chunks written but META not committed → checkpoint invisible, store
    healthy (the WAL-time separation commit point)."""
    path = str(tmp_path / "ck")
    store = BVCheckpointStore(path)
    st = _state()
    store.save(1, st)
    # simulate crash mid-save of step 2: write chunks only, no META, crash
    leaf = np.asarray(st["params"]["w1"])
    store.db.put(store._chunk_key(2, "['params']['w1']", 0), leaf.tobytes())
    store.db.close(crash=True)

    store2 = BVCheckpointStore(path)
    try:
        assert store2.latest_step() == 1  # step-2 orphan chunks are invisible
        out, _ = store2.load(template=st)
        np.testing.assert_array_equal(np.asarray(out["params"]["w1"]), leaf)
    finally:
        store2.close()


def test_async_manager_overlap(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "ck"))
    mgr = CheckpointManager(store, interval_steps=1, keep_last=3, async_save=True)
    try:
        st = _state()
        mgr.save_now(1, st)
        mgr.save_now(2, st)  # waits for 1, then async 2
        mgr.wait()
        assert store.latest_step() == 2
        assert mgr.save_count == 2
    finally:
        mgr.close()
        store.close()


def test_retention_uses_range_tombstones(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "store"), num_queues=2)
    state = {"w": np.arange(4096, dtype=np.float32)}
    for step in (1, 2, 3):
        store.save(step, state)
    assert store.steps() == [1, 2, 3]
    store.delete_step(1)
    assert store.steps() == [2, 3]
    # chunks of the deleted step are unreadable, survivors untouched
    assert store.db.get(store._chunk_key(1, "['w']", 0)) is None
    loaded, _ = store.load(3)
    np.testing.assert_array_equal(loaded["['w']"], state["w"])
    with pytest.raises(KeyError):
        store.delete_step(99)
    store.close()


def test_online_backup_opens_as_store(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "store"), num_queues=2)
    state = {"w": np.arange(8192, dtype=np.float32),
             "b": np.ones(16, dtype=np.float32)}
    store.save(10, state)
    bdir = store.backup(str(tmp_path / "bak"))
    # mutate the source AFTER the backup: the image must not move
    store.save(20, {"w": state["w"] * 2, "b": state["b"]})
    store.delete_step(10)
    bak = BVCheckpointStore(bdir, num_queues=2)
    assert bak.latest_step() == 10
    loaded, meta = bak.load(10)
    np.testing.assert_array_equal(loaded["['w']"], state["w"])
    bak.close()
    assert store.steps() == [20]
    store.close()


def test_manager_backup_waits_for_inflight_save(tmp_path):
    store = BVCheckpointStore(str(tmp_path / "store"), num_queues=2)
    mgr = CheckpointManager(store, interval_steps=1, async_save=True)
    state = {"w": np.arange(4096, dtype=np.float32)}
    mgr.maybe_save(1, state)  # async: may still be in flight
    bdir = mgr.backup(str(tmp_path / "bak"))
    bak = BVCheckpointStore(bdir, num_queues=2)
    assert bak.latest_step() == 1  # the in-flight save is IN the image
    bak.close()
    mgr.close()
    store.close()
