import os

# Smoke tests and benches must see the REAL device count (1 CPU); only
# launch/dryrun.py sets the 512-device flag (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import importlib.util
import warnings

import pytest

# Seed gap: some test modules need deps this container doesn't have
# (`hypothesis` is not installed). Gate them at collection so the rest of
# the suite still runs — remove entries as the gaps are filled in.
# (`repro.dist` was restored in PR 2; its former gate entries are gone.)
_GATED = {
    "hypothesis": ["test_optimizer.py", "test_serving.py"],
}
collect_ignore = []
for _mod, _files in _GATED.items():
    try:
        _found = importlib.util.find_spec(_mod) is not None
    except ModuleNotFoundError:
        _found = False
    if not _found:
        collect_ignore.extend(_files)
        warnings.warn(f"skipping {_files}: module {_mod!r} unavailable")


@pytest.fixture()
def tmp_db_dir(tmp_path):
    return str(tmp_path / "db")
