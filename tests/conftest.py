import os

# Smoke tests and benches must see the REAL device count (1 CPU); only
# launch/dryrun.py sets the 512-device flag (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest


@pytest.fixture()
def tmp_db_dir(tmp_path):
    return str(tmp_path / "db")
