"""Paged KV allocator invariants (hypothesis), host page cache semantics,
and the continuous-batching engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import HostPageCache, OutOfPages, PagedKVCache


def _kv(num_pages=16, page=8, maxp=4):
    return PagedKVCache(num_pages, page, n_layers=2, n_kv_heads=2, head_dim=8,
                        max_pages_per_seq=maxp)


def test_alloc_free_roundtrip():
    kv = _kv()
    kv.admit(1, prompt_len=20)  # 3 pages at page=8
    assert len(kv.seqs[1].pages) == 3
    assert kv.utilization() == 3 / 16
    kv.release(1)
    assert kv.utilization() == 0.0


def test_out_of_pages():
    kv = _kv(num_pages=4, maxp=8)
    kv.admit(1, prompt_len=30)  # needs 4 pages
    kv.admit(2)
    with pytest.raises(OutOfPages):
        kv.reserve(2, 10)


def test_page_table_and_lengths():
    kv = _kv()
    kv.admit(7, prompt_len=10)
    kv.admit(9, prompt_len=3)
    pt = kv.page_table([7, 9])
    assert pt.shape == (2, 4)
    assert (kv.lengths([7, 9]) == np.array([10, 3])).all()
    # no page shared between sequences
    assert set(kv.seqs[7].pages).isdisjoint(kv.seqs[9].pages)


@settings(max_examples=30, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["admit", "reserve", "release"]), st.integers(0, 5), st.integers(1, 12)),
        max_size=60,
    )
)
def test_allocator_invariants(ops):
    """No page is ever owned by two sequences; free+owned == total."""
    kv = _kv(num_pages=12, page=4, maxp=6)
    for op, sid, n in ops:
        try:
            if op == "admit" and sid not in kv.seqs:
                kv.admit(sid)
            elif op == "reserve" and sid in kv.seqs:
                kv.reserve(sid, n)
            elif op == "release" and sid in kv.seqs:
                kv.release(sid)
        except OutOfPages:
            pass
        owned = [p for s in kv.seqs.values() for p in s.pages]
        assert len(owned) == len(set(owned))  # no double allocation
        assert sorted(owned + kv.free) == list(range(12))  # conservation


def test_host_page_cache_mrwf_pin():
    c = HostPageCache(capacity_pages=2)
    c.put(("s1", 0), np.zeros(4), pinned=True)
    c.put(("s1", 1), np.ones(4))
    c.put(("s1", 2), np.ones(4) * 2)  # evicts (s1,1) — (s1,0) pinned
    assert ("s1", 0) in c._map  # pinned survives
    assert ("s1", 1) not in c._map
    c.unpin(("s1", 0))
    c.put(("s1", 3), np.ones(4) * 3)
    assert ("s1", 0) not in c._map  # LRU + unpinned → evicted


def test_engine_end_to_end():
    cfg = get_config("llama3-8b").reduced(d_model=64, n_layers=2, vocab=256, vocab_pad_multiple=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=16)
    rng = np.random.default_rng(0)
    for rid in range(5):
        engine.submit(Request(rid, rng.integers(1, cfg.vocab, 8).astype(np.int32), max_new_tokens=6))
    done = engine.run_until_drained()
    assert len(done) == 5
    assert all(len(r.tokens) == 6 for r in done)
    m = engine.metrics()
    assert m["tokens"] == 30
    assert engine.kv.utilization() == 0.0  # everything freed


def test_engine_greedy_matches_manual_decode():
    """Engine tokens == manual prefill+decode_step loop (same params)."""
    cfg = get_config("llama3-8b").reduced(d_model=64, n_layers=2, vocab=256, vocab_pad_multiple=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    prompt = np.arange(1, 9, dtype=np.int32)

    engine = ServingEngine(cfg, params, max_batch=1, max_len=64, page_size=16)
    engine.submit(Request(0, prompt, max_new_tokens=5))
    (req,) = engine.run_until_drained()

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], pad_to=64)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    assert req.tokens == toks
