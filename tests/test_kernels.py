"""Per-kernel interpret-mode sweeps against the pure-jnp oracles in
kernels/ref.py — shapes × dtypes per the deliverable contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (
    mha_reference,
    paged_decode_reference,
    rglru_reference,
    ssd_chunk_reference,
)
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd_scan import ssd_chunked_pallas

RNG = np.random.default_rng(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,K,hd,causal,window",
    [
        (2, 256, 8, 4, 64, True, None),   # GQA
        (1, 384, 4, 1, 128, True, None),  # MQA
        (2, 256, 8, 8, 64, False, None),  # MHA bidirectional (whisper enc)
        (1, 512, 4, 2, 64, True, 128),    # sliding window (recurrentgemma)
        (1, 200, 4, 2, 64, True, None),   # unaligned T (padding path)
        (1, 256, 2, 2, 32, True, None),   # small head_dim
    ],
)
def test_flash_attention_sweep(B, T, H, K, hd, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, T, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, T, K, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=_tol(dtype), rtol=1e-2
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,hd,P,page,maxp",
    [(2, 8, 4, 64, 16, 128, 4), (4, 4, 1, 128, 32, 128, 6), (2, 16, 8, 64, 16, 256, 3)],
)
def test_paged_decode_sweep(B, H, K, hd, P, page, maxp, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, hd)), dtype)
    pk = jnp.asarray(RNG.normal(size=(P, page, K, hd)), dtype)
    pv = jnp.asarray(RNG.normal(size=(P, page, K, hd)), dtype)
    pt = jnp.asarray(RNG.integers(0, P, size=(B, maxp)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, maxp * page, size=(B,)), jnp.int32)
    out = paged_decode_attention(q, pk, pv, pt, lengths, interpret=True)
    ref = paged_decode_reference(q, pk, pv, pt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=_tol(dtype), rtol=1e-2
    )


@pytest.mark.parametrize(
    "b,t,h,p,n,chunk",
    [(1, 128, 4, 32, 64, 32), (2, 256, 2, 64, 128, 64), (1, 64, 8, 16, 32, 64)],
)
def test_ssd_chunk_sweep(b, t, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, t, h, p)), jnp.float32)
    dA = -jnp.abs(jnp.asarray(RNG.normal(size=(b, t, h)), jnp.float32)) * 0.3
    B_ = jnp.asarray(RNG.normal(size=(b, t, 1, n)), jnp.float32)
    C_ = jnp.asarray(RNG.normal(size=(b, t, 1, n)), jnp.float32)
    y, st = ssd_chunked_pallas(x, dA, B_, C_, chunk, interpret=True)
    yr, sr = ssd_chunk_reference(x, dA, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,W,bt,bw", [(2, 128, 256, 64, 128), (1, 256, 512, 128, 256)])
def test_rglru_sweep(B, T, W, bt, bw, dtype):
    x = jnp.asarray(RNG.normal(size=(B, T, W)), dtype)
    r = jnp.asarray(RNG.uniform(size=(B, T, W)), dtype)
    i = jnp.asarray(RNG.uniform(size=(B, T, W)), dtype)
    lam = jnp.asarray(RNG.uniform(0.5, 4.0, size=(W,)), jnp.float32)
    y, h = rglru_pallas(x, r, i, lam, block_t=bt, block_w=bw, interpret=True)
    yr, hr = rglru_reference(x, r, i, lam)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=_tol(dtype), rtol=1e-2
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=_tol(dtype), rtol=1e-2)


def test_rglru_carried_state():
    """State h0 threads correctly across two kernel invocations."""
    B, T, W = 1, 64, 128
    x = jnp.asarray(RNG.normal(size=(B, 2 * T, W)), jnp.float32)
    r = jnp.asarray(RNG.uniform(size=(B, 2 * T, W)), jnp.float32)
    i = jnp.asarray(RNG.uniform(size=(B, 2 * T, W)), jnp.float32)
    lam = jnp.asarray(RNG.uniform(0.5, 4.0, size=(W,)), jnp.float32)
    y1, h1 = rglru_pallas(x[:, :T], r[:, :T], i[:, :T], lam, block_t=64, block_w=128, interpret=True)
    y2, h2 = rglru_pallas(x[:, T:], r[:, T:], i[:, T:], lam, h0=h1, block_t=64, block_w=128, interpret=True)
    yr, hr = rglru_reference(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hr), atol=1e-5)
