"""Background job scheduler: parallel subcompactions, per-file compaction
locks, crash atomicity of the single manifest edit, the shared background
I/O rate limiter, the delayed-write controller, and auto-GC scheduling."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import DB, DBConfig
from repro.core.compaction import Compactor
from repro.core.ratelimiter import PRI_HIGH, PRI_LOW, RateLimiter
from repro.core.scheduler import WriteController
from repro.core.sstable import FileMetadata


def _db(tmp, **kw):
    cfg = dict(
        separation_mode="wal",
        wal_mode="sync",
        memtable_size=64 << 10,
        value_threshold=4096,
        level1_max_bytes=128 << 10,
        l0_compaction_trigger=2,
        max_subcompactions=3,
        background_threads=2,
        # test DBs are tiny: scale the adaptive-shard floor down so
        # multi-file compactions still fan out at this size
        subcompaction_min_bytes=32 << 10,
    )
    cfg.update(kw)
    return DB(tmp, DBConfig(**cfg))


def _fill(db, n, value_size=512, seed=0, prefix="k"):
    rng = np.random.default_rng(seed)
    vals = {}
    for i in range(n):
        k = f"{prefix}{i:06d}".encode()
        v = rng.bytes(value_size)
        db.put(k, v)
        vals[k] = v
    return vals


def _sst_files(path):
    return {int(f[:-4]) for f in os.listdir(path) if f.endswith(".sst")}


# ---------------------------------------------------------------------------
# parallel subcompactions
# ---------------------------------------------------------------------------
def test_subcompactions_split_and_preserve_reads(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        vals = _fill(db, 1500, value_size=512)
        vals.update(_fill(db, 1500, value_size=512, seed=1))  # overwrite all
        db.flush()
        db.compact_all()
        st = db.stats.snapshot()
        assert st["compaction_count"] >= 1
        # the workload spans many input files, so at least one compaction
        # must have fanned out into range shards
        assert st["subcompactions"] >= 2, st["subcompactions"]
        for k, v in vals.items():
            assert db.get(k) == v, k
        # merged view stays sorted and deduped across shard boundaries
        out = list(db.range(limit=5000))
        keys = [k for k, _ in out]
        assert keys == sorted(set(keys))
        assert len(keys) == 1500
    finally:
        db.close()


def test_subcompaction_bounds_partition_key_space():
    class _FakeDB:
        pass

    comp = Compactor(_FakeDB())
    files = [
        FileMetadata(i, 1000, f"{i:02d}a".encode(), f"{i:02d}z".encode(), 10)
        for i in range(8)
    ]
    bounds = comp._subcompaction_bounds(files[:2], files[2:], 4)
    assert 1 <= len(bounds) <= 3
    assert bounds == sorted(bounds)
    assert len(set(bounds)) == len(bounds)
    # every bound is a real file boundary inside the key span
    starts = {f.smallest for f in files}
    assert all(b in starts for b in bounds)
    assert comp._subcompaction_bounds(files[:1], [], 4) == []  # single file
    assert comp._subcompaction_bounds(files[:2], files[2:], 1) == []  # disabled


# ---------------------------------------------------------------------------
# per-file compaction locks / concurrent jobs
# ---------------------------------------------------------------------------
def test_concurrent_compaction_inputs_never_overlap(tmp_db_dir):
    db = _db(tmp_db_dir, background_threads=3, memtable_size=32 << 10)
    inflight: set[int] = set()
    overlap_errors: list[str] = []
    lock = threading.Lock()
    real_run = Compactor.run

    def spying_run(self, level, inputs, overlaps, subtasks=None):
        nos = {f.file_no for f in inputs + overlaps}
        with lock:
            if inflight & nos:
                overlap_errors.append(f"overlap: {inflight & nos}")
            inflight.update(nos)
        try:
            return real_run(self, level, inputs, overlaps, subtasks=subtasks)
        finally:
            with lock:
                inflight.difference_update(nos)

    Compactor.run = spying_run
    try:
        for round_ in range(3):
            _fill(db, 1200, value_size=256, seed=round_)
            db.flush()
        db.compact_all()
        assert not overlap_errors, overlap_errors
        assert db.stats.snapshot()["compaction_count"] >= 2
    finally:
        Compactor.run = real_run
        db.close()


def test_pick_skips_locked_files(tmp_db_dir):
    # trigger=100 keeps the event-driven scheduler from compacting L0 away
    # while we fill; lowering it afterwards makes the files pickable
    db = _db(tmp_db_dir, l0_compaction_trigger=100)
    try:
        _fill(db, 600, value_size=512)
        db.flush()  # several L0 files exist
        db.cfg.l0_compaction_trigger = 2
        comp = db.bg.compactor
        picked = comp.pick(db.versions.locked_files())
        assert picked is not None
        level, inputs, overlaps = picked
        nos = [f.file_no for f in inputs + overlaps]
        assert db.versions.try_lock_files(nos)
        # all of L0 is locked now: no second L0 job may form, and the lock
        # acquisition itself is all-or-nothing
        assert comp.pick(db.versions.locked_files()) is None
        assert not db.versions.try_lock_files([nos[0]])
        db.versions.unlock_files(nos)
        assert comp.pick(db.versions.locked_files()) is not None
    finally:
        db.close()


# ---------------------------------------------------------------------------
# crash atomicity
# ---------------------------------------------------------------------------
def test_crash_mid_subcompaction_keeps_manifest_atomic(tmp_db_dir):
    # hold compaction off (trigger=100) until the failure hook is armed
    db = _db(tmp_db_dir, l0_compaction_trigger=100)
    vals = _fill(db, 1200, value_size=512)
    db.flush()
    tables_before = _sst_files(tmp_db_dir)
    assert len(tables_before) >= 2

    real_range = Compactor._run_range
    fail = {"armed": True}

    def failing_range(self, level, inputs, overlaps, lo, hi, bottom, fill):
        if fail["armed"] and lo is not None:  # die in a non-first shard
            raise RuntimeError("injected subcompaction crash")
        return real_range(self, level, inputs, overlaps, lo, hi, bottom, fill)

    Compactor._run_range = failing_range
    try:
        db.cfg.l0_compaction_trigger = 2
        with pytest.raises((TimeoutError, RuntimeError)):
            db.compact_all()  # surfaces the background job error
    finally:
        Compactor._run_range = real_range
        fail["armed"] = False
        db.close(crash=True)

    # the failed compaction must not have touched the manifest, and the
    # reopen sweep must leave a consistent directory: every referenced
    # table present, every orphan shard output gone
    db2 = _db(tmp_db_dir, l0_compaction_trigger=100)
    try:
        live = {f.file_no for lv in db2.versions.current.levels for f in lv}
        on_disk = _sst_files(tmp_db_dir)
        assert live == on_disk, (live, on_disk)
        assert live == tables_before, (live, tables_before)
        for k, v in vals.items():
            assert db2.get(k) == v, k
        db2.cfg.l0_compaction_trigger = 2
        db2.compact_all()  # and compaction completes cleanly afterwards
        for k, v in vals.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


def test_orphan_sstables_swept_on_open(tmp_db_dir):
    db = _db(tmp_db_dir)
    vals = _fill(db, 200, value_size=512)
    db.flush()
    db.close()
    orphan = os.path.join(tmp_db_dir, "999123.sst")
    with open(orphan, "wb") as f:
        f.write(b"half-written subcompaction output")
    db2 = _db(tmp_db_dir)
    try:
        assert not os.path.exists(orphan)
        # the swept number can never be reissued and collide
        assert db2.versions.next_file_no > 999123
        for k, v in vals.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


# ---------------------------------------------------------------------------
# condition-variable idle signalling
# ---------------------------------------------------------------------------
def test_wait_idle_returns_promptly_and_quiesces(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        _fill(db, 800, value_size=512)
        db.flush()
        db.compact_all()
        with db.mutex:
            assert not db.immutables
        assert db.bg.sched.outstanding() == 0
        assert db.bg.compactor.pick(db.versions.locked_files()) is None
        # an idle DB answers wait_idle in CV time, not poll time
        t0 = time.monotonic()
        for _ in range(20):
            db.wait_idle()
        assert time.monotonic() - t0 < 1.0
    finally:
        db.close()


def test_background_error_surfaces_to_writers(tmp_db_dir):
    db = _db(tmp_db_dir)
    real_flush = Compactor.flush_memtable
    Compactor.flush_memtable = lambda self, mem: (_ for _ in ()).throw(
        RuntimeError("injected flush failure")
    )
    try:
        with pytest.raises(RuntimeError):
            _fill(db, 4000, value_size=512)  # rotation → failing flush job
            db.wait_idle(timeout=10)
    finally:
        Compactor.flush_memtable = real_flush
        db.close(crash=True)


# ---------------------------------------------------------------------------
# rate limiter
# ---------------------------------------------------------------------------
def test_rate_limiter_paces_throughput():
    rl = RateLimiter(1 << 20, refill_period_s=0.002)  # 1 MiB/s
    t0 = time.monotonic()
    for _ in range(4):
        rl.request(128 << 10, PRI_LOW)  # 512 KiB total ≈ 0.5 s
    dt = time.monotonic() - t0
    assert 0.25 <= dt <= 2.0, dt


def test_rate_limiter_disabled_is_free():
    rl = RateLimiter(0)
    t0 = time.monotonic()
    for _ in range(10_000):
        rl.request(1 << 20, PRI_LOW)
    assert time.monotonic() - t0 < 0.5


def test_rate_limiter_high_priority_served_first():
    rl = RateLimiter(256 << 10, refill_period_s=0.002)  # slow: 256 KiB/s
    order: list[str] = []
    rl.request(128 << 10, PRI_LOW)  # drain the bucket into deficit
    low = threading.Thread(
        target=lambda: (rl.request(64 << 10, PRI_LOW), order.append("low"))
    )
    low.start()
    time.sleep(0.05)  # LOW is queued and waiting on the deficit
    high = threading.Thread(
        target=lambda: (rl.request(64 << 10, PRI_HIGH), order.append("high"))
    )
    high.start()
    low.join(timeout=10)
    high.join(timeout=10)
    assert order and order[0] == "high", order


def test_compaction_draws_from_limiter(tmp_db_dir):
    from repro.core.ratelimiter import PRI_HIGH as _HI

    # trigger=100 holds compaction until the deficit below is in place
    db = _db(tmp_db_dir, bg_io_bytes_per_sec=1 << 20, l0_compaction_trigger=100)
    try:
        _fill(db, 1000, value_size=512)
        db.flush()
        # drive the bucket into a deterministic deficit (HIGH charges are
        # accounted but never block); the compaction's LOW requests must
        # then wait for the refill regardless of machine speed
        db.rate_limiter.request(2 << 20, _HI)
        db.cfg.l0_compaction_trigger = 2
        db.compact_all()
        st = db.stats.snapshot()
        assert st["rate_limiter_waits"] >= 1, st["rate_limiter_waits"]
        assert st["rate_limiter_wait_seconds"] > 0
    finally:
        db.close()


# ---------------------------------------------------------------------------
# delayed-write controller
# ---------------------------------------------------------------------------
def test_write_controller_ramps_and_recovers():
    cfg = DBConfig()
    wc = WriteController(cfg)
    # below the slowdown region: free
    assert wc.delay_for(cfg.l0_slowdown_trigger - 1, 0, 1 << 20) == 0.0
    # entering the region: delay at the full delayed rate
    d0 = wc.delay_for(cfg.l0_slowdown_trigger, 0, 1 << 20)
    assert d0 == pytest.approx((1 << 20) / cfg.delayed_write_rate)
    # backlog worsening: rate decays, delay grows monotonically
    d1 = wc.delay_for(cfg.l0_slowdown_trigger + 1, 0, 1 << 20)
    d2 = wc.delay_for(cfg.l0_slowdown_trigger + 2, 0, 1 << 20)
    assert d2 > d1 > d0
    # unchanged backlog = sustained pressure: the rate HOLDS (recovering
    # between flush edges would reintroduce the on/off oscillation)
    d2b = wc.delay_for(cfg.l0_slowdown_trigger + 2, 0, 1 << 20)
    assert d2b == pytest.approx(d2)
    # improving: rate recovers, delay shrinks
    d3 = wc.delay_for(cfg.l0_slowdown_trigger, 0, 1 << 20)
    assert d3 < d2
    # leaving the region resets to free
    assert wc.delay_for(0, 0, 1 << 20) == 0.0
    # delay is charged per byte
    wc2 = WriteController(cfg)
    small = wc2.delay_for(cfg.l0_slowdown_trigger, 0, 4 << 10)
    assert small < d0


def test_writers_record_smooth_delays_not_just_stops(tmp_db_dir):
    # slowdown=1 < compaction trigger=2: after the first flush, L0 holds a
    # file that no compaction will clear, so every commit sits in the
    # delay region — deterministic controller engagement, no stop stalls
    db = _db(
        tmp_db_dir,
        memtable_size=16 << 10,
        l0_compaction_trigger=2,
        l0_slowdown_trigger=1,
        l0_stop_trigger=20,
        delayed_write_rate=4 << 20,
    )
    try:
        _fill(db, 400, value_size=256)
        st = db.stats.snapshot()
        assert st.get("stall_delay_seconds", 0) > 0, st
        assert st["stall_hist"], st
    finally:
        db.close()


# ---------------------------------------------------------------------------
# auto-GC scheduling
# ---------------------------------------------------------------------------
def test_auto_gc_triggers_after_compaction(tmp_db_dir):
    db = _db(
        tmp_db_dir,
        value_threshold=512,
        bvalue_max_file_bytes=32 << 10,
        gc_auto=True,
        gc_dead_ratio_trigger=0.4,
    )
    try:
        vals = {}
        rng = np.random.default_rng(0)
        for _round in range(3):  # supersede everything repeatedly
            for i in range(120):
                k = f"k{i:04d}".encode()
                v = rng.bytes(2048)
                db.put(k, v)
                vals[k] = v
        db.flush()
        db.compact_all()  # drops dead pointers → dead ratios rise → GC job
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if db.stats.snapshot()["job_gc_count"] >= 1:
                break
            db.wait_idle()
            time.sleep(0.01)
        st = db.stats.snapshot()
        assert st["job_gc_count"] >= 1, st
        for k, v in vals.items():
            assert db.get(k) == v, k
    finally:
        db.close()


def test_pick_never_truncates_overlaps(tmp_db_dir):
    db = _db(tmp_db_dir)
    try:
        # build a structure with two adjacent populated levels; compaction
        # job interleaving is nondeterministic, so compact_all sometimes
        # settles everything into ONE level — keep feeding fresh keyspace
        # until an adjacent pair exists
        level = None
        for round_ in range(6):
            _fill(db, 1500, value_size=512, seed=round_, prefix=f"r{round_}/")
            db.flush()
            db.compact_all()
            v = db.versions.current
            level = next(
                (l for l in range(1, len(v.levels) - 1) if v.levels[l] and v.levels[l + 1]),
                None,
            )
            if level is not None:
                break
        assert level is not None, [len(lv) for lv in v.levels]
        # an absurdly small cap must steer the pick, never shrink the
        # overlap set — a truncated set would leave the merged output
        # overlapping the dropped Ln+1 files (stale reads)
        db.cfg.max_compaction_input_bytes = 1
        picked = db.bg.compactor._pick_level(v, level, frozenset())
        assert picked is not None
        _lvl, inputs, overlaps = picked
        full = v.files_touching(level + 1, inputs[0].smallest, inputs[0].largest)
        assert [f.file_no for f in overlaps] == [f.file_no for f in full]
    finally:
        db.close()


def test_precondition_sees_pending_pipelined_groups(tmp_db_dir):
    from repro.core.db import _Group, _Writer
    from repro.core.record import kTypeValue

    db = _db(tmp_db_dir)
    try:
        db.put(b"k", b"old")
        # simulate a seq-assigned but not-yet-published pipelined group
        # carrying a newer write of "k": the conditional batch must be
        # skipped even though the memtable/version check still passes
        pend = _Group([_Writer([(kTypeValue, b"k", b"new")], 4)])
        with db.mutex:
            db._pending.append(pend)
            w = _Writer([(kTypeValue, b"k", b"stale")], 6, precondition=lambda: True)
            db._check_preconditions_locked([w])
            popped = db._pending.pop()
            assert popped is pend
        assert w.skipped and w.entries == []
        # an unrelated key is unaffected by the pending group
        w2 = _Writer([(kTypeValue, b"other", b"x")], 6, precondition=lambda: True)
        with db.mutex:
            db._check_preconditions_locked([w2])
        assert not w2.skipped
    finally:
        db.close()


def test_conditional_commit_skips_when_precondition_fails(tmp_db_dir):
    from repro.core.record import kTypeValue

    db = _db(tmp_db_dir)
    try:
        db.put(b"k", b"v1")
        assert db._commit([(kTypeValue, b"k", b"stale")], precondition=lambda: False) is False
        assert db.get(b"k") == b"v1"
        assert db._commit([(kTypeValue, b"k", b"v2")], precondition=lambda: True) is True
        assert db.get(b"k") == b"v2"
    finally:
        db.close()


def test_gc_never_resurrects_concurrent_overwrite(tmp_db_dir):
    db = _db(tmp_db_dir, value_threshold=512, bvalue_max_file_bytes=16 << 10)
    try:
        for i in range(40):
            db.put(f"g{i:03d}".encode(), b"A" * 2048)
        for i in range(40):
            if i != 7:
                db.put(f"g{i:03d}".encode(), b"B" * 2048)
        db.flush()
        db.compact_all()
        # g007's only version is the old "A" value: GC will try to rewrite
        # it. Interleave a foreground overwrite between GC's value read and
        # its conditional re-insert — the precondition must drop the stale
        # rewrite instead of resurrecting it over the newer value.
        real_get = db.bvalue.get

        def racing_get(voff, **kw):
            v = real_get(voff, **kw)
            if v == b"A" * 2048:
                db.put(b"g007", b"C" * 2048)
            return v

        db.bvalue.get = racing_get
        try:
            db.gc_collect(threshold=0.0)
        finally:
            db.bvalue.get = real_get
        assert db.get(b"g007") == b"C" * 2048
    finally:
        db.close()


def test_manual_gc_still_synchronous(tmp_db_dir):
    db = _db(tmp_db_dir, value_threshold=512, bvalue_max_file_bytes=16 << 10)
    try:
        for i in range(60):
            db.put(f"g{i:03d}".encode(), b"A" * 2048)
        for i in range(60):
            db.put(f"g{i:03d}".encode(), b"B" * 2048)
        db.flush()
        db.compact_all()
        stats = db.gc_collect(threshold=0.3)
        assert stats["collected_files"] >= 1, stats
        for i in range(60):
            assert db.get(f"g{i:03d}".encode()) == b"B" * 2048
    finally:
        db.close()
