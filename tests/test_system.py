"""End-to-end system behaviour: train → checkpoint → crash → restore →
serve, exercising every layer of the stack together, plus cell-spec
contracts used by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeCell
from repro.launch.analytic import analytic_memory_bytes, model_flops
from repro.launch.specs import auto_accum_steps, batch_specs, input_specs
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig

CFG = get_config("llama3-8b").reduced(d_model=64, n_layers=2, vocab=512, vocab_pad_multiple=64)


def test_train_crash_restore_serve(tmp_path):
    """The full lifecycle on one model."""
    d = str(tmp_path / "ck")
    tcfg = TrainerConfig(
        steps=8, global_batch=2, seq_len=32, ckpt_dir=d, ckpt_interval=4,
        log_every=10_000,
        train=TrainConfig(opt=OptimizerConfig(warmup_steps=2, total_steps=50)),
    )
    tr = Trainer(CFG, tcfg)
    res = tr.run()
    assert res["status"] == "done"
    losses = [m["loss"] for m in res["metrics"]]
    assert losses[-1] < losses[0]  # it learns
    params = jax.device_get(tr.state["params"])
    tr.store.db.close(crash=True)  # hard crash of the storage engine

    # restore into a fresh trainer (recovery path) and serve with the params
    tr2 = Trainer(CFG, tcfg)
    start = tr2._init_or_restore()
    assert start == 8
    p2 = jax.device_get(tr2.state["params"])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    engine = ServingEngine(CFG, tr2.state["params"], max_batch=2, max_len=64, page_size=16)
    engine.submit(Request(0, np.arange(1, 9, dtype=np.int32), max_new_tokens=4))
    done = engine.run_until_drained()
    assert len(done[0].tokens) == 4
    tr2.close()


def test_input_specs_contract():
    """input_specs returns weak-type-correct, shardable stand-ins for every
    (arch × shape) cell — the dry-run contract."""
    for arch in ("llama3-8b", "whisper-small", "internvl2-76b", "mamba2-1.3b"):
        cfg = get_config(arch)
        for cell in SHAPES.values():
            ok, _ = cfg.shape_supported(cell)
            if not ok:
                continue
            specs = input_specs(cfg, cell)
            assert "tokens" in specs
            t = specs["tokens"]
            assert t.dtype == jnp.int32
            if cell.kind == "decode":
                assert t.shape == (cell.global_batch, 1)
            else:
                assert t.shape == (cell.global_batch, cell.seq_len)
            if cell.kind != "decode":
                if cfg.family == "audio":
                    assert "enc_embeds" in batch_specs(cfg, cell)[0]
                if cfg.family == "vlm":
                    assert "vision_embeds" in batch_specs(cfg, cell)[0]


def test_auto_accum_bounds_microbatch_tokens():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # 256 seqs × 4096 → local 16 seqs; accum 8 → 2×4096 = 8192 tokens ✓
    assert auto_accum_steps(FakeMesh(), 256, 4096) == 8
    assert auto_accum_steps(FakeMesh(), 256, 8192) == 16
    assert auto_accum_steps(FakeMesh(), 16, 512) == 1


def test_analytic_model_sane():
    cfg = get_config("llama3-8b")
    mesh = {"data": 16, "model": 16}
    tr = SHAPES["train_4k"]
    f = model_flops(cfg, tr)
    assert 0.9 * 6 * 8e9 * 1048576 < f < 1.5 * 6 * 8e9 * 1048576
    m = analytic_memory_bytes(cfg, tr, mesh, accum=8)
    assert 1e9 < m < 1e12  # per-chip, plausible range
    de = SHAPES["decode_32k"]
    f_de = model_flops(cfg, de)
    assert f_de < f / 1000  # decode step ≪ train step


def test_all_arch_cells_have_verdict():
    """Every (arch × shape) is either supported or explicitly skipped."""
    from repro.configs import ARCH_IDS, all_configs

    n_run = n_skip = 0
    for arch, cfg in all_configs().items():
        for cell in SHAPES.values():
            ok, why = cfg.shape_supported(cell)
            if ok:
                n_run += 1
            else:
                assert "skip" in why
                n_skip += 1
    assert n_run + n_skip == 40
    assert n_skip == 8  # 8 full-attention archs × long_500k


def test_pipeline_host_sharding():
    from repro.data.pipeline import TokenPipeline

    p0 = TokenPipeline(512, 8, 16, seed=1, host=0, num_hosts=2)
    p1 = TokenPipeline(512, 8, 16, seed=1, host=1, num_hosts=2)
    b0, b1 = p0.next_batch(), p1.next_batch()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # different shards
