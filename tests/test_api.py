"""KVStore conformance: every behavioural contract of the client surface,
parameterized over BOTH implementations (one ``DB`` engine, a 3-shard
``ShardedDB``) — the protocol is only real if the same test body passes
against each."""
import warnings

import pytest

from repro.core import DB, DBConfig, KVStore, ShardedDB, WriteBatch

BIG = 4096  # >= value_threshold below → separated values exercised too


def _cfg() -> DBConfig:
    return DBConfig.bvlsm(
        value_threshold=256,
        memtable_size=256 << 10,
        num_bvalue_queues=2,
        block_cache_bytes=1 << 20,
        bvcache_bytes=1 << 20,
    )


@pytest.fixture(params=["db", "sharded"])
def store(request, tmp_path):
    path = str(tmp_path / "store")
    if request.param == "db":
        s = DB.open(path, _cfg())
    else:
        s = ShardedDB.open(path, shards=3, config=_cfg())
    yield s
    s.close()


def _reopen(store, path):
    store.close()
    if isinstance(store, ShardedDB):
        return ShardedDB.open(path)  # count/partitioner come from ROUTER
    return DB.open(path, _cfg())


def test_satisfies_protocol(store):
    assert isinstance(store, KVStore)


def test_put_get_delete_roundtrip(store):
    store.put(b"small", b"v1")
    store.put(b"big", b"x" * BIG)
    assert store.get(b"small") == b"v1"
    assert store.get(b"big") == b"x" * BIG
    assert store.get(b"absent") is None
    store.delete(b"small")
    assert store.get(b"small") is None


def test_multi_get_alignment(store):
    keys = [f"k{i:03d}".encode() for i in range(40)]
    for i, k in enumerate(keys):
        store.put(k, f"v{i}".encode() * (200 if i % 5 == 0 else 1))
    probe = keys[::3] + [b"missing1", keys[0], b"missing2"]
    got = store.multi_get(probe)
    assert got == [store.get(k) for k in probe]
    assert store.multi_get([]) == []


def test_delete_range(store):
    for i in range(30):
        store.put(f"k{i:03d}".encode(), b"v")
    store.delete_range(b"k005", b"k015")
    assert [k for k, _ in store.range()] == [
        f"k{i:03d}".encode() for i in list(range(5)) + list(range(15, 30))
    ]


def test_write_batch_applies_all(store):
    for i in range(20):
        store.put(f"k{i:03d}".encode(), b"old")
    wb = WriteBatch()
    for i in range(10):
        wb.put(f"k{i:03d}".encode(), f"new{i}".encode())
    wb.delete(b"k000").delete_range(b"k015", b"k020")
    store.write(wb)
    assert store.get(b"k000") is None  # later op in the batch wins
    assert store.get(b"k016") is None  # pre-batch value range-deleted
    assert store.get(b"k007") == b"new7"
    assert store.get(b"k012") == b"old"


def test_range_bounds_and_limit(store):
    keys = [f"k{i:03d}".encode() for i in range(50)]
    for k in keys:
        store.put(k, b"v" + k)
    assert [k for k, _ in store.range()] == keys
    assert [k for k, _ in store.range(b"k010", end=b"k013")] == [
        b"k010", b"k011", b"k012",
    ]
    assert [k for k, _ in store.range(b"k045", limit=3)] == [
        b"k045", b"k046", b"k047",
    ]
    assert list(store.range(limit=0)) == []
    assert list(store.range(b"zzz")) == []
    # abandoning the generator early must not leak the cursor/snapshot
    for _ in store.range():
        break
    assert [k for k, _ in store.range(limit=1)] == [b"k000"]


def test_scan_shim_warns_and_matches_range(store):
    for i in range(10):
        store.put(f"k{i:03d}".encode(), b"v")
    with pytest.warns(DeprecationWarning):
        legacy = store.scan(b"k002", 4)
    assert legacy == list(store.range(b"k002", limit=4))


def test_iterator_seek_next_prev(store):
    keys = [f"k{i:03d}".encode() for i in range(30)]
    for k in keys:
        store.put(k, b"v" + k)
    with store.iterator() as cur:
        assert cur.seek_to_first() and cur.key == b"k000"
        assert cur.seek(b"k010") and cur.key == b"k010" and cur.value == b"vk010"
        assert cur.next() and cur.key == b"k011"
        assert cur.prev() and cur.key == b"k010"
        assert cur.prev() and cur.key == b"k009"
        assert cur.next() and cur.key == b"k010"
        assert not cur.seek(b"zzz")
        assert cur.prev() and cur.key == keys[-1]  # invalid prev = seek-to-last
        walked = [cur.key]
        while cur.prev():
            walked.append(cur.key)
        assert walked == keys[::-1]


def test_snapshot_isolation(store):
    store.put(b"a", b"1")
    store.put(b"b", b"big" * 200)
    snap = store.snapshot()
    try:
        store.put(b"a", b"2")
        store.delete(b"b")
        store.put(b"c", b"3")
        assert store.get(b"a", snapshot=snap) == b"1"
        assert store.get(b"b", snapshot=snap) == b"big" * 200
        assert store.get(b"c", snapshot=snap) is None
        assert store.multi_get([b"a", b"b", b"c"], snapshot=snap) == [
            b"1", b"big" * 200, None,
        ]
        assert [k for k, _ in store.range(snapshot=snap)] == [b"a", b"b"]
        assert store.get(b"a") == b"2"
    finally:
        snap.release()


def test_snapshot_context_manager(store):
    store.put(b"x", b"1")
    with store.snapshot() as snap:
        store.put(b"x", b"2")
        assert store.get(b"x", snapshot=snap) == b"1"


def test_checkpoint_then_open_copy(store, tmp_path):
    for i in range(25):
        store.put(f"k{i:03d}".encode(), f"v{i}".encode() * (300 if i % 4 else 1))
    store.delete_range(b"k020", b"k023")
    ck = str(tmp_path / "ck")
    store.checkpoint(ck)
    store.put(b"post-ckpt", b"not in the image")
    copy = ShardedDB.open(ck) if isinstance(store, ShardedDB) else DB.open(ck, _cfg())
    try:
        want = [kv for kv in store.range() if kv[0] != b"post-ckpt"]
        assert list(copy.range()) == want
    finally:
        copy.close()


def test_stats_is_callable_dict(store):
    store.put(b"k", b"v")
    st = store.stats()
    assert isinstance(st, dict)
    # both implementations expose the user-write counter (ShardedDB under
    # "aggregate" plus untouched per-shard dicts)
    if isinstance(store, ShardedDB):
        assert st["aggregate"]["user_writes"] == 1
        assert len(st["per_shard"]) == 3
    else:
        assert st["user_writes"] == 1


def test_flush_then_reopen_durable(store, tmp_path):
    path = str(tmp_path / "store")
    for i in range(15):
        store.put(f"k{i:03d}".encode(), b"v" * (400 if i % 2 else 4))
    store.flush()
    store = _reopen(store, path)
    try:
        assert len(list(store.range())) == 15
    finally:
        store.close()


def test_verify_integrity_clean(store):
    for i in range(10):
        store.put(f"k{i:03d}".encode(), b"v" * 500)
    store.flush()
    rep = store.verify_integrity()
    assert rep["corruptions"] == []


def test_bvstore_accepts_injected_kvstore(store):
    """checkpoint/bvstore rides any KVStore: save/load a tiny pytree
    through the injected store (DB and ShardedDB alike)."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint.bvstore import BVCheckpointStore

    cs = BVCheckpointStore("ignored", db=store)
    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    cs.save(3, state)
    assert cs.steps() == [3]
    loaded, meta = cs.load(3)
    assert meta["step"] == 3
    np.testing.assert_array_equal(loaded["['w']"], state["w"])
    # do NOT cs.close(): the fixture owns the store's lifetime


def test_page_spill_store_roundtrip(store):
    np = pytest.importorskip("numpy")
    from repro.serving.kv_cache import PageSpillStore

    spill = PageSpillStore(store)
    pages = {
        (layer, seq, p): np.random.default_rng(layer + p).standard_normal(
            (8, 16)
        ).astype(np.float32)
        for layer in range(2) for seq in (7,) for p in range(3)
    }
    for key, page in pages.items():
        spill.spill(key, page)
    got = spill.restore_many(list(pages) + [(9, 9, 9)])
    for (key, page), g in zip(pages.items(), got):
        np.testing.assert_array_equal(g, page)
    assert got[-1] is None
    np.testing.assert_array_equal(spill.restore((0, 7, 0)), pages[(0, 7, 0)])
