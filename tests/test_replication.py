"""Primary/replica WAL shipping (PR 8).

Covers the replication subsystem end to end: checkpoint bootstrap +
stream attach, follower apply with value mirroring, transport faults
(drop/duplicate/reorder/corrupt) bridged by WAL catch-up, rolling-CRC
divergence detection + rebootstrap, failover promotion (durability
invariant, idempotency), WAL retention while followers are attached,
the all-findings integrity scrub report, incremental checkpoint
chains, and a smoke run of the randomized failover harness.
"""
from __future__ import annotations

import os
import time

import pytest

from repro.core import (
    DB,
    DBConfig,
    CorruptionError,
    DBReadOnlyError,
    FaultInjectionEnv,
)
from repro.core.replication import attach, bootstrap_replica
from repro.testing.failover_harness import run_failover_loop


def _cfg(env=None, wal_mode="sync", **kw):
    cfg = DBConfig.bvlsm(
        wal_mode=wal_mode,
        value_threshold=kw.pop("value_threshold", 64),
        memtable_size=kw.pop("memtable_size", 8192),
        num_bvalue_queues=2,
        **kw,
    )
    cfg.env = env
    cfg.bg_error_backoff_ms = 1.0
    return cfg


def _pair(tmp_path, wal_mode="sync", seed_writes=30, penv=None, renv=None,
          **cfg_kw):
    """Primary with some data, bootstrapped replica, live link."""
    primary = DB(str(tmp_path / "p"), _cfg(penv, wal_mode, **cfg_kw))
    data = {}
    for i in range(seed_writes):
        k = f"seed{i:04d}".encode()
        v = (f"val{i}_".encode() * 40)[: 200 if i % 3 else 24]
        primary.put(k, v)
        data[k] = v
    replica = bootstrap_replica(
        primary, str(tmp_path / "r"), cfg=_cfg(renv, wal_mode, **cfg_kw)
    )
    link = attach(primary, replica)
    return primary, replica, link, data


def _scan_all(db):
    return dict(db.range())


def _converge(link, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        link.nudge()
        if link.wait_caught_up(timeout=1.0):
            return True
    return False


# ---------------------------------------------------------------------------
# ship + apply
# ---------------------------------------------------------------------------
class TestShipApply:
    def test_stream_converges_and_scans_match(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path)
        try:
            for i in range(60):
                primary.put(f"live{i:04d}".encode(), (b"x%d_" % i) * 50)
            primary.delete(b"seed0001")
            primary.delete_range(b"seed0010", b"seed0014")
            assert _converge(link)
            assert _scan_all(primary) == _scan_all(replica)
            assert replica.get(b"seed0001") is None
            assert replica.get(b"seed0012") is None
        finally:
            primary.close()
            replica.close()

    def test_replica_rejects_user_writes(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path, seed_writes=3)
        try:
            with pytest.raises(DBReadOnlyError):
                replica.put(b"nope", b"v")
            with pytest.raises(DBReadOnlyError):
                replica.delete(b"seed0000")
        finally:
            primary.close()
            replica.close()

    def test_bootstrap_image_preserves_l0_order(self, tmp_path):
        """Two overlapping L0 flushes: the checkpoint's single manifest
        edit must rebuild L0 newest-first, or the image resurrects old
        versions (regression: replay inserts L0 adds at the front, so a
        batched newest-first list came back reversed)."""
        primary = DB(str(tmp_path / "p"), _cfg())
        try:
            primary.put(b"k", b"old")
            primary.delete(b"gone")
            primary.flush()
            primary.put(b"k", b"new")
            primary.put(b"gone", b"resurrected?")
            primary.delete(b"gone")
            primary.flush()
            replica = bootstrap_replica(primary, str(tmp_path / "r"))
            try:
                assert replica.get(b"k") == b"new"
                assert replica.get(b"gone") is None
            finally:
                replica.close()
        finally:
            primary.close()

    def test_lag_and_status_reporting(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path)
        try:
            for i in range(10):
                primary.put(f"st{i:02d}".encode(), b"s" * 80)
            assert _converge(link)
            ps = primary.replication_status()
            rs = replica.replication_status()
            assert ps["role"] == "primary"
            assert rs["role"] == "replica"
            assert ps["shipped_seq"] == primary._seq
            assert ps["min_acked_seq"] <= primary._seq
            assert rs["applied_seq"] == replica._seq
            assert rs["lag"] == 0
            assert rs["diverged"] is False
            assert link.lag == 0
        finally:
            primary.close()
            replica.close()


# ---------------------------------------------------------------------------
# transport faults
# ---------------------------------------------------------------------------
class TestTransportFaults:
    @pytest.mark.parametrize("wal_mode", ["sync", "async"])
    def test_lossy_wire_converges_via_catch_up(self, tmp_path, wal_mode):
        penv = FaultInjectionEnv(seed=7)
        primary, replica, link, _ = _pair(
            tmp_path, wal_mode=wal_mode, penv=penv,
            renv=FaultInjectionEnv(seed=8),
        )
        try:
            penv.set_transport_faults(
                drop=0.3, duplicate=0.2, reorder=0.2, corrupt=0.15
            )
            for i in range(120):
                primary.put(f"fault{i:04d}".encode(), (b"f%d_" % i) * 60)
            penv.set_transport_faults()  # heal the wire, then converge
            assert _converge(link)
            assert _scan_all(primary) == _scan_all(replica)
            assert not replica._follower.diverged
            t = penv.transport_stats
            assert sum(t.values()) > 0  # the wire actually misbehaved
        finally:
            primary.close()
            replica.close()

    def test_corrupt_frames_are_dropped_not_applied(self, tmp_path):
        """A flipped byte must fail the frame CRC — the follower treats it
        as a dropped frame (catch-up bridges the hole), never as data."""
        penv = FaultInjectionEnv(seed=11)
        primary, replica, link, _ = _pair(
            tmp_path, penv=penv, renv=FaultInjectionEnv(seed=12)
        )
        try:
            penv.set_transport_faults(corrupt=1.0)
            for i in range(40):
                primary.put(f"c{i:04d}".encode(), b"corrupt-wire" * 10)
            penv.set_transport_faults()
            assert _converge(link)
            assert _scan_all(primary) == _scan_all(replica)
            assert replica.stats.snapshot()["repl_frames_corrupt"] > 0
            assert not replica._follower.diverged
        finally:
            primary.close()
            replica.close()


# ---------------------------------------------------------------------------
# WAL retention
# ---------------------------------------------------------------------------
class TestRetention:
    def test_unacked_wal_survives_flush_until_follower_acks(self, tmp_path):
        """Flush normally deletes replayed WAL segments; with a follower
        attached the primary must retain them until acked, so a slow
        follower can always catch up from durable logs."""
        primary, replica, link, _ = _pair(tmp_path, memtable_size=2048)
        try:
            # wedge the follower: stop applying, then push enough to
            # rotate + flush WAL segments on the primary
            replica._follower.sealed = True
            for i in range(80):
                primary.put(f"slow{i:04d}".encode(), b"r" * 200)
            primary.flush()
            st = primary.replication_status()
            assert st["retained_wals"] > 0
            # un-wedge: a fresh catch-up replays the retained logs
            replica._follower.sealed = False
            assert _converge(link)
            assert _scan_all(primary) == _scan_all(replica)
            primary.flush()
            assert primary.replication_status()["retained_wals"] == 0
        finally:
            primary.close()
            replica.close()

    def test_detach_releases_retention(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path, memtable_size=2048)
        try:
            assert _converge(link)
            link.detach()
            for i in range(60):
                primary.put(f"post{i:04d}".encode(), b"d" * 150)
            primary.flush()
            assert primary.replication_status().get("retained_wals", 0) == 0
        finally:
            primary.close()
            replica.close()


# ---------------------------------------------------------------------------
# divergence detection
# ---------------------------------------------------------------------------
class TestDivergence:
    def test_tampered_state_is_flagged_and_rebootstrapped(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path, repl_crc_interval=16)
        try:
            assert _converge(link)
            # poison the follower's rolling CRC for a FUTURE run, then
            # write through it: the completed run's digest can't match
            interval = 16
            target = primary._seq // interval + 1
            with replica._follower._lock:
                replica._follower._runs[target] = 0xDEAD
            for i in range(interval * 3):
                primary.put(f"div{i:04d}".encode(), b"z" * 100)
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                link.nudge()
                if replica._follower.diverged:
                    break
                time.sleep(0.02)
            assert replica._follower.diverged
            assert replica._follower.needs_rebootstrap
            assert primary.stats.snapshot()["repl_divergence_detected"] >= 0
            replica = link.rebootstrap()
            assert _converge(link)
            assert _scan_all(primary) == _scan_all(replica)
            assert not replica._follower.diverged
        finally:
            primary.close()
            link.replica.close()

    def test_clean_stream_never_flags(self, tmp_path):
        primary, replica, link, _ = _pair(tmp_path, repl_crc_interval=8)
        try:
            for i in range(100):
                primary.put(f"ok{i:04d}".encode(), b"y" * 80)
            assert _converge(link)
            assert replica.stats.snapshot()["repl_crc_checks"] > 0
            assert not replica._follower.diverged
        finally:
            primary.close()
            replica.close()


# ---------------------------------------------------------------------------
# failover promotion
# ---------------------------------------------------------------------------
class TestPromotion:
    def test_failover_keeps_every_acked_sync_write(self, tmp_path):
        penv = FaultInjectionEnv(seed=21)
        primary, replica, link, data = _pair(
            tmp_path, penv=penv, renv=FaultInjectionEnv(seed=22)
        )
        try:
            for i in range(50):
                k = f"acked{i:04d}".encode()
                v = (b"a%d_" % i) * 60
                primary.put(k, v)
                data[k] = v
            try:
                primary.close(crash=True)
            except Exception:
                pass
            penv.drop_unsynced()
            penv.disarm_crash()
            replica.promote()
            assert replica.replication_status()["role"] == "primary"
            for k, v in data.items():
                assert replica.get(k) == v, k
            replica.put(b"post-failover", b"accepted")
            assert replica.get(b"post-failover") == b"accepted"
        finally:
            replica.close()

    def test_promote_is_idempotent(self, tmp_path):
        primary, replica, link, data = _pair(tmp_path, seed_writes=10)
        try:
            assert _converge(link)
            primary.close()
            replica.promote()
            wals_after_first = sorted(
                n for n in os.listdir(replica.path) if n.startswith("wal_")
            )
            replica.promote()  # second call: strict no-op
            wals_after_second = sorted(
                n for n in os.listdir(replica.path) if n.startswith("wal_")
            )
            assert wals_after_first == wals_after_second  # no double rotation
            replica.put(b"still-works", b"yes")
            assert replica.get(b"still-works") == b"yes"
        finally:
            replica.close()

    def test_promote_on_primary_is_noop(self, tmp_path):
        db = DB(str(tmp_path / "solo"), _cfg())
        try:
            db.put(b"a", b"1")
            db.promote()
            assert db.replication_status()["role"] == "primary"
            assert db.get(b"a") == b"1"
        finally:
            db.close()


# ---------------------------------------------------------------------------
# incremental checkpoints (chain of 3)
# ---------------------------------------------------------------------------
class TestIncrementalCheckpoint:
    def test_chain_of_three_links_unchanged_files(self, tmp_path):
        db = DB(str(tmp_path / "db"), _cfg())
        try:
            data = {}
            for i in range(20):
                k = f"ck{i:04d}".encode()
                v = (b"c%d_" % i) * 50
                db.put(k, v)
                data[k] = v
            db.flush()
            cp1 = str(tmp_path / "cp1")
            db.checkpoint(cp1)

            for i in range(20, 40):
                k = f"ck{i:04d}".encode()
                v = (b"c%d_" % i) * 50
                db.put(k, v)
                data[k] = v
            db.flush()
            cp2 = str(tmp_path / "cp2")
            db.checkpoint(cp2, base=cp1)

            # every SSTable cp1 already held is a hard link, not a copy
            shared = [
                n for n in os.listdir(cp1)
                if n.endswith(".sst") and os.path.exists(os.path.join(cp2, n))
            ]
            assert shared, "chain test needs at least one carried-over table"
            for n in shared:
                assert os.path.samefile(
                    os.path.join(cp1, n), os.path.join(cp2, n)
                ), f"{n} was re-materialized instead of linked from base"

            for i in range(40, 60):
                k = f"ck{i:04d}".encode()
                v = (b"c%d_" % i) * 50
                db.put(k, v)
                data[k] = v
            db.flush()
            cp3 = str(tmp_path / "cp3")
            db.checkpoint(cp3, base=cp2)
            for n in os.listdir(cp2):
                if n.endswith(".sst") and os.path.exists(os.path.join(cp3, n)):
                    assert os.path.samefile(
                        os.path.join(cp2, n), os.path.join(cp3, n)
                    )

            # the end of the chain opens to exactly the live contents
            img = DB(cp3, _cfg())
            try:
                assert _scan_all(img) == data
            finally:
                img.close()
        finally:
            db.close()

    def test_base_link_skipped_when_sizes_differ(self, tmp_path):
        """Same-name ⇒ same-content only holds for pristine images: a
        base file whose size differs (e.g. a short mirrored value file)
        must be re-copied, not linked."""
        db = DB(str(tmp_path / "db"), _cfg())
        try:
            db.put(b"big", b"B" * 500)  # separated value
            db.flush()
            cp1 = str(tmp_path / "cp1")
            # hardlink=False: we are about to mutate the image, and a
            # hard-linked one shares inodes with the live DB
            db.checkpoint(cp1, hardlink=False)
            # truncate a value file in the base image to simulate a
            # partially-mirrored replica store reused as a base
            bv = os.path.join(cp1, "bvalue")
            victim = next(
                n for n in sorted(os.listdir(bv))
                if n.endswith(".val") and os.path.getsize(os.path.join(bv, n))
            )
            with open(os.path.join(bv, victim), "r+b") as f:
                f.truncate(max(0, os.path.getsize(os.path.join(bv, victim)) - 8))
            cp2 = str(tmp_path / "cp2")
            db.checkpoint(cp2, base=cp1)
            img = DB(cp2, _cfg())
            try:
                assert img.get(b"big") == b"B" * 500
            finally:
                img.close()
        finally:
            db.close()


# ---------------------------------------------------------------------------
# integrity scrub: the all-findings report
# ---------------------------------------------------------------------------
class TestScrubReport:
    def _corrupt(self, path, off=30, n=4):
        with open(path, "r+b") as f:
            f.seek(off)
            b = f.read(n)
            f.seek(off)
            f.write(bytes(x ^ 0xFF for x in b))

    def test_report_collects_every_finding(self, tmp_path):
        db = DB(str(tmp_path / "db"), _cfg())
        try:
            # table A: inline values only (this one gets block rot — its
            # pointers are skipped once quarantined, so the value probe
            # must come from a different table)
            for i in range(15):
                db.put(f"a{i:04d}".encode(), b"inline")
            db.flush()
            db.wait_idle()
            fno = db.versions.current.levels[0][0].file_no
            # table B: separated values (its value file gets the rot)
            for i in range(15):
                db.put(f"b{i:04d}".encode(), (b"v%d_" % i) * 40)
            db.flush()
            db.wait_idle()
            self._corrupt(os.path.join(db.path, f"{fno:06d}.sst"))
            bv = os.path.join(db.path, "bvalue")
            victim = next(
                n for n in sorted(os.listdir(bv))
                if os.path.getsize(os.path.join(bv, n))
            )
            self._corrupt(os.path.join(bv, victim), off=8)

            report = db.verify_integrity()
            assert len(report["findings"]) >= 2
            kinds = {f["kind"] for f in report["findings"]}
            assert "sst_block" in kinds and "bvalue" in kinds
            for f in report["findings"]:
                assert f["file"] is not None
                assert f["error"]
        finally:
            db.close()

    def test_fail_fast_raises_on_first(self, tmp_path):
        db = DB(str(tmp_path / "db"), _cfg())
        try:
            for i in range(10):
                db.put(f"f{i:04d}".encode(), b"x" * 30)
            db.flush()
            db.wait_idle()
            fno = db.versions.current.levels[0][0].file_no
            self._corrupt(os.path.join(db.path, f"{fno:06d}.sst"))
            with pytest.raises(CorruptionError):
                db.verify_integrity(fail_fast=True)
        finally:
            db.close()


# ---------------------------------------------------------------------------
# randomized failover harness (smoke; CI runs the long loop)
# ---------------------------------------------------------------------------
def test_failover_harness_smoke():
    report = run_failover_loop(iters=8, seed=123)
    assert report["iterations"] == 8
    assert report["failures"] == []


def test_failover_iteration_deterministic(tmp_path):
    from repro.testing.failover_harness import run_iteration

    a = run_iteration(5, "sync", str(tmp_path / "a"))
    b = run_iteration(5, "sync", str(tmp_path / "b"))
    assert a["scenario"] == b["scenario"]
    assert a["violations"] == b["violations"] == []
