"""Engine behaviour: unit tests + hypothesis property tests for the LSM
invariants across all three separation modes and WAL modes."""
import os
import shutil
import tempfile

import pytest

try:  # hypothesis is optional in this container; property tests skip without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:
    HealthCheck = given = settings = st = None

from repro.core import DB, DBConfig
from repro.core.bloom import BloomFilter
from repro.core.bvcache import BVCache
from repro.core.record import (
    ValueOffset,
    decode_entries,
    encode_entries,
    frame_record,
    iter_framed_records,
    pack_internal_key,
    unpack_internal_key,
)
from repro.core.sstable import SSTableReader, SSTableWriter

SMALL = dict(
    memtable_size=64 << 10,
    level1_max_bytes=256 << 10,
    value_threshold=512,
    bvcache_bytes=64 << 10,
    l0_compaction_trigger=2,
)


def mk(tmp, mode="wal", wal="sync", **kw):
    cfg = {**SMALL, **kw}
    return DB(tmp, DBConfig(separation_mode=mode, wal_mode=wal, **cfg))


# ---------------------------------------------------------------------------
# record encodings
# ---------------------------------------------------------------------------

def test_internal_key_roundtrip_and_order():
    k1 = pack_internal_key(b"aaa", 5, 1)
    assert unpack_internal_key(k1) == (b"aaa", 5, 1)
    # same key: higher seq sorts FIRST (bytewise ascending)
    assert pack_internal_key(b"aaa", 9, 1) < pack_internal_key(b"aaa", 5, 1)
    assert pack_internal_key(b"aaa", 5, 1) < pack_internal_key(b"aab", 1, 1)


def test_wal_framing_detects_torn_tail():
    recs = [encode_entries(i, [(1, b"k%d" % i, b"v")]) for i in range(5)]
    buf = b"".join(frame_record(r) for r in recs)
    assert len(list(iter_framed_records(buf))) == 5
    assert len(list(iter_framed_records(buf[:-3]))) == 4  # torn tail dropped
    corrupted = buf[:10] + b"\xff" + buf[11:]
    assert len(list(iter_framed_records(corrupted))) < 5


def test_value_offset_roundtrip():
    v = ValueOffset(3, 123456789, 4096, 0xDEADBEEF)
    assert ValueOffset.decode(v.encode()) == v


# ---------------------------------------------------------------------------
# bloom + sstable
# ---------------------------------------------------------------------------

def test_bloom_no_false_negatives():
    keys = [f"key{i}".encode() for i in range(500)]
    bf = BloomFilter.build(keys)
    assert all(bf.may_contain(k) for k in keys)
    fp = sum(bf.may_contain(f"other{i}".encode()) for i in range(1000))
    assert fp < 50  # ~1% expected at 10 bits/key
    bf2 = BloomFilter.decode(bf.encode())
    assert all(bf2.may_contain(k) for k in keys)


def test_sstable_roundtrip(tmp_path):
    path = str(tmp_path / "t.sst")
    w = SSTableWriter(path, block_size=256, compression=True)
    items = [(f"k{i:05d}".encode(), i, 1, b"v" * (i % 97)) for i in range(300)]
    for k, s, t, v in items:
        w.add(k, s, t, v)
    meta = w.finish(1)
    assert meta.entries == 300
    r = SSTableReader(path)
    for k, s, t, v in items[::7]:
        found, seq, type_, val = r.get(k)
        assert found and seq == s and val == v
    assert r.get(b"nope") == (False, 0, 0, b"")
    assert [k for k, *_ in r] == [k for k, *_ in items]
    # iter_from mid-range
    got = [k for k, *_ in r.iter_from(b"k00150")]
    assert got == [k for k, *_ in items[150:]]
    r.close()


# ---------------------------------------------------------------------------
# DB behaviour across modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["none", "flush", "wal"])
@pytest.mark.parametrize("wal", ["sync", "async", "off"])
def test_put_get_delete_overwrite(tmp_db_dir, mode, wal):
    db = mk(tmp_db_dir, mode, wal)
    try:
        vals = {}
        for i in range(150):
            k = f"k{i:04d}".encode()
            v = bytes([i % 251]) * (64 if i % 3 else 2048)
            db.put(k, v)
            vals[k] = v
        for i in range(0, 150, 5):
            k = f"k{i:04d}".encode()
            db.put(k, b"new" * 400)
            vals[k] = b"new" * 400
        for i in range(0, 150, 7):
            k = f"k{i:04d}".encode()
            db.delete(k)
            vals.pop(k, None)
        db.flush()
        db.compact_all()
        for k, v in vals.items():
            assert db.get(k) == v, k
        for i in range(0, 150, 7):
            assert db.get(f"k{i:04d}".encode()) is None
    finally:
        db.close()


@pytest.mark.parametrize("mode", ["none", "flush", "wal"])
def test_recovery_after_clean_close(tmp_db_dir, mode):
    db = mk(tmp_db_dir, mode, "sync")
    for i in range(80):
        db.put(f"k{i}".encode(), f"value-{i}".encode() * 200)
    db.close()
    db2 = mk(tmp_db_dir, mode, "sync")
    try:
        for i in range(80):
            assert db2.get(f"k{i}".encode()) == f"value-{i}".encode() * 200
    finally:
        db2.close()


def test_crash_recovery_sync_wal_durable(tmp_db_dir):
    """Every acknowledged write with sync WAL survives a crash."""
    db = mk(tmp_db_dir, "wal", "sync")
    acked = {}
    for i in range(60):
        k, v = f"k{i}".encode(), (b"%d" % i) * 300
        db.put(k, v)
        acked[k] = v
    db.close(crash=True)  # memtable NOT flushed; async buffers dropped
    db2 = mk(tmp_db_dir, "wal", "sync")
    try:
        for k, v in acked.items():
            assert db2.get(k) == v
    finally:
        db2.close()


def test_crash_recovery_async_wal_prefix(tmp_db_dir):
    """Async WAL: recovered state is a prefix-consistent subset of acked."""
    db = mk(tmp_db_dir, "wal", "async")
    acked = {}
    for i in range(60):
        k, v = f"k{i}".encode(), (b"%d" % i) * 300
        db.put(k, v)
        acked[k] = v
    if db.wal is not None:
        db.wal.flush()  # barrier: everything before this must survive
    for i in range(60, 80):
        db.put(f"k{i}".encode(), b"after-barrier")
    db.close(crash=True)
    db2 = mk(tmp_db_dir, "wal", "async")
    try:
        for k, v in acked.items():
            assert db2.get(k) == v  # pre-barrier writes must be there
    finally:
        db2.close()


def test_write_amp_ordering(tmp_db_dir):
    """The paper's claim at engine level: WA(bvlsm) < WA(blobdb) ≤ WA(rocksdb)."""
    import numpy as np

    val = np.random.default_rng(0).bytes(8192)
    amps = {}
    for mode in ("none", "flush", "wal"):
        d = tmp_db_dir + mode
        db = mk(d, mode, "sync")
        try:
            for i in np.random.default_rng(1).permutation(120):
                db.put(f"{i:06d}".encode(), val)
            db.flush()
            db.compact_all()
            amps[mode] = db.stats.write_amp
        finally:
            db.close()
    assert amps["wal"] < amps["flush"] <= amps["none"] + 1e-6, amps
    assert amps["wal"] < 1.5


def test_scan_merges_all_levels(tmp_db_dir):
    db = mk(tmp_db_dir, "wal", "sync")
    try:
        for i in range(100):
            db.put(f"s{i:04d}".encode(), b"x" * 700)
        db.flush()
        for i in range(50, 150):
            db.put(f"s{i:04d}".encode(), b"y" * 700)  # overwrite + extend
        got = list(db.range(b"s0040", limit=30))
        assert [k for k, _ in got] == [f"s{i:04d}".encode() for i in range(40, 70)]
        for k, v in got:
            i = int(k[1:])
            assert v == (b"y" if i >= 50 else b"x") * 700
    finally:
        db.close()


# ---------------------------------------------------------------------------
# BVCache
# ---------------------------------------------------------------------------

def test_bvcache_mrwf_and_pinning():
    c = BVCache(capacity_bytes=1000, policy="lru")
    vo = lambda i: ValueOffset(0, i * 100, 100)
    c.insert(b"a", vo(1), b"x" * 400, pinned=True)
    c.insert(b"b", vo(2), b"y" * 400)
    c.insert(b"c", vo(3), b"z" * 400)  # overflows: b evicted (a pinned)
    assert c.get(b"a") is not None
    assert c.get(b"b") is None
    assert c.get(b"c") is not None
    c.unpin(b"a", vo(1))  # a becomes evictable (joins LRU order at MRU)
    c.insert(b"d", vo(4), b"w" * 400)
    c.insert(b"e", vo(5), b"v" * 400)
    assert c.get(b"a") is None  # evicted once enough unpinned pressure


def test_bvcache_serves_unpersisted_reads(tmp_db_dir):
    """WAL-off mode: reads of freshly written big values come from BVCache
    before the async BValue write lands."""
    db = mk(tmp_db_dir, "wal", "off")
    try:
        big = b"Q" * 8192
        db.put(b"hot", big)
        assert db.get(b"hot") == big
        assert db.bvcache.hits >= 1
    finally:
        db.close()


# ---------------------------------------------------------------------------
# hypothesis: engine vs model dict
# ---------------------------------------------------------------------------

if st is not None:
    ops_strategy = st.lists(
        st.tuples(
            st.sampled_from(["put", "put_big", "delete", "get"]),
            st.integers(0, 30),
            st.integers(0, 255),
        ),
        min_size=1,
        max_size=120,
    )
    _hyp_settings = settings(
        max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow]
    )
    _hyp_given = given(ops=ops_strategy, mode=st.sampled_from(["none", "flush", "wal"]))
else:
    _hyp_settings = _hyp_given = pytest.mark.skip(reason="hypothesis not installed")


@_hyp_settings
@_hyp_given
def test_engine_matches_model_dict(ops, mode):
    tmp = tempfile.mkdtemp(prefix="hyp_")
    db = DB(
        os.path.join(tmp, "db"),
        DBConfig(
            separation_mode=mode,
            wal_mode="sync",
            memtable_size=8 << 10,
            value_threshold=256,
            level1_max_bytes=64 << 10,
            l0_compaction_trigger=2,
            bvcache_bytes=16 << 10,
        ),
    )
    model: dict[bytes, bytes] = {}
    try:
        for op, ki, vb in ops:
            k = f"key{ki:03d}".encode()
            if op == "put":
                v = bytes([vb]) * 37
                db.put(k, v)
                model[k] = v
            elif op == "put_big":
                v = bytes([vb]) * 1024
                db.put(k, v)
                model[k] = v
            elif op == "delete":
                db.delete(k)
                model.pop(k, None)
            else:
                assert db.get(k) == model.get(k)
        db.flush()
        db.compact_all()
        for k, v in model.items():
            assert db.get(k) == v
        # scan equivalence
        got = dict(db.range(limit=1000))
        assert got == model
        # reopen equivalence
        db.close()
        db2 = DB(os.path.join(tmp, "db"), DBConfig(separation_mode=mode, wal_mode="sync"))
        try:
            for k, v in model.items():
                assert db2.get(k) == v
        finally:
            db2.close()
            db = None
    finally:
        if db is not None:
            db.close()
        shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# paper-config presets, LFU policy, batching knobs
# ---------------------------------------------------------------------------

def test_paper_config_presets():
    from repro.configs.bvlsm_paper import container_scaled, paper_exact

    p = paper_exact()
    assert p.memtable_size == 128 << 20 and p.bvcache_bytes == 128 << 20
    assert p.separation_mode == "wal" and p.num_bvalue_queues == 4
    c = container_scaled("none", "sync")
    assert c.separation_mode == "none" and c.wal_mode == "sync"


def test_bvcache_lfu_policy():
    c = BVCache(capacity_bytes=900, policy="lfu")
    vo = lambda i: ValueOffset(0, i * 100, 100)
    c.insert(b"hot", vo(1), b"h" * 400)
    for _ in range(5):
        assert c.get(b"hot") is not None  # freq → 6
    c.insert(b"cold", vo(2), b"c" * 400)
    c.insert(b"new", vo(3), b"n" * 400)  # overflow → LFU evicts 'cold'
    assert c.get(b"hot") is not None
    assert c.get(b"cold") is None


def test_gather_window_batches_small_values(tmp_db_dir):
    """Async writers must coalesce small values into few fsyncs."""
    db = mk(tmp_db_dir, "wal", "async", bvalue_gather_window_s=0.02)
    try:
        for i in range(300):
            db.put(f"w{i:05d}".encode(), b"V" * 1024)
        db.flush()
        for i in range(0, 300, 17):
            assert db.get(f"w{i:05d}".encode()) == b"V" * 1024
    finally:
        db.close()
