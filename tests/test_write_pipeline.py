"""Write pipeline: WriteBatch semantics, leader/follower group commit,
pipelined leader handoff (v2: overlap, sequence-ordered publication,
adaptive group sizing, sharded memtable apply), BValue batched fan-out +
roll race, MemTable sorted-view cache, and the BValue flush barrier."""
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import DB, DBConfig, WriteBatch
from repro.core.bvalue import BValueManager
from repro.core.memtable import MemTable
from repro.core.record import decode_entries, kTypeValue
from repro.core.wal import replay_wal

SMALL = dict(
    memtable_size=64 << 10,
    level1_max_bytes=256 << 10,
    value_threshold=512,
    bvcache_bytes=64 << 10,
    l0_compaction_trigger=2,
)


def mk(tmp, mode="wal", wal="sync", **kw):
    cfg = {**SMALL, **kw}
    return DB(tmp, DBConfig(separation_mode=mode, wal_mode=wal, **cfg))


# ---------------------------------------------------------------------------
# WriteBatch API
# ---------------------------------------------------------------------------

def test_writebatch_basic_and_empty(tmp_db_dir):
    db = mk(tmp_db_dir)
    try:
        b = WriteBatch()
        assert len(b) == 0
        db.write(b)  # empty batch is a no-op
        b.put(b"a", b"1").put(b"b", b"2").delete(b"missing")
        assert len(b) == 3 and b.size_bytes == 4 + len(b"missing")
        db.write(b)
        assert db.get(b"a") == b"1"
        assert db.get(b"b") == b"2"
        assert db.get(b"missing") is None
        b.clear()
        assert len(b) == 0 and b.size_bytes == 0
    finally:
        db.close()


def test_writebatch_one_wal_record_one_fsync(tmp_db_dir):
    """A 100-entry batch must cost a single WAL record + a single fsync."""
    db = mk(tmp_db_dir, wal="sync")
    try:
        b = WriteBatch()
        for i in range(100):
            b.put(f"k{i:03d}".encode(), b"v" * 64)
        db.write(b)
        s = db.stats.snapshot()
        assert s["wal_records"] == 1
        assert s["wal_fsyncs"] == 1
        assert s["user_writes"] == 100
        assert s["group_commits"] == 1
    finally:
        db.close()


def test_writebatch_duplicate_keys_last_wins(tmp_db_dir):
    db = mk(tmp_db_dir)
    try:
        b = WriteBatch()
        b.put(b"k", b"first").delete(b"k").put(b"k", b"last")
        db.write(b)
        assert db.get(b"k") == b"last"
        db.flush()
        assert db.get(b"k") == b"last"
    finally:
        db.close()


# ---------------------------------------------------------------------------
# concurrent group commit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wal", ["sync", "async"])
def test_concurrent_writers_all_readable(tmp_db_dir, wal):
    db = mk(tmp_db_dir, wal=wal, memtable_size=4 << 20)
    nthreads, n = 8, 120
    errors = []

    def writer(t):
        try:
            for i in range(n):
                db.put(f"t{t}k{i:04d}".encode(), f"val-{t}-{i}".encode() * 20)
        except BaseException as e:  # pragma: no cover - failure diagnostics
            errors.append(e)

    try:
        ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors
        s = db.stats.snapshot()
        assert s["user_writes"] == nthreads * n
        for t in range(nthreads):
            for i in range(0, n, 13):
                assert db.get(f"t{t}k{i:04d}".encode()) == f"val-{t}-{i}".encode() * 20
    finally:
        db.close()


def test_concurrent_sync_writers_durable_after_crash(tmp_db_dir):
    """Every acknowledged concurrent write with sync WAL survives a crash:
    followers are only woken after the leader's group fsync covers them."""
    db = mk(tmp_db_dir, wal="sync", memtable_size=4 << 20)
    nthreads, n = 6, 60
    acked: dict[bytes, bytes] = {}
    lock = threading.Lock()

    def writer(t):
        for i in range(n):
            k, v = f"t{t}k{i:04d}".encode(), (b"%d.%d|" % (t, i)) * 30
            db.put(k, v)
            with lock:
                acked[k] = v

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    db.close(crash=True)  # memtable NOT flushed
    db2 = mk(tmp_db_dir, wal="sync")
    try:
        for k, v in acked.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


def test_group_commit_amortizes_fsyncs(tmp_db_dir):
    """With 8 concurrent sync writers the leader must merge groups: strictly
    fewer fsyncs than writes (the pre-pipeline path pays 1.0 per write)."""
    db = mk(tmp_db_dir, wal="sync", memtable_size=16 << 20)
    nthreads, n = 8, 80

    def writer(t):
        for i in range(n):
            db.put(f"t{t}k{i:04d}".encode(), b"v" * 256)

    try:
        ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        s = db.stats.snapshot()
        assert s["user_writes"] == nthreads * n
        # on a single CPU overlap varies, but SOME grouping must happen
        assert s["wal_fsyncs"] < s["user_writes"]
        assert s["fsyncs_per_write"] < 1.0
        assert sum(s["group_size_hist"].values()) == s["group_commits"]
    finally:
        db.close()


def test_group_commit_disabled_baseline(tmp_db_dir):
    """wal_group_commit=False restores one record + one fsync per write."""
    db = mk(tmp_db_dir, wal="sync", wal_group_commit=False)
    try:
        for i in range(20):
            db.put(f"k{i}".encode(), b"v" * 64)
        s = db.stats.snapshot()
        assert s["wal_fsyncs"] == 20
        assert s["fsyncs_per_write"] == 1.0
        assert s["avg_group_size"] == 1.0
    finally:
        db.close()


# ---------------------------------------------------------------------------
# pipelined commit (write pipeline v2)
# ---------------------------------------------------------------------------

def _slow_fsync(monkeypatch, delay_s: float):
    """Make WAL fsyncs observably slow (GIL released during the sleep, like
    a real fsync) so commit groups genuinely overlap. The WAL syncs through
    the Env layer, so the syscall site to slow down lives in core.env."""
    import repro.core.env as env_mod

    real = os.fsync

    def slow(fd):
        time.sleep(delay_s)
        return real(fd)

    monkeypatch.setattr(env_mod.os, "fsync", slow)


def test_pipelined_handoff_overlaps_fsync(tmp_db_dir, monkeypatch):
    """With a slow fsync, the next leader must form+write its group while
    the previous group's fsync is in flight: observed pipeline depth > 1."""
    _slow_fsync(monkeypatch, 0.01)
    db = mk(tmp_db_dir, wal="sync", memtable_size=16 << 20)
    nthreads, n = 8, 30

    def writer(t):
        for i in range(n):
            db.put(f"t{t}k{i:04d}".encode(), b"v" * 128)

    try:
        ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        s = db.stats.snapshot()
        assert s["user_writes"] == nthreads * n
        assert s["pipeline_depth_max"] >= 2, s["pipeline_depth_hist"]
        for t in range(nthreads):
            for i in range(0, n, 7):
                assert db.get(f"t{t}k{i:04d}".encode()) == b"v" * 128
    finally:
        db.close()


def test_pipelined_disabled_is_single_outstanding(tmp_db_dir, monkeypatch):
    """wal_pipelined_commit=False restores PR 1's depth-1 pipeline."""
    _slow_fsync(monkeypatch, 0.005)
    db = mk(tmp_db_dir, wal="sync", wal_pipelined_commit=False, memtable_size=16 << 20)
    nthreads, n = 6, 20

    def writer(t):
        for i in range(n):
            db.put(f"t{t}k{i:04d}".encode(), b"v" * 64)

    try:
        ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        s = db.stats.snapshot()
        assert s["user_writes"] == nthreads * n
        assert s["pipeline_depth_max"] <= 1
    finally:
        db.close()


def test_pipelined_crash_recovery_no_commit_order_hole(tmp_db_dir, monkeypatch):
    """Crash under pipelined sync commits: (a) the WAL byte stream is in
    strictly ascending sequence order — replay can never surface group N+1
    without group N — and (b) every ACKED write survives recovery."""
    _slow_fsync(monkeypatch, 0.002)
    db = mk(tmp_db_dir, wal="sync", memtable_size=16 << 20)
    nthreads, n = 6, 40
    acked: dict[bytes, bytes] = {}
    lock = threading.Lock()

    def writer(t):
        for i in range(n):
            k, v = f"t{t}k{i:04d}".encode(), (b"%d.%d|" % (t, i)) * 20
            db.put(k, v)
            with lock:
                acked[k] = v

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    db.close(crash=True)  # memtable NOT flushed
    logs = sorted(f for f in os.listdir(tmp_db_dir) if f.startswith("wal_"))
    assert logs
    seqs = []
    for name in logs:
        for payload in replay_wal(os.path.join(tmp_db_dir, name)):
            seq, _ = decode_entries(payload)
            seqs.append(seq)
    assert seqs == sorted(seqs), "WAL file order diverged from sequence order"
    assert len(seqs) == len(set(seqs))
    db2 = mk(tmp_db_dir, wal="sync")
    try:
        for k, v in acked.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


def test_covered_fsync_skipped(tmp_db_dir, monkeypatch):
    """Pipelined groups whose ticket a later-started fsync already covered
    skip their own fsync (wal_fsync_skips > 0 under a slow-fsync pileup)."""
    _slow_fsync(monkeypatch, 0.01)
    db = mk(tmp_db_dir, wal="sync", memtable_size=16 << 20, wal_pipeline_depth=8,
            wal_pipeline_min_fill=1)  # eager handoff: force groups to stack
    nthreads, n = 8, 25

    def writer(t):
        for i in range(n):
            db.put(f"t{t}k{i:04d}".encode(), b"v" * 64)

    try:
        ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        s = db.stats.snapshot()
        assert s["user_writes"] == nthreads * n
        assert s["wal_fsync_skips"] > 0, s
        # skips never weaken durability accounting: every group either
        # fsynced or was covered by one
        assert s["wal_fsyncs"] + s["wal_fsync_skips"] >= s["group_commits"]
    finally:
        db.close()


def test_adaptive_cap_tracks_latency_target(tmp_db_dir, monkeypatch):
    """The latency-target controller shrinks the effective byte cap to the
    floor under a slow fsync and grows it to the ceiling under a fast one."""
    import repro.core.env as env_mod

    # slow: persist EWMA far above the 4 ms default target -> floor
    monkeypatch.setattr(env_mod.os, "fsync", lambda fd: time.sleep(0.012))
    db = mk(tmp_db_dir + "_slow", wal="sync", memtable_size=16 << 20)
    try:
        for i in range(25):
            db.put(f"k{i:03d}".encode(), b"v" * 64)
        g = db.stats.snapshot()["gauges"]
        assert g["wal_group_effective_bytes"] == db.cfg.wal_group_min_bytes, g
        assert g["wal_persist_ewma_s"] > db.cfg.wal_group_target_latency_s
    finally:
        db.close()

    # fast: fsync is a no-op -> EWMA under target/2 -> ceiling
    monkeypatch.setattr(env_mod.os, "fsync", lambda fd: None)
    db = mk(tmp_db_dir + "_fast", wal="sync", memtable_size=16 << 20)
    try:
        for i in range(40):
            db.put(f"k{i:03d}".encode(), b"v" * 64)
        g = db.stats.snapshot()["gauges"]
        assert g["wal_group_effective_bytes"] == db.cfg.wal_group_max_bytes, g
    finally:
        db.close()


def test_adaptive_disabled_uses_fixed_cap(tmp_db_dir):
    db = mk(tmp_db_dir, wal="sync", wal_group_adaptive=False)
    try:
        for i in range(10):
            db.put(f"k{i}".encode(), b"v" * 64)
        assert "wal_group_effective_bytes" not in db.stats.snapshot()["gauges"]
    finally:
        db.close()


# ---------------------------------------------------------------------------
# sharded memtable apply
# ---------------------------------------------------------------------------

def test_memtable_add_group_sharded_matches_sequential():
    """Hash-sharded group apply is bit-identical to the sequential apply,
    including cross-batch overwrites (per-key seq order preserved)."""
    seq_mt, sh_mt = MemTable(), MemTable()
    applies = []
    for b in range(5):
        entries = [
            (kTypeValue, f"k{(b * 31 + i) % 97:03d}".encode(), bytes([b]) * (10 + i % 7))
            for i in range(50)
        ]
        applies.append((100 + b, entries))
    seq_prevs = []
    for seq, entries in applies:
        seq_prevs.extend(seq_mt.add_batch(seq, entries))
    with ThreadPoolExecutor(max_workers=4) as pool:
        sh_prevs = sh_mt.add_group_sharded(applies, pool, 4)
    assert list(seq_mt.sorted_items()) == list(sh_mt.sorted_items())
    assert seq_mt.approximate_size == sh_mt.approximate_size
    assert sorted(seq_prevs) == sorted(sh_prevs)
    assert (seq_mt.first_seq, seq_mt.last_seq) == (sh_mt.first_seq, sh_mt.last_seq)


def test_db_shards_huge_group_apply(tmp_db_dir):
    """A group over the entry threshold goes through the sharded apply and
    stays fully readable (and durable across reopen)."""
    db = mk(
        tmp_db_dir, wal="sync", memtable_size=32 << 20,
        memtable_shard_apply_entries=64, memtable_apply_shards=4,
        value_threshold=1 << 20,
    )
    b = WriteBatch()
    for i in range(500):
        b.put(f"k{i:04d}".encode(), bytes([i % 251]) * 40)
    try:
        db.write(b)
        s = db.stats.snapshot()
        assert s["memtable_shard_applies"] >= 1
        assert s["user_writes"] == 500
        for i in range(0, 500, 37):
            assert db.get(f"k{i:04d}".encode()) == bytes([i % 251]) * 40
    finally:
        db.close(crash=True)
    db2 = mk(tmp_db_dir, wal="sync")
    try:
        for i in range(0, 500, 11):
            assert db2.get(f"k{i:04d}".encode()) == bytes([i % 251]) * 40
    finally:
        db2.close()


def test_pipelined_rotation_preserves_durability(tmp_db_dir):
    """Tiny memtable: rotations interleave with pipelined commits; every
    acked write must survive a crash (rotation only happens with the
    pipeline drained, so no WAL record is stranded in a dropped file)."""
    db = mk(tmp_db_dir, wal="sync", memtable_size=8 << 10)
    nthreads, n = 4, 40
    acked: dict[bytes, bytes] = {}
    lock = threading.Lock()

    def writer(t):
        for i in range(n):
            k, v = f"t{t}k{i:04d}".encode(), (b"%d:%d|" % (t, i)) * 40
            db.put(k, v)
            with lock:
                acked[k] = v

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(nthreads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    db.close(crash=True)
    db2 = mk(tmp_db_dir, wal="sync")
    try:
        for k, v in acked.items():
            assert db2.get(k) == v, k
    finally:
        db2.close()


# ---------------------------------------------------------------------------
# WriteBatch atomicity
# ---------------------------------------------------------------------------

def test_batch_atomic_across_memtable_rotation(tmp_db_dir):
    """A batch bigger than the memtable budget lands in ONE memtable/WAL
    generation (rotation happens between groups, never inside one)."""
    db = mk(tmp_db_dir, wal="sync", memtable_size=8 << 10)
    try:
        for r in range(6):
            b = WriteBatch()
            for i in range(40):
                b.put(f"r{r}k{i:03d}".encode(), bytes([r]) * 400)
            db.write(b)
        db.flush()
        db.compact_all()
        for r in range(6):
            for i in range(0, 40, 7):
                assert db.get(f"r{r}k{i:03d}".encode()) == bytes([r]) * 400
    finally:
        db.close()


def test_batch_replay_is_all_or_nothing(tmp_db_dir):
    """A torn WAL tail drops the whole trailing batch, never part of it."""
    db = mk(tmp_db_dir, wal="sync", memtable_size=4 << 20, value_threshold=1 << 20)
    for r in range(3):
        b = WriteBatch()
        for i in range(10):
            b.put(f"r{r}k{i:02d}".encode(), bytes([65 + r]) * 100)
        db.write(b)
    db.close(crash=True)
    # tear the tail of the WAL: the LAST batch's record becomes corrupt
    logs = sorted(f for f in os.listdir(tmp_db_dir) if f.startswith("wal_"))
    assert logs
    wal_path = os.path.join(tmp_db_dir, logs[-1])
    size = os.path.getsize(wal_path)
    with open(wal_path, "ab") as f:
        f.truncate(size - 3)
    db2 = mk(tmp_db_dir, wal="sync")
    try:
        for r in range(2):  # intact batches fully present
            for i in range(10):
                assert db2.get(f"r{r}k{i:02d}".encode()) == bytes([65 + r]) * 100
        # torn batch fully absent — not a single entry of it survived
        for i in range(10):
            assert db2.get(f"r2k{i:02d}".encode()) is None
    finally:
        db2.close()


def test_mixed_big_and_inline_batch(tmp_db_dir):
    """One batch mixing separated big values, inline values and deletes."""
    db = mk(tmp_db_dir, wal="sync", value_threshold=512)
    try:
        db.put(b"gone", b"x" * 64)
        b = WriteBatch()
        for i in range(20):
            b.put(f"big{i:02d}".encode(), bytes([i + 1]) * 2048)  # separated
            b.put(f"small{i:02d}".encode(), bytes([i + 1]) * 32)  # inline
        b.delete(b"gone")
        db.write(b)
        s = db.stats.snapshot()
        assert s["wal_records"] == 2  # the single put + the batch
        for i in range(20):
            assert db.get(f"big{i:02d}".encode()) == bytes([i + 1]) * 2048
            assert db.get(f"small{i:02d}".encode()) == bytes([i + 1]) * 32
        assert db.get(b"gone") is None
        db.flush()
        db.compact_all()
        assert db.get(b"big07") == bytes([8]) * 2048
    finally:
        db.close()
    db2 = mk(tmp_db_dir, wal="sync", value_threshold=512)
    try:
        for i in range(20):
            assert db2.get(f"big{i:02d}".encode()) == bytes([i + 1]) * 2048
            assert db2.get(f"small{i:02d}".encode()) == bytes([i + 1]) * 32
        assert db2.get(b"gone") is None
    finally:
        db2.close()


# ---------------------------------------------------------------------------
# BValue store: put_many fan-out, roll race, flush barrier
# ---------------------------------------------------------------------------

def test_put_many_fans_out_and_amortizes_fsyncs(tmp_path):
    mgr = BValueManager(str(tmp_path / "bv"), num_queues=4, async_writes=False)
    items = [(f"k{i:03d}".encode(), bytes([i % 251]) * 600) for i in range(32)]
    voffs = mgr.put_many(items, sync=True)
    assert len(voffs) == 32
    # round-robin: 32 values spread across all 4 queue files
    assert len({v.file_id for v in voffs}) == 4
    for (k, val), voff in zip(items, voffs):
        assert mgr.get(voff, verify=True) == val
    mgr.close()


def test_bvalue_roll_race_sync_writers(tmp_path):
    """Concurrent sync writers on one queue force file rolls between
    reserve() and the pwrite; every value must land in ITS reserved file
    (CRC-verified reads would explode if a write hit the wrong file)."""
    mgr = BValueManager(
        str(tmp_path / "bv"), num_queues=1, async_writes=False,
        max_file_bytes=4 << 10,  # tiny: rolls every ~2 values
    )
    results: dict[bytes, object] = {}
    lock = threading.Lock()
    errors = []

    def writer(t):
        try:
            for i in range(40):
                key = f"t{t}k{i:02d}".encode()
                val = (b"%d:%d|" % (t, i)) * 300  # ~1.8 KiB
                voff = mgr.put(key, val, sync=True)
                with lock:
                    results[key] = (voff, val)
        except BaseException as e:
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors
    assert len({v.file_id for v, _ in results.values()}) > 10  # many rolls happened
    for key, (voff, val) in results.items():
        assert mgr.get(voff, verify=True) == val, key
    mgr.close()


def test_async_big_value_batch_unpins_after_persist(tmp_db_dir):
    """Async WAL: pinned BVCache entries become evictable once the BValue
    writers persist them — the unpin must match despite the writer-side
    ValueOffset lacking the CRC, and must never race ahead of the insert."""
    db = mk(
        tmp_db_dir, wal="async",
        bvalue_batch_bytes=4 << 10, bvalue_gather_window_s=0.005,
        memtable_size=16 << 20, bvcache_bytes=16 << 20,
    )
    try:
        b = WriteBatch()
        for i in range(200):
            b.put(f"big{i:03d}".encode(), bytes([i % 251]) * 2048)
        db.write(b)
        db.bvalue.flush()
        assert db.bvcache.stats()["pinned"] == 0
        for i in range(0, 200, 23):
            assert db.get(f"big{i:03d}".encode()) == bytes([i % 251]) * 2048
    finally:
        db.close()


def test_bvalue_flush_barrier_drains_async_queues(tmp_path):
    persisted = []
    mgr = BValueManager(
        str(tmp_path / "bv"), num_queues=2, async_writes=True,
        gather_window_s=0.01, on_persisted=lambda k, v: persisted.append(k),
    )
    voffs = [mgr.put(f"k{i}".encode(), bytes([i]) * 512, sync=False) for i in range(50)]
    mgr.flush(timeout=30)  # CV barrier — returns only once queues are drained
    assert len(persisted) == 50
    for q in mgr.queues:
        assert q._pending_items == 0 and q.pending_bytes == 0
    for i, voff in enumerate(voffs):
        assert mgr.get(voff, verify=True) == bytes([i]) * 512
    mgr.close()


# ---------------------------------------------------------------------------
# MemTable: bulk apply + sorted-view cache
# ---------------------------------------------------------------------------

def test_memtable_add_batch_matches_add():
    a, b = MemTable(), MemTable()
    entries = [(kTypeValue, f"k{i % 7}".encode(), bytes([i]) * 10) for i in range(20)]
    for e in entries:
        a.add(5, *e)
    prevs = b.add_batch(5, entries)
    assert len(prevs) == 13  # 20 adds over 7 distinct keys
    assert list(a.sorted_items()) == list(b.sorted_items())
    assert a.approximate_size == b.approximate_size


def test_memtable_sorted_view_cached_and_invalidated():
    m = MemTable()
    for i in (3, 1, 2):
        m.add(i, kTypeValue, f"k{i}".encode(), b"v")
    assert [k for k, *_ in m.sorted_items()] == [b"k1", b"k2", b"k3"]
    cache = m._sorted_cache
    assert cache is not None and cache[0] == m._version
    # overwrite existing key: cached list survives (key set unchanged)
    m.add(4, kTypeValue, b"k2", b"v2")
    assert m._sorted() is cache[1]
    assert [k for k, *_ in m.range_items(b"k2", None)] == [b"k2", b"k3"]
    # new key: version bump invalidates, next read re-sorts
    m.add(5, kTypeValue, b"k0", b"v")
    assert m._sorted_cache[0] != m._version
    assert [k for k, *_ in m.sorted_items()] == [b"k0", b"k1", b"k2", b"k3"]
    assert m._sorted_cache[0] == m._version
    assert [k for k, *_ in m.range_items(b"k1", b"k3")] == [b"k1", b"k2"]
