"""Deterministic, checkpointable, host-sharded data pipeline.

State is {seed, step, host, num_hosts} — saving it in the checkpoint META
and restoring gives exact-batch resume (tested). Sources: synthetic token
stream (hash-counter PRNG, no global RNG state) or a memory-mapped token
file. Each host draws only its shard of the global batch; the trainer
forms global arrays from per-host shards (single-host here, but the
sharding math is the multi-host layout).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PipelineState:
    seed: int
    step: int
    host: int = 0
    num_hosts: int = 1

    def as_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step, "host": self.host, "num_hosts": self.num_hosts}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(d["seed"], d["step"], d.get("host", 0), d.get("num_hosts", 1))


class TokenPipeline:
    def __init__(
        self,
        vocab: int,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        host: int = 0,
        num_hosts: int = 1,
        token_file: str | None = None,
        extra_fields: dict | None = None,
    ):
        assert global_batch % num_hosts == 0
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.local_batch = global_batch // num_hosts
        self.state = PipelineState(seed, 0, host, num_hosts)
        self._tokens = None
        if token_file is not None:
            self._tokens = np.memmap(token_file, dtype=np.int32, mode="r")
        self.extra_fields = extra_fields or {}

    # counter-based PRNG → stateless, exactly resumable
    def _rng(self, step: int) -> np.random.Generator:
        key = (self.state.seed * 0x9E3779B1 + step * 0x85EBCA77 + self.state.host) & 0xFFFFFFFF
        return np.random.default_rng(key)

    def next_batch(self) -> dict:
        step = self.state.step
        rng = self._rng(step)
        B, S = self.local_batch, self.seq_len
        if self._tokens is not None:
            n = len(self._tokens) - (S + 1)
            starts = rng.integers(0, n, size=B)
            tok = np.stack([self._tokens[s : s + S + 1] for s in starts]).astype(np.int32)
        else:
            # zipf-flavored synthetic stream (bounded to vocab)
            tok = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
            tok = (tok % (self.vocab - 2)) + 1
            tok = tok.astype(np.int32)
        batch = {"tokens": tok[:, :S], "labels": tok[:, 1 : S + 1]}
        for name, spec in self.extra_fields.items():
            shape, dtype = spec
            batch[name] = rng.normal(0, 0.02, size=(B, *shape)).astype(dtype)
        self.state.step += 1
        return batch

    # -- checkpoint integration -------------------------------------------
    def state_dict(self) -> dict:
        return self.state.as_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
