"""qwen2-moe-a2.7b — 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # per routed expert
    vocab=151936,
    head_dim=128,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,  # shared FFN width = 4 * 1408 = 5632
    attention_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
