"""command-r-plus-104b — dense GQA decoder.
[hf:CohereForAI/c4ai-command-r-v01 family; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    parallel_block=True,  # Cohere parallel attn+FFN residual block
    attention_bias=False,
    tie_embeddings=True,
    rope_theta=75e6,
    norm_type="layernorm",  # Cohere uses LayerNorm (no bias)
    activation="swiglu",
    source="hf:CohereForAI/c4ai-command-r-plus",
)
