"""internvl2-76b — InternViT (stubbed frontend) + InternLM2-style backbone.
[arXiv:2404.16821; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    n_vision_patches=256,  # stub: input_specs() provides patch embeddings
    rope_theta=1000000.0,
    rms_eps=1e-5,
    source="arXiv:2404.16821",
)
