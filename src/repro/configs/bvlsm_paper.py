"""The paper's own experimental configurations (Table I / §IV-A), scaled
for this container, plus the exact-paper preset for real hardware.

Paper setup: 128 MiB MemTable, 1 immutable (+5 mutable pool), 16 B keys,
values 4–64 KiB, 100 GB datasets, NVMe SSD (Samsung 990 EVO), RocksDB
v9.7.3 baselines.
"""
from __future__ import annotations

from repro.core import DBConfig

KEY_SIZE = 16
VALUE_SIZES = [4096, 8192, 16384, 32768, 65536]
PAPER_DATASET_BYTES = 100 << 30  # 100 GB (scaled down by benchmarks/--mb)


def paper_exact(separation_mode: str = "wal", wal_mode: str = "async") -> DBConfig:
    """The paper's Table I configuration (needs NVMe-class storage)."""
    return DBConfig(
        separation_mode=separation_mode,
        wal_mode=wal_mode,
        value_threshold=4096,
        memtable_size=128 << 20,
        max_immutables=1,
        num_bvalue_queues=4,
        bvcache_bytes=128 << 20,  # §III-D: capacity equal to the MemTable
        bvalue_page_size=4096,
    )


def container_scaled(separation_mode: str = "wal", wal_mode: str = "async") -> DBConfig:
    """Same shape, scaled to the 1-vCPU container the benchmarks run on."""
    return DBConfig(
        separation_mode=separation_mode,
        wal_mode=wal_mode,
        value_threshold=4096,
        memtable_size=8 << 20,
        max_immutables=2,
        num_bvalue_queues=4,
        bvcache_bytes=8 << 20,
        level1_max_bytes=32 << 20,
    )
