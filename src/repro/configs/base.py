"""Model + shape-cell configuration.

Every assigned architecture gets one ``configs/<id>.py`` exporting
``CONFIG: ModelConfig`` with the exact published numbers, plus
``reduced()`` for CPU smoke tests. The four assigned input-shape cells are
global (``SHAPES``); per-arch applicability (decode/long skips) is derived
from the family.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    head_dim: int = 0  # 0 → d_model // n_heads
    qk_norm: bool = False
    parallel_block: bool = False  # command-r: attn + FFN in parallel
    attention_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # enc-dec (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_len: int = 1500  # precomputed frame embeddings (frontend stub)
    # vlm
    n_vision_patches: int = 0  # patch embeddings merged at input (stub)
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    ssm_groups: int = 1
    # hybrid (recurrentgemma)
    layer_pattern: str = ""  # e.g. "RRA" repeated cyclically
    window: int = 2048
    rnn_width: int = 0
    # block details
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | geglu | gelu
    use_rope: bool = True
    pos_emb: str = "none"  # none | learned (whisper)
    # numerics / padding
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    # source provenance
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid-local-attn only.)"""
        return self.family in ("ssm", "hybrid")

    def shape_supported(self, cell: ShapeCell) -> tuple[bool, str]:
        if cell.name == "long_500k" and not self.sub_quadratic:
            return False, "skip (full attention — no sub-quadratic path)"
        return True, ""

    def params_count(self) -> int:
        """Total parameter count (used for 6·N·D MODEL_FLOPS)."""
        hd = self.resolved_head_dim
        V = self.padded_vocab
        d = self.d_model
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_groups * self.ssm_state
            per_layer = (
                d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nh)  # in_proj
                + conv_dim * self.conv_kernel
                + 3 * nh  # A_log, dt_bias, D
                + d_in  # norm
                + d_in * d  # out_proj
                + d  # pre-norm
            )
            return emb + self.n_layers * per_layer + d
        if self.family == "hybrid":
            w = self.rnn_width or d
            rec = d * w * 2 + w * self.conv_kernel + 2 * w * w // 1 + w * d + 3 * w
            # rec block: 2 in-proj, conv, rg-lru gates (2 * w*w), out proj
            att = attn
            ff = 3 * d * self.d_ff  # GeGLU
            n_rec = sum(1 for i in range(self.n_layers) if self._layer_kind(i) == "R")
            n_att = self.n_layers - n_rec
            per = n_rec * (rec + ff + 2 * d) + n_att * (att + ff + 2 * d)
            return emb + per + d
        ffm = 3 if self.activation in ("swiglu", "geglu") else 2
        ff = ffm * d * self.d_ff
        moe = 0
        if self.family == "moe":
            moe = self.n_experts * ffm * d * self.d_ff + d * self.n_experts
            if self.n_shared_experts:
                moe += ffm * d * self.d_ff * self.n_shared_experts
            ff = 0
        per_layer = attn + ff + moe + 2 * d
        total = emb + self.n_layers * per_layer + d
        if self.enc_dec:
            # encoder layers + cross-attention in decoder
            enc = self.enc_layers * (attn + ff + 2 * d)
            cross = self.n_layers * (attn + d)
            total += enc + cross
        return total

    def active_params_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.params_count()
        d = self.d_model
        ffm = 3 if self.activation in ("swiglu", "geglu") else 2
        dense = self.params_count() - self.n_layers * (
            self.n_experts * ffm * d * self.d_ff
            + (ffm * d * self.d_ff * self.n_shared_experts if self.n_shared_experts else 0)
        )
        active_ff = self.n_layers * ffm * d * self.d_ff * (self.top_k + self.n_shared_experts)
        return dense + active_ff

    def _layer_kind(self, i: int) -> str:
        if not self.layer_pattern:
            return "A"
        return self.layer_pattern[i % len(self.layer_pattern)]

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        hd = min(self.resolved_head_dim, 16)
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.layer_pattern else 3),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=256,
            head_dim=hd,
            vocab_pad_multiple=32,
        )
        if self.family == "moe":
            kw.update(n_experts=min(self.n_experts, 4), top_k=min(self.top_k, 2), d_ff=32)
        if self.family == "ssm":
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.family == "hybrid":
            kw.update(rnn_width=64, window=32)
        if self.enc_dec:
            kw.update(enc_layers=2, enc_len=16)
        if self.n_vision_patches:
            kw.update(n_vision_patches=4)
        kw.update(overrides)
        return replace(self, **kw)
