"""whisper-small — encoder-decoder; conv frontend stubbed (precomputed
frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    attention_bias=True,
    enc_dec=True,
    enc_layers=12,
    enc_len=1500,
    norm_type="layernorm",
    activation="gelu",
    use_rope=False,
    pos_emb="learned",
    source="arXiv:2212.04356",
)
