"""recurrentgemma-9b — RG-LRU + local attention, pattern (R,R,A).
[arXiv:2402.19427; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,  # MQA on the local-attention layers
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    layer_pattern="RRA",
    window=2048,
    rnn_width=4096,
    activation="geglu",
    rope_theta=10000.0,
    source="arXiv:2402.19427",
)
