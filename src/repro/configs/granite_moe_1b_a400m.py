"""granite-moe-1b-a400m — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert FF width
    vocab=49155,  # NOT 16-divisible — padded via vocab_pad_multiple
    head_dim=64,
    n_experts=32,
    top_k=8,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
