"""Architecture registry — ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeCell

_ARCH_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3-8b": "llama3_8b",
    "qwen3-4b": "qwen3_4b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "internvl2-76b": "internvl2_76b",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = _ARCH_MODULES.get(arch)
    if mod is None:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeCell", "get_config", "all_configs"]
