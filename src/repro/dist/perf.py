"""§Perf variant switches.

Each flag gates one optimization that must stay mathematically equivalent
to the baseline path (equivalence enforced by ``tests/test_perf_variants``);
the dry-run compiles every variant and diffs the HLO cost model. Flags are
ambient (``perf_context``) rather than threaded through call signatures so
a variant can be toggled around an unmodified ``jit``/``lower`` call.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass


@dataclass(frozen=True)
class PerfConfig:
    save_dot_outputs: bool = False  # V1: remat policy saves post-AR tensors
    moe_local_dispatch: bool = False  # V2: per-data-shard MoE routing
    sharded_decode_attn: bool = False  # V3/V5: flash-decode over sharded kv_seq
    causal_chunk_growth: bool = False  # V4: growing causal attention chunks
    cast_weights_early: bool = False  # V6: bf16 weights across the FSDP gather
    bf16_rowparallel: bool = False  # V9: explicit bf16 row-parallel psum


_active: contextvars.ContextVar[PerfConfig] = contextvars.ContextVar(
    "repro_dist_perf", default=PerfConfig()
)


def perf() -> PerfConfig:
    """The ambient variant config (all-baseline when none installed)."""
    return _active.get()


@contextlib.contextmanager
def perf_context(cfg: PerfConfig):
    token = _active.set(cfg)
    try:
        yield cfg
    finally:
        _active.reset(token)
