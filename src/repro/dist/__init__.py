"""Logical-axis sharding: named axes on params/activations, resolved to
mesh ``PartitionSpec``s by a rule table.

Model code annotates every tensor dimension with a *logical* name
(:class:`Axes` for param pytrees, plain tuples at ``constrain`` call
sites); :func:`logical_to_spec` maps those names onto the *physical* mesh
axes via :func:`default_rules`, with two safety valves:

* **divisibility fallback** — a dim that doesn't divide the candidate mesh
  axes is replicated instead (never a lowering error: the 104B dry-run and
  the 1-device test mesh share one rule table);
* **first-dim-wins conflict resolution** — a mesh axis claimed by an
  earlier dimension of the same tensor is unavailable to later dims, which
  fall through to their next candidate (or replicate).

The active mesh is ambient (:func:`mesh_context` / :func:`active_mesh`)
so model code stays mesh-agnostic: :func:`constrain` is the identity when
no mesh is installed, and a ``with_sharding_constraint`` under one.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec


class Axes:
    """Logical axis names for one tensor, e.g. ``Axes("layers", "param_embed",
    "heads")``. ``None`` marks a dimension that is always replicated.

    Deliberately NOT a pytree container: an ``Axes`` is a *leaf*, so a tree
    of them can be ``jax.tree.map``-ed in parallel with the matching params
    tree. The raw name tuple is exposed as ``.t`` for slicing (e.g. dropping
    the scanned ``"layers"`` dim: ``Axes(*ax.t[1:])``).
    """

    __slots__ = ("t",)

    def __init__(self, *names: str | None):
        self.t = names

    def __repr__(self) -> str:
        return f"Axes{self.t!r}"

    def __eq__(self, other) -> bool:
        return isinstance(other, Axes) and self.t == other.t

    def __hash__(self) -> int:
        return hash((Axes, self.t))

    def __len__(self) -> int:
        return len(self.t)


def default_rules() -> dict[str, tuple[tuple[str, ...], ...]]:
    """Logical name -> ordered candidate mesh-axis groups.

    Each candidate is a tuple of mesh axes the dim shards across jointly
    (``("pod", "data")`` spans DCN+ICI data parallelism). The first
    candidate whose axes all exist in the mesh, are unclaimed by an earlier
    dim, and divide the dim size wins. Names absent from the table (and
    ``None``) replicate.

    Conventions: ``batch``/``cache_batch`` are data-parallel; ``param_*``
    shards over ``data`` (FSDP); heads/ffn/experts/vocab and the other
    model-parallel dims shard over ``model`` (megatron TP); ``seq`` /
    ``layers`` / small state dims replicate.
    """
    dp = (("pod", "data"), ("data",), ("pod",))
    tp = (("model",),)
    fsdp = (("data",),)
    return {
        "batch": dp,
        "cache_batch": dp,
        "param_embed": fsdp,
        "param_seq": (),
        "vocab": tp,
        "act_vocab": tp,
        "heads": tp,
        "act_heads": tp,
        "kv": tp,
        "act_kv": tp,
        "kv_seq": tp,
        "mlp": tp,
        "act_mlp": tp,
        "experts": tp,
        "act_experts": tp,
        "rnn_width": tp,
        "conv_dim": tp,
        "ssm_heads": tp,
    }


def logical_to_spec(axes, shape, mesh, rules=None) -> PartitionSpec:
    """Resolve logical names to a ``PartitionSpec`` against ``mesh``.

    Only ``mesh.shape`` (a name -> size mapping) is read, so tests can pass
    lightweight fakes. ``axes`` may be shorter than ``shape``; trailing dims
    replicate (PartitionSpec semantics).
    """
    if rules is None:
        rules = _active_rules.get() or default_rules()
    mesh_shape = dict(mesh.shape)
    used: set[str] = set()
    out = []
    for name, dim in zip(axes, shape):
        pick = None
        for cand in rules.get(name, ()) if name is not None else ():
            cand_t = cand if isinstance(cand, tuple) else (cand,)
            if any(a not in mesh_shape or a in used for a in cand_t):
                continue
            n = 1
            for a in cand_t:
                n *= mesh_shape[a]
            if dim % n != 0:
                continue
            pick = cand_t[0] if len(cand_t) == 1 else cand_t
            used.update(cand_t)
            break
        out.append(pick)
    return PartitionSpec(*out)


def tree_shardings(mesh, sds_tree, axes_tree, rules=None):
    """NamedSharding pytree matching ``sds_tree``'s structure.

    ``sds_tree`` holds ShapeDtypeStructs (or arrays); ``axes_tree`` is the
    parallel tree of :class:`Axes` leaves. No device allocation happens —
    this is what lets the 104B dry-run build shardings abstractly.
    """

    def one(sds, ax):
        t = ax.t if isinstance(ax, Axes) else tuple(ax)
        return NamedSharding(mesh, logical_to_spec(t, sds.shape, mesh, rules))

    return jax.tree.map(one, sds_tree, axes_tree)


# ---------------------------------------------------------------------------
# ambient mesh
# ---------------------------------------------------------------------------

_active_mesh: contextvars.ContextVar = contextvars.ContextVar("repro_dist_mesh", default=None)
_active_rules: contextvars.ContextVar = contextvars.ContextVar("repro_dist_rules", default=None)


def active_mesh():
    """The mesh installed by the innermost :func:`mesh_context`, or None."""
    return _active_mesh.get()


@contextlib.contextmanager
def mesh_context(mesh, rules=None):
    """Install ``mesh`` (and optionally a rule table) as the ambient sharding
    context consulted by :func:`constrain` / :func:`active_mesh`. ``None``
    explicitly disables constraints (every ``constrain`` is the identity)."""
    t_mesh = _active_mesh.set(mesh)
    t_rules = _active_rules.set(rules)
    try:
        yield mesh
    finally:
        _active_mesh.reset(t_mesh)
        _active_rules.reset(t_rules)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """Version-portable ``shard_map``.

    jax >= 0.6 exposes ``jax.shard_map(..., axis_names=<manual set>,
    check_vma=...)``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    with the complementary ``auto=<non-manual set>`` and ``check_rep``.
    Model code calls this wrapper with the NEW spelling only.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    # 0.4.x's partial-manual mode (auto=...) trips an XLA SPMD-partitioner
    # CHECK on CPU, so run fully manual: unmentioned axes are replicated per
    # the in_specs, which is semantically valid (just skips GSPMD
    # auto-sharding inside the body on the non-manual axes).
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def _bound_axis_names() -> set:
    """Mesh axes currently bound manually (we are tracing inside a
    ``shard_map``/``pmap`` body over them)."""
    try:
        from jax._src import core as _core

        return set(_core.get_axis_env().axis_sizes)
    except Exception:
        return set()


def constrain(x, axes, rules=None):
    """``with_sharding_constraint(x)`` under the ambient mesh; identity (the
    SAME object) when no mesh is installed, so unsharded paths cost nothing
    and stay trace-identical.

    Axes that are already *manual* (bound by an enclosing ``shard_map``) are
    dropped from the constraint: the tensor is per-shard there, and GSPMD
    rejects constraints over manual axes.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    t = axes.t if isinstance(axes, Axes) else tuple(axes)
    spec = logical_to_spec(t, x.shape, mesh, rules)
    manual = _bound_axis_names()
    if manual and any(e is not None for e in spec):
        ents = []
        for e in spec:
            grp = e if isinstance(e, tuple) else (e,) if e is not None else ()
            grp = tuple(a for a in grp if a not in manual)
            ents.append(grp[0] if len(grp) == 1 else grp or None)
        spec = PartitionSpec(*ents)
        if all(e is None for e in spec):
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, axes_tree, drop_leading: int = 0, rules=None):
    """Constrain every leaf of ``tree`` per the parallel ``axes_tree``.

    ``drop_leading=1`` strips the logical name of a scanned-away leading dim
    (the per-layer params inside ``lax.scan`` have lost their ``"layers"``
    axis)."""
    if active_mesh() is None:
        return tree

    def one(x, ax):
        t = ax.t if isinstance(ax, Axes) else tuple(ax)
        return constrain(x, t[drop_leading:], rules)

    return jax.tree.map(one, tree, axes_tree)
