"""Engine statistics: byte counters per I/O class (wal / flush / compaction /
bvalue), stall accounting, and a throughput timeline recorder used to
reproduce the paper's Fig. 2 / Fig. 9 instant-vs-average plots.

``write_amp`` = total device bytes / user payload bytes — the paper's core
metric.

Group-commit accounting (write pipeline): ``record_group`` tracks a
power-of-two histogram of writers-per-group, and ``fsyncs_per_write``
(= (wal_fsyncs + bvalue_fsyncs) / user_writes) measures how well the
leader/follower commit amortizes durability barriers — 1.0 means every
write paid its own fsync; well-batched sync workloads sit far below 0.5.

Pipelined-commit accounting (write pipeline v2): ``record_pipeline_depth``
histograms the number of commit groups in flight at group-formation time
(a max > 1 proves fsync/encode overlap actually happened), and the
``gauges`` dict carries the adaptive controller's live state —
``wal_group_effective_bytes`` (current latency-targeted byte cap) and
``wal_persist_ewma_s`` (smoothed group persist latency). ``wal_fsync_skips``
counts groups whose durability was covered by a later-started fsync.
"""
from __future__ import annotations

import random
import threading
import time
from collections import defaultdict


class EngineStats:
    """Thread-safe engine counters; read a consistent copy via ``snapshot()``.

    Counter names (``snapshot()`` keys; all monotonic):

    * ``user_writes`` / ``user_bytes`` — acknowledged entries / payload
    * ``wal_bytes`` / ``wal_records`` / ``wal_fsyncs`` — WAL I/O;
      ``wal_fsync_skips`` — groups covered by a later-started fsync
    * ``bvalue_bytes`` / ``bvalue_fsyncs`` — BValue store I/O
    * ``flush_bytes`` / ``flush_count`` — MemTable→L0 flushes
    * ``compaction_bytes`` / ``compaction_read_bytes`` / ``compaction_count``
    * ``trivial_moves`` / ``trivial_move_bytes`` — no-overlap files promoted
      by manifest edit alone (zero rewrite); ``compaction_bytes_written`` /
      ``user_bytes_written`` — aliases of ``compaction_bytes`` /
      ``user_bytes`` (the write-amp benchmark's canonical names)
    * ``gc_slices`` — auto-GC passes that yielded early on the slice budget
    * ``group_commits`` / ``group_writers`` / ``group_entries`` — group
      commit totals; ``memtable_shard_applies`` — groups applied sharded
    * ``job_{flush,compaction,gc}_count`` (+ the ``jobs`` table with wall
      seconds per kind) — background scheduler jobs; ``subcompactions`` —
      key-range shards fanned out by partitioned compactions
    * ``rate_limiter_waits`` / ``rate_limiter_wait_seconds`` — background
      I/O token-bucket backpressure; ``rate_limiter_fg_bytes`` — foreground
      value-log bytes charged to the unified budget (accounted, never
      blocked)
    * ``wal_truncated_bytes`` — torn WAL tail bytes truncated at recovery
    * ``bg_retries`` — transient background-job errors retried with backoff;
      ``bg_errors_hard`` / ``bg_errors_transient_exhausted`` — errors that
      latched the DB read-only; ``resumes`` — successful ``DB.resume()``
      calls clearing the latch
    * ``corruptions_detected`` / ``files_quarantined`` — CRC-verified reads
      that failed and the files quarantined for it
    * ``stall_stop_seconds`` / ``stall_delay_seconds`` — hard stops vs
      delayed-write-controller delays; ``stall_hist`` (pow2 ms bucket →
      count) and ``stall_p99_ms`` — the stall tail
    * ``block_cache_hits`` / ``block_cache_misses`` /
      ``block_cache_evictions`` / ``block_cache_bytes`` /
      ``block_cache_entries`` / ``block_cache_hit_rate`` — shared block
      cache (pulled live from the registered BlockCache; all-zero when the
      cache is disabled). Every ratio in ``snapshot()`` reads 0.0 on a
      fresh DB rather than dividing by zero.

    Derived (properties, also in ``snapshot()``): ``device_bytes``,
    ``write_amp``, ``fsyncs_per_write``, ``avg_group_size``,
    ``pipeline_depth_max``. Structures: ``group_size_hist`` (pow2 bucket →
    count), ``pipeline_depth_hist`` (depth → count), ``gauges`` (last-value,
    e.g. ``wal_group_effective_bytes`` / ``wal_persist_ewma_s``),
    ``timeline`` (t, acked bytes) feeding ``interval_throughput``, and
    stall accounting (``stall_seconds`` / ``stall_events``).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.counters: dict[str, int] = defaultdict(int)
        self.stall_seconds = 0.0
        self.stall_events = 0
        self._t0 = time.monotonic()
        self.timeline: list[tuple[float, int]] = []  # (t, user_bytes_acked)
        self.group_size_hist: dict[int, int] = defaultdict(int)  # pow2 bucket -> count
        self.pipeline_depth_hist: dict[int, int] = defaultdict(int)  # depth -> count
        self.stall_hist: dict[int, int] = defaultdict(int)  # pow2 ms bucket -> count
        self._stall_samples: list[float] = []  # capped reservoir for p99
        self.job_seconds: dict[str, float] = defaultdict(float)  # kind -> wall s
        self.gauges: dict[str, float] = {}  # last-value gauges (adaptive caps, ...)
        self._block_cache = None  # BlockCache; its counters merge into snapshot()

    def register_block_cache(self, cache) -> None:
        """Attach the DB's shared BlockCache so ``snapshot()`` carries its
        hit/miss/eviction counters (the cache keeps them shard-local for
        lock-free-ish reads; we pull on demand instead of pushing per-get)."""
        self._block_cache = cache

    def add(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    def add_stall(self, seconds: float, kind: str = "stall") -> None:
        """One writer stall/delay event. ``kind`` splits hard stops from
        controller delays (``stall_stop_seconds`` / ``stall_delay_seconds``)
        and every event lands in the pow2-millisecond ``stall_hist`` plus a
        capped sample reservoir feeding ``stall_p99_ms``."""
        with self._lock:
            self.stall_seconds += seconds
            self.stall_events += 1
            self.counters[f"stall_{kind}_seconds"] += seconds
            ms = seconds * 1e3
            self.stall_hist[1 << max(0, int(ms).bit_length())] += 1
            # true reservoir sample: every event over the run has equal
            # probability of being retained, so stall_p99_ms reflects the
            # whole run, not just its first 10k events
            if len(self._stall_samples) < 10_000:
                self._stall_samples.append(seconds)
            else:
                j = random.randrange(self.stall_events)
                if j < 10_000:
                    self._stall_samples[j] = seconds

    def record_job(self, kind: str, seconds: float) -> None:
        """Completion of one background job (flush/compaction/gc): counts
        and total wall seconds per kind feed the ``jobs`` snapshot table."""
        with self._lock:
            self.counters[f"job_{kind}_count"] += 1
            self.job_seconds[kind] += seconds

    def stall_p99_ms(self) -> float:
        with self._lock:
            samples = sorted(self._stall_samples)
        if not samples:
            return 0.0
        return samples[min(len(samples) - 1, int(len(samples) * 0.99))] * 1e3

    def mark_user_write(self, nbytes: int) -> None:
        self.mark_user_writes(1, nbytes)

    def mark_user_writes(self, count: int, nbytes: int) -> None:
        """Bulk ack: one lock acquisition + one timeline point per group."""
        with self._lock:
            self.counters["user_writes"] += count
            self.counters["user_bytes"] += nbytes
            self.timeline.append((time.monotonic() - self._t0, self.counters["user_bytes"]))

    def record_group(self, n_writers: int, n_entries: int) -> None:
        """One group commit: n_writers batches merged into one WAL write."""
        with self._lock:
            self.counters["group_commits"] += 1
            self.counters["group_writers"] += n_writers
            self.counters["group_entries"] += n_entries
            self.group_size_hist[1 << max(0, n_writers - 1).bit_length()] += 1

    def record_pipeline_depth(self, depth: int) -> None:
        """Commit groups in flight (incl. this one) when a group formed."""
        with self._lock:
            self.pipeline_depth_hist[depth] += 1

    def set_gauge(self, name: str, value: float) -> None:
        """Publish a last-value gauge (e.g. the adaptive group-size cap)."""
        with self._lock:
            self.gauges[name] = value

    @property
    def pipeline_depth_max(self) -> int:
        return max(self.pipeline_depth_hist, default=0)

    @property
    def device_bytes(self) -> int:
        c = self.counters
        return (
            c["wal_bytes"]
            + c["flush_bytes"]
            + c["compaction_bytes"]
            + c["bvalue_bytes"]
        )

    @property
    def write_amp(self) -> float:
        user = self.counters["user_bytes"]
        return self.device_bytes / user if user else 0.0

    @property
    def fsyncs_per_write(self) -> float:
        # fresh DB (zero writes) must read 0.0, never ZeroDivisionError
        writes = self.counters["user_writes"]
        syncs = self.counters["wal_fsyncs"] + self.counters["bvalue_fsyncs"]
        return syncs / writes if writes else 0.0

    @property
    def avg_group_size(self) -> float:
        groups = self.counters["group_commits"]
        return self.counters["group_writers"] / groups if groups else 0.0

    @property
    def block_cache_hit_rate(self) -> float:
        if self._block_cache is None:
            return 0.0
        return self._block_cache.stats()["block_cache_hit_rate"]

    def interval_throughput(self, interval_s: float = 10.0) -> list[tuple[float, float]]:
        """(t_end, MB/s) per interval — the paper's 10-second instant curve."""
        out = []
        if not self.timeline:
            return out
        t_end = interval_s
        prev_bytes = 0
        i = 0
        last_t = self.timeline[-1][0]
        while t_end <= last_t + interval_s:
            while i < len(self.timeline) and self.timeline[i][0] <= t_end:
                i += 1
            cur = self.timeline[i - 1][1] if i > 0 else 0
            out.append((t_end, (cur - prev_bytes) / interval_s / 1e6))
            prev_bytes = cur
            t_end += interval_s
        return out

    def snapshot(self) -> dict:
        with self._lock:
            d = dict(self.counters)
            hist = dict(sorted(self.group_size_hist.items()))
            depth_hist = dict(sorted(self.pipeline_depth_hist.items()))
            stall_hist = dict(sorted(self.stall_hist.items()))
            jobs = {
                kind: {
                    "count": self.counters.get(f"job_{kind}_count", 0),
                    "seconds": secs,
                }
                for kind, secs in sorted(self.job_seconds.items())
            }
            gauges = dict(self.gauges)
        for k in (
            "wal_bytes",
            "flush_bytes",
            "compaction_bytes",
            "bvalue_bytes",
            "user_bytes",
            "user_writes",
            "wal_fsyncs",
            "bvalue_fsyncs",
            "group_commits",
        ):
            d.setdefault(k, 0)
        d["device_bytes"] = self.device_bytes
        d["write_amp"] = self.write_amp
        d["stall_seconds"] = self.stall_seconds
        d["stall_events"] = self.stall_events
        d.setdefault("wal_fsync_skips", 0)
        d["fsyncs_per_write"] = self.fsyncs_per_write
        d["avg_group_size"] = self.avg_group_size
        d["group_size_hist"] = hist
        d["pipeline_depth_hist"] = depth_hist
        d["pipeline_depth_max"] = max(depth_hist, default=0)
        d["stall_hist"] = stall_hist
        d["stall_p99_ms"] = self.stall_p99_ms()
        d["jobs"] = jobs
        d.setdefault("rate_limiter_waits", 0)
        d.setdefault("rate_limiter_wait_seconds", 0.0)
        d.setdefault("rate_limiter_fg_bytes", 0)
        d.setdefault("subcompactions", 0)
        d.setdefault("trivial_moves", 0)
        d.setdefault("trivial_move_bytes", 0)
        d.setdefault("gc_slices", 0)
        d.setdefault("wal_truncated_bytes", 0)
        d.setdefault("bg_retries", 0)
        d.setdefault("bg_errors_hard", 0)
        d.setdefault("bg_errors_transient_exhausted", 0)
        d.setdefault("corruptions_detected", 0)
        d.setdefault("files_quarantined", 0)
        for k in (
            "repl_batches_shipped",
            "repl_bytes_shipped",
            "repl_batches_applied",
            "repl_frames_corrupt",
            "repl_frames_duplicate",
            "repl_catchups",
            "repl_crc_checks",
            "repl_divergence_detected",
            "repl_rebootstraps",
            "repl_ship_errors",
            "repl_lag_warnings",
            "repl_wals_retained",
            "repl_value_fetch_misses",
            "promotions",
        ):
            d.setdefault(k, 0)
        d.setdefault("resumes", 0)
        # canonical names for the write-amp trajectory (BENCH_writeamp.json):
        # device bytes compaction wrote vs. bytes the user actually stored
        d["compaction_bytes_written"] = d["compaction_bytes"]
        d["user_bytes_written"] = d["user_bytes"]
        d["gauges"] = gauges
        if self._block_cache is not None:
            d.update(self._block_cache.stats())
        else:
            d.update(
                block_cache_hits=0, block_cache_misses=0, block_cache_evictions=0,
                block_cache_bytes=0, block_cache_entries=0, block_cache_hit_rate=0.0,
                block_cache_promotions=0, block_cache_ghost_hits=0,
                block_cache_a1_bytes=0,
            )
        return d

    # ``db.stats`` is this object (attribute access keeps working for every
    # existing caller); making it callable lets ``db.stats()`` satisfy the
    # KVStore protocol's ``stats() -> dict`` the same way ShardedDB's real
    # method does.
    __call__ = snapshot
