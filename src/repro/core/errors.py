"""Typed error hierarchy + background ErrorHandler (failure subsystem).

Before this module existed, ANY exception escaping a background job latched
``scheduler.error`` forever: one transient ``OSError`` in a flush turned
every later ``put``/``get`` into ``RuntimeError("background job failed")``
with no retry, no degradation, and no way back short of reopening the DB.

The failure model now has three severities (see docs/ARCHITECTURE.md
§"Failure model & recovery"):

* **transient** — plausibly-recoverable I/O errors (EINTR/EAGAIN/EIO/...):
  background jobs retry with bounded exponential backoff + jitter
  (``bg_error_max_retries`` / ``bg_error_backoff_ms``). Only after the
  retries are exhausted does the error escalate to *hard*.
* **hard** — resource exhaustion (ENOSPC, EDQUOT, EROFS, ...), simulated
  device loss, or any non-I/O exception (a programming error is never
  retried): the DB degrades to **read-only mode** — reads keep serving,
  writes fail fast with :class:`DBReadOnlyError` — until :meth:`DB.resume`
  re-probes the Env and clears the latch.
* **corruption** — a CRC-verified read failed (:class:`CorruptionError`):
  the offending file is *quarantined* (marked in the manifest, skipped by
  compaction picking and GC) and the job aborts without latching, so one
  bad block degrades one file, not the whole DB.

``DBError`` subclasses ``RuntimeError`` and ``CorruptionError`` subclasses
``IOError`` so every pre-existing ``except RuntimeError`` /
``pytest.raises(IOError)`` contract over these paths keeps holding.
"""
from __future__ import annotations

import errno
import random
import threading
import time

# -- severities -------------------------------------------------------------

TRANSIENT = "transient"
HARD = "hard"
CORRUPTION = "corruption"

#: errnos that mean "the device/filesystem cannot take writes, retrying
#: will not help": degrade to read-only instead of burning retries.
_HARD_ERRNOS = frozenset(
    getattr(errno, name)
    for name in ("ENOSPC", "EDQUOT", "EROFS", "EACCES", "EPERM", "ENODEV", "ENXIO")
    if hasattr(errno, name)
)


# -- typed errors -----------------------------------------------------------


class DBError(RuntimeError):
    """Base of the engine's typed errors (a RuntimeError so callers written
    against the pre-hierarchy behavior keep catching them)."""


class DBReadOnlyError(DBError):
    """The DB latched read-only after a hard background error; writes fail
    fast until :meth:`DB.resume` clears the latch. ``__cause__`` carries the
    original hard error."""


class BackgroundError(DBError):
    """A background job failed hard; raised by ``wait_idle``/``flush`` when
    the scheduler error latch is set."""


class SnapshotUnstableError(DBError):
    """A read could not obtain a stable version snapshot even after retries
    and one backoff round (sustained compaction churn)."""


class ReplicaDivergedError(DBError):
    """A follower's rolling stream CRC disagreed with the primary's: its
    applied state has forked (byte flip, reorder, or lost frame that slipped
    past the frame CRC). The replica stops applying and must re-bootstrap
    from a fresh checkpoint image."""


class CorruptionError(IOError):
    """A CRC-verified read found corrupt data. Carries enough identity for
    the ErrorHandler to quarantine the file (``sst_file_no`` or
    ``bvalue_file_id``). An IOError so paranoid-read callers that predate
    the hierarchy (``pytest.raises(IOError)``) still catch it."""

    def __init__(
        self,
        msg: str,
        *,
        sst_file_no: int | None = None,
        bvalue_file_id: int | None = None,
        path: str | None = None,
    ):
        super().__init__(msg)
        self.sst_file_no = sst_file_no
        self.bvalue_file_id = bvalue_file_id
        self.path = path


class SimulatedCrashError(OSError):
    """Raised by FaultInjectionEnv once its crash point fires: the simulated
    device is gone, so classification is HARD (no retries)."""


def classify(exc: BaseException) -> str:
    """Map an exception to a severity. Unknown exception types (including
    plain RuntimeError — a programming error, not an I/O hiccup) are HARD:
    retrying a bug only repeats it."""
    if isinstance(exc, CorruptionError):
        return CORRUPTION
    if isinstance(exc, SimulatedCrashError):
        return HARD
    if isinstance(exc, SnapshotUnstableError):
        return TRANSIENT  # compaction churn: backs off and settles
    if isinstance(exc, OSError):
        if exc.errno in _HARD_ERRNOS:
            return HARD
        return TRANSIENT
    return HARD


#: sentinel returned by :meth:`ErrorHandler.run_job` when the job was
#: aborted on a handled (quarantined) corruption instead of completing.
JOB_ABORTED = object()


class ErrorHandler:
    """Severity-classified background-failure policy for one DB.

    The scheduler's sticky ``error`` latch still exists — but only *hard*
    errors reach it now. ``run_job`` wraps every background job body:
    transient errors retry in place (bounded exponential backoff with
    jitter, on the worker thread), corruption quarantines the offending
    file and aborts the job without latching, and hard errors (or exhausted
    retries) re-raise so the worker latches them and the DB enters
    read-only mode."""

    def __init__(self, db):
        self.db = db
        cfg = db.cfg
        self.max_retries = max(0, cfg.bg_error_max_retries)
        self.backoff_s = max(0.0, cfg.bg_error_backoff_ms) / 1e3
        self.backoff_max_s = max(self.backoff_s, cfg.bg_error_backoff_max_ms / 1e3)
        self._rng = random.Random(0xB44D)
        self._lock = threading.Lock()

    # -- read-only latch -------------------------------------------------
    @property
    def error(self) -> BaseException | None:
        """The hard error the DB is latched on (None = healthy)."""
        bg = getattr(self.db, "bg", None)
        return bg.error if bg is not None else None

    @property
    def read_only(self) -> bool:
        return self.error is not None

    def check_writable(self) -> None:
        """Write-path gate: fail fast (typed) while the DB is read-only."""
        if getattr(self.db, "_role", "primary") != "primary":
            raise DBReadOnlyError(
                "DB is a replica: user writes are rejected until promote()"
            )
        e = self.error
        if e is not None:
            raise DBReadOnlyError(
                "DB is read-only after a hard background error "
                "(call resume() once the cause is cleared)"
            ) from e

    def clear(self) -> None:
        """Drop the hard-error latch (resume path). The scheduler latch is
        the single source of truth, so clearing it is the whole job."""
        bg = getattr(self.db, "bg", None)
        if bg is not None:
            with bg.sched.condition:
                bg.sched.error = None

    # -- corruption ------------------------------------------------------
    def on_corruption(self, exc: CorruptionError) -> bool:
        """Quarantine the file a CorruptionError identifies. Returns True
        when the error was attributable (and the DB can keep running
        without the file); False means it must escalate to hard."""
        db = self.db
        handled = False
        if exc.sst_file_no is not None:
            if db.versions.quarantine("sst", exc.sst_file_no):
                handled = True
        if exc.bvalue_file_id is not None:
            if db.versions.quarantine("bvalue", exc.bvalue_file_id):
                handled = True
        if handled:
            db.stats.add("corruptions_detected")
            db.stats.add("files_quarantined")
        return handled

    # -- background job wrapper -----------------------------------------
    def run_job(self, fn, kind: str):
        """Run one background job body under the retry/severity policy.

        Returns ``fn()``'s result on success, :data:`JOB_ABORTED` when a
        corruption was handled by quarantine (the job gives up its slot;
        the next scheduling edge re-picks without the quarantined file),
        and re-raises hard errors for the scheduler to latch."""
        db = self.db
        attempt = 0
        while True:
            try:
                return fn()
            except Exception as exc:
                sev = classify(exc)
                if sev == CORRUPTION:
                    if self.on_corruption(exc):
                        return JOB_ABORTED
                    sev = HARD
                if (
                    sev == TRANSIENT
                    and attempt < self.max_retries
                    and not getattr(db, "_closed", False)
                ):
                    attempt += 1
                    db.stats.add("bg_retries")
                    delay = min(
                        self.backoff_max_s, self.backoff_s * (2 ** (attempt - 1))
                    )
                    # full jitter in [0.5, 1.5): retries from concurrent
                    # jobs against the same device spread out
                    time.sleep(delay * (0.5 + self._rng.random()))
                    continue
                db.stats.add(
                    "bg_errors_hard" if sev == HARD else "bg_errors_transient_exhausted"
                )
                raise
