"""Manifest: versioned level metadata + edit log (RocksDB MANIFEST analogue).

A *Version* is the immutable set of live SSTables per level. Mutations are
*VersionEdits* appended to a CRC-framed msgpack log; recovery replays the
log. Tracked alongside the file layout: ``last_seq``, ``next_file_no``, and
``bvalue_next_file_id`` so BValue files never collide across restarts.
"""
from __future__ import annotations

import os
import threading

import msgpack

from .env import DEFAULT_ENV
from .record import frame_record, iter_framed_records
from .sstable import FileMetadata, SSTableReader, table_path

MANIFEST_NAME = "MANIFEST"


class Version:
    """Immutable snapshot of the LSM level structure."""

    __slots__ = ("levels", "_level_bytes")

    def __init__(self, num_levels: int, levels=None):
        self.levels: list[list[FileMetadata]] = (
            levels if levels is not None else [[] for _ in range(num_levels)]
        )
        self._level_bytes: list[int] | None = None

    def clone(self) -> "Version":
        return Version(len(self.levels), [list(lv) for lv in self.levels])

    def level_bytes(self, level: int) -> int:
        # memoized on first read: a Version is immutable once installed
        # (clones are only mutated before publication), and the write path
        # consults level sizes on every commit — O(levels), not O(files)
        cache = self._level_bytes
        if cache is None:
            cache = [sum(f.size for f in lv) for lv in self.levels]
            self._level_bytes = cache
        return cache[level]

    def files_touching(self, level: int, smallest: bytes, largest: bytes):
        files = self.levels[level]
        if level == 0:
            # L0 is ordered by age, not key — linear scan is the only option
            return [f for f in files if f.largest >= smallest and f.smallest <= largest]
        # sorted levels are key-disjoint and ordered by smallest (so also by
        # largest): binary-search the first candidate, extend while touching
        # — overlap-ratio picking calls this per file per pick, so O(log n +
        # overlap) instead of O(level) matters
        lo, hi = 0, len(files)
        while lo < hi:
            mid = (lo + hi) // 2
            if files[mid].largest < smallest:
                lo = mid + 1
            else:
                hi = mid
        out = []
        for f in files[lo:]:
            if f.smallest > largest:
                break
            out.append(f)
        return out

    def overlap_bytes(self, level: int, smallest: bytes, largest: bytes) -> int:
        """Total size of the files in ``level`` whose key range touches
        [smallest, largest] — the bytes a compaction of that range would
        have to rewrite at (or a trivial move would park on top of) this
        level. Used by overlap-ratio picking and the grandparent checks."""
        return sum(f.size for f in self.files_touching(level, smallest, largest))

    def files_from(self, level: int, start: bytes):
        """Files in a SORTED level (L1+) that may hold keys >= ``start``,
        in key order — binary search for the first candidate, so a lazy
        concatenating scan iterator does no per-file work up front."""
        files = self.levels[level]
        lo, hi = 0, len(files)
        while lo < hi:
            mid = (lo + hi) // 2
            if files[mid].largest < start:
                lo = mid + 1
            else:
                hi = mid
        return files[lo:]

    def candidates_for_get(self, key: bytes):
        """Yield (level, FileMetadata) newest-first for a point lookup.

        Sorted-level file ranges are disjoint in their POINT keys, but the
        bounds are extended by range-tombstone spans, which clip exactly at
        a neighbour's first key — two files can *touch* on one key. Yield
        every touching file (at most two), in order: the earlier file holds
        the newer versions when a key sits on a table boundary."""
        # L0 files may overlap — newest first (we append newest at index 0).
        for f in self.levels[0]:
            if f.smallest <= key <= f.largest:
                yield 0, f
        for level in range(1, len(self.levels)):
            files = self.levels[level]
            lo, hi = 0, len(files)
            while lo < hi:  # first file with largest >= key
                mid = (lo + hi) // 2
                if files[mid].largest < key:
                    lo = mid + 1
                else:
                    hi = mid
            for f in files[lo:]:
                if f.smallest > key:
                    break
                yield level, f


class VersionSet:
    def __init__(self, directory: str, num_levels: int, block_cache=None, env=None, paranoid=False):
        self.dir = directory
        self.num_levels = num_levels
        # shared decoded-block cache handed to every SSTableReader (None =
        # caching disabled); owned by the DB, shared with gets/scans/compaction
        self.block_cache = block_cache
        self.env = env or DEFAULT_ENV
        self.paranoid = paranoid
        self.current = Version(num_levels)
        self.last_seq = 0
        self.next_file_no = 1
        self.bvalue_next_file_id = 0
        # files a CRC-verified read found corrupt: still in the levels (their
        # intact blocks keep serving reads) but excluded from compaction
        # picking and, for value files, from GC — the damage is contained
        # instead of being rewritten downstream or crashing jobs forever.
        self.quarantined: set[int] = set()
        self.quarantined_bvalues: set[int] = set()
        self._manifest = None
        self._lock = threading.Lock()
        self._readers: dict[int, SSTableReader] = {}
        self._retired: list[SSTableReader] = []  # dropped, close-deferred
        # -- cursor pinning ------------------------------------------------
        # while pins > 0 (open cursors/checkpoints), readers dropped by
        # compaction PARK instead of retiring (still resolvable via
        # ``reader()``) and input unlinks are deferred — a lazy merged scan
        # may open a cold input file minutes after the compaction that
        # replaced it committed.
        self._pins = 0
        self._parked: dict[int, SSTableReader] = {}
        self._deferred_unlinks: list[str] = []
        self.compaction_ptr: dict[int, bytes] = {}
        # per-file compaction locks: a file is locked from pick time until
        # its job's manifest edit commits, so concurrent compaction jobs
        # can never claim overlapping inputs (and a locked file is only
        # ever deleted by the job holding its lock).
        self._compacting: set[int] = set()

    # -- manifest log -----------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, MANIFEST_NAME)

    def open(self) -> None:
        path = self._manifest_path()
        if self.env.exists(path):
            with self.env.open(path, "rb") as f:
                buf = f.read()
            for payload in iter_framed_records(buf):
                self._apply(msgpack.unpackb(payload))
        self._sweep_orphans()
        self._manifest = self.env.open(path, "ab", buffering=0)

    def _sweep_orphans(self) -> None:
        """Delete .sst files not referenced by any level — the outputs of a
        flush/compaction (or individual subcompaction shards) that crashed
        before its atomic manifest edit. Also bumps ``next_file_no`` past
        every on-disk table so a recovered counter can never collide."""
        live = {f.file_no for lv in self.current.levels for f in lv}
        for name in self.env.listdir(self.dir):
            if not name.endswith(".sst"):
                continue
            try:
                no = int(name[: -len(".sst")])
            except ValueError:
                continue
            self.next_file_no = max(self.next_file_no, no + 1)
            if no not in live:
                try:
                    self.env.unlink(os.path.join(self.dir, name))
                except OSError:
                    pass

    def _apply(self, edit: dict) -> None:
        v = self.current.clone()
        for level, meta in edit.get(b"add", edit.get("add", [])):
            fm = FileMetadata.from_wire(meta)
            if level == 0:
                v.levels[level].insert(0, fm)  # newest first
            else:
                v.levels[level].append(fm)
                v.levels[level].sort(key=lambda f: f.smallest)
        for level, file_no in edit.get(b"delete", edit.get("delete", [])):
            v.levels[level] = [f for f in v.levels[level] if f.file_no != file_no]
            self.quarantined.discard(file_no)
        self.current = v
        for kind, ident in edit.get(b"quarantine", edit.get("quarantine", [])):
            kind = kind.decode() if isinstance(kind, bytes) else kind
            if kind == "sst":
                self.quarantined.add(ident)
            elif kind == "bvalue":
                self.quarantined_bvalues.add(ident)
        for k_raw in (b"last_seq", "last_seq"):
            if k_raw in edit:
                self.last_seq = max(self.last_seq, edit[k_raw])
        for k_raw in (b"next_file_no", "next_file_no"):
            if k_raw in edit:
                self.next_file_no = max(self.next_file_no, edit[k_raw])
        for k_raw in (b"bvalue_next_file_id", "bvalue_next_file_id"):
            if k_raw in edit:
                self.bvalue_next_file_id = max(self.bvalue_next_file_id, edit[k_raw])

    def log_and_apply(self, edit: dict) -> None:
        with self._lock:
            edit.setdefault("next_file_no", self.next_file_no)
            payload = msgpack.packb(edit, use_bin_type=True)
            self._manifest.write(frame_record(payload))
            self.env.fsync(self._manifest)
            self._apply(edit)

    # -- quarantine -------------------------------------------------------
    def quarantine(self, kind: str, ident: int) -> bool:
        """Mark a corrupt file so pick/GC skip it. Durable via a manifest
        edit when possible; if even the manifest write fails, the mark is
        kept in memory (better to run degraded now and rediscover the
        corruption after a restart than to crash). Returns False when the
        file was already quarantined (nothing new to handle)."""
        with self._lock:
            already = (
                ident in self.quarantined
                if kind == "sst"
                else ident in self.quarantined_bvalues
            )
        if already:
            return False
        try:
            self.log_and_apply({"quarantine": [(kind, ident)]})
        except OSError:
            with self._lock:
                if kind == "sst":
                    self.quarantined.add(ident)
                else:
                    self.quarantined_bvalues.add(ident)
        return True

    def quarantined_files(self) -> set[int]:
        with self._lock:
            return set(self.quarantined)

    # -- file number / reader management -------------------------------------
    def new_file_no(self) -> int:
        with self._lock:
            no = self.next_file_no
            self.next_file_no += 1
            return no

    def try_lock_files(self, file_nos) -> bool:
        """Atomically acquire the compaction lock on every file in
        ``file_nos`` — all or nothing. Returns False if any is held."""
        with self._lock:
            if any(no in self._compacting for no in file_nos):
                return False
            self._compacting.update(file_nos)
            return True

    def unlock_files(self, file_nos) -> None:
        with self._lock:
            self._compacting.difference_update(file_nos)

    def locked_files(self) -> set[int]:
        with self._lock:
            return set(self._compacting)

    def pin(self) -> None:
        """A cursor (or checkpoint) is walking the current version: park
        dropped readers and defer input unlinks until every pin releases."""
        with self._lock:
            self._pins += 1

    def pin_current(self):
        """Atomically pin AND return the current version. The two must be
        one critical section: a compaction edit + input unlink between a
        ``current`` read and the pin() would hand the caller a version
        whose files are already gone. (A pin landing between an edit and
        its input unlink merely defers that unlink — conservative, cleaned
        up at unpin.) Pair with :meth:`unpin`."""
        with self._lock:
            self._pins += 1
            return self.current

    def unpin(self) -> None:
        with self._lock:
            self._pins -= 1
            if self._pins > 0:
                return
            to_unlink = self._deferred_unlinks
            self._deferred_unlinks = []
            # parked readers join the normal close-deferred retirement
            self._retired.extend(self._parked.values())
            self._parked.clear()
            to_close = self._retired[:-32] if len(self._retired) > 64 else []
            if to_close:
                self._retired = self._retired[-32:]
        for path in to_unlink:
            try:
                self.env.unlink(path)
            except OSError:
                pass  # rediscovered by the next open's orphan sweep
        for r in to_close:
            r.close()

    def defer_or_unlink(self, path: str) -> None:
        """Unlink a replaced input table now — or, while cursors hold pins,
        after the last pin releases (the file stays openable meanwhile)."""
        with self._lock:
            if self._pins > 0:
                self._deferred_unlinks.append(path)
                return
        self.env.unlink(path)

    def reader(self, file_no: int) -> SSTableReader:
        with self._lock:
            r = self._readers.get(file_no)
            if r is None:
                r = self._parked.get(file_no)
        if r is not None:
            return r
        # construct OUTSIDE the lock (opens the file + loads its index);
        # on a race the loser's never-shared reader is closed immediately
        r = SSTableReader(
            table_path(self.dir, file_no), file_no, self.block_cache,
            env=self.env, paranoid=self.paranoid,
        )
        with self._lock:
            existing = self._readers.get(file_no)
            if existing is None:
                self._readers[file_no] = r
                return r
        r.close()
        return existing

    def drop_reader(self, file_no: int) -> None:
        # Don't close immediately: a get() walking a just-superseded version
        # snapshot may still pread() this reader, and closing would free the
        # fd for reuse (a concurrent pread would then silently read some
        # OTHER file). Retire it instead and close a stale batch once enough
        # pile up — any in-flight lookup is long done by then.
        with self._lock:
            r = self._readers.pop(file_no, None)
            if r is None:
                return
            if self._pins > 0:
                # an open cursor may still reach this file through its
                # pinned version — keep it resolvable until unpin
                self._parked[file_no] = r
                return
            self._retired.append(r)
            to_close = self._retired[:-32] if len(self._retired) > 64 else []
            if to_close:
                self._retired = self._retired[-32:]
        for r in to_close:
            r.close()
        if self.block_cache is not None:
            # file numbers are never reused, so stale blocks could only
            # waste capacity — reclaim them eagerly anyway
            self.block_cache.evict_file(file_no)

    def close(self) -> None:
        if self._manifest is not None:
            self._manifest.close()
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        for r in self._parked.values():
            r.close()
        self._parked.clear()
        for r in self._retired:
            r.close()
        self._retired.clear()
