"""Shared token-bucket rate limiter — one device model for every byte.

Every background byte written — compaction output, MemTable→L0 flush, GC
value rewrites — draws tokens from one bucket (``DBConfig.
bg_io_bytes_per_sec``), so a compaction burst can never monopolize the
device bandwidth the foreground WAL/BValue fsyncs need. This is the
RocksDB ``GenericRateLimiter`` idea, simplified:

* The bucket refills continuously at ``bytes_per_sec`` up to a small burst
  allowance; a request may drive the balance negative (deficit model), in
  which case *later* requests wait for the balance to recover — large
  writes are never split, they just push their cost onto the next caller.
* Three priorities, in descending order of entitlement:

  - ``PRI_FG`` (foreground value-log writes, WAL-time separation): charged
    but **never blocked** — a user write must not stall on a background
    budget. Instead the limiter folds foreground traffic into an EWMA
    bytes/sec estimate and *shrinks the refill* available to background
    work to ``rate - fg_rate`` (floored at ``bg_min_fraction * rate``), so
    value-log and compaction I/O genuinely share one device budget. The
    instantaneous deficit a FG charge may create is clamped to one burst —
    foreground awareness must dampen background work, not wedge it behind
    an unbounded debt.
  - ``PRI_HIGH`` (flush — it unblocks writers, so making it wait would
    turn background throttling into foreground stop-stalls): *accounted
    but never blocked*; its deficit pushes back on LOW.
  - ``PRI_LOW`` (compaction / GC): queues FIFO until the balance recovers.
    GC's value rewrites **inherit** this priority when they re-enter the
    foreground write path (priority inheritance — the charge belongs to
    the initiator, not the code path).
* ``bytes_per_sec == 0`` disables limiting entirely: ``request`` is a
  no-op, so the default configuration has zero overhead.

Waits are accounted to ``EngineStats`` (``rate_limiter_waits`` /
``rate_limiter_wait_seconds``; foreground charges under
``rate_limiter_fg_bytes``) so the stability and write-amp benchmarks can
show how much work was deferred and how the device budget split.
"""
from __future__ import annotations

import threading
import time
from collections import deque

PRI_HIGH = 0  # flush: unblocks foreground writers
PRI_LOW = 1  # compaction / GC: pure background
PRI_FG = 2  # foreground value-log writes: shape the budget, never block

#: background writers charge the limiter in chunks of at most this many
#: bytes, so a single huge request can't stall the bucket for seconds
IO_CHUNK = 256 << 10

#: seconds of smoothing on the foreground bytes/sec estimate
_FG_EWMA_TAU_S = 1.0


class RateLimiter:
    def __init__(
        self,
        bytes_per_sec: int,
        refill_period_s: float = 0.005,
        stats=None,
        bg_min_fraction: float = 0.1,
    ):
        self.rate = int(bytes_per_sec)
        self._period = refill_period_s
        self._stats = stats
        self._bg_min_fraction = bg_min_fraction
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._waiters: deque = deque()  # LOW requests, FIFO
        self._available = float(max(0, self.rate) * refill_period_s)
        self._burst = max(float(IO_CHUNK), self.rate * 0.05)
        self._last_refill = time.monotonic()
        # foreground-awareness state: bytes charged at PRI_FG since the
        # last refill edge, and the smoothed foreground bytes/sec they
        # imply (shrinks the background refill)
        self._fg_acc = 0
        self._fg_rate = 0.0

    @property
    def enabled(self) -> bool:
        return self.rate > 0

    def fg_rate_estimate(self) -> float:
        """Smoothed foreground (PRI_FG) bytes/sec — observability."""
        with self._lock:
            return self._fg_rate

    def request(self, nbytes: int, priority: int = PRI_LOW) -> float:
        """Block until ``nbytes`` of I/O budget is granted.

        Returns the seconds spent waiting (0.0 on the fast path; FG and
        HIGH never wait). Unlimited (rate 0) or non-positive requests
        return immediately.
        """
        if self.rate <= 0 or nbytes <= 0:
            return 0.0
        me = object()
        t0 = None
        with self._cv:
            if priority == PRI_FG:
                # account + adapt, never wait: the EWMA shrinks the
                # background refill; the immediate deficit an FG charge
                # adds is clamped to one burst so FG bursts dampen LOW
                # instead of wedging it — but the clamp must never RAISE
                # a balance a HIGH/LOW deficit already drove deeper, or
                # foreground traffic would erase the pushback on
                # background work instead of adding to it
                self._fg_acc += nbytes
                self._refill_locked()
                self._available = min(
                    self._available, max(self._available - nbytes, -self._burst)
                )
                if self._stats is not None:
                    self._stats.add("rate_limiter_fg_bytes", nbytes)
                return 0.0
            if priority == PRI_HIGH:
                # charge the bucket but never wait: the deficit defers
                # queued LOW work instead of stalling the flush path
                self._refill_locked()
                self._available -= nbytes
                return 0.0
            self._waiters.append(me)
            while True:
                self._refill_locked()
                if self._available > 0.0 and self._waiters[0] is me:
                    self._available -= nbytes  # may go negative: deficit
                    self._waiters.popleft()
                    self._cv.notify_all()
                    break
                if t0 is None:
                    t0 = time.monotonic()
                # wake at the next refill edge (or when the head changes)
                self._cv.wait(timeout=self._period)
        if t0 is None:
            return 0.0
        waited = time.monotonic() - t0
        if self._stats is not None:
            self._stats.add("rate_limiter_waits")
            self._stats.add("rate_limiter_wait_seconds", waited)
        return waited

    def _refill_locked(self) -> None:
        now = time.monotonic()
        dt = now - self._last_refill
        if dt <= 0:
            return
        # fold foreground bytes into the smoothed fg bytes/sec estimate
        alpha = min(1.0, dt / _FG_EWMA_TAU_S)
        self._fg_rate = (1.0 - alpha) * self._fg_rate + alpha * (self._fg_acc / dt)
        self._fg_acc = 0
        effective = max(self.rate * self._bg_min_fraction, self.rate - self._fg_rate)
        self._available = min(self._burst, self._available + dt * effective)
        self._last_refill = now
