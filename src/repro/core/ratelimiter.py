"""Shared token-bucket rate limiter for background I/O.

Every background byte written — compaction output, MemTable→L0 flush, GC
value rewrites — draws tokens from one bucket (``DBConfig.
bg_io_bytes_per_sec``), so a compaction burst can never monopolize the
device bandwidth the foreground WAL/BValue fsyncs need. This is the
RocksDB ``GenericRateLimiter`` idea, simplified:

* The bucket refills continuously at ``bytes_per_sec`` up to a small burst
  allowance; a request may drive the balance negative (deficit model), in
  which case *later* requests wait for the balance to recover — large
  writes are never split, they just push their cost onto the next caller.
* Two priorities: ``PRI_HIGH`` (flush — it unblocks writers, so making it
  wait would turn background throttling into foreground stop-stalls) is
  *accounted but never blocked*: it deducts its bytes and returns, and the
  deficit it creates pushes back on ``PRI_LOW`` (compaction / GC), which
  queues FIFO until the balance recovers.
* ``bytes_per_sec == 0`` disables limiting entirely: ``request`` is a
  no-op, so the default configuration has zero overhead.

Waits are accounted to ``EngineStats`` (``rate_limiter_waits`` /
``rate_limiter_wait_seconds``) so the stability benchmark can show how
much background work was deferred.
"""
from __future__ import annotations

import threading
import time
from collections import deque

PRI_HIGH = 0  # flush: unblocks foreground writers
PRI_LOW = 1  # compaction / GC: pure background

#: background writers charge the limiter in chunks of at most this many
#: bytes, so a single huge request can't stall the bucket for seconds
IO_CHUNK = 256 << 10


class RateLimiter:
    def __init__(
        self,
        bytes_per_sec: int,
        refill_period_s: float = 0.005,
        stats=None,
    ):
        self.rate = int(bytes_per_sec)
        self._period = refill_period_s
        self._stats = stats
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._waiters: deque = deque()  # LOW requests, FIFO
        self._available = float(max(0, self.rate) * refill_period_s)
        self._burst = max(float(IO_CHUNK), self.rate * 0.05)
        self._last_refill = time.monotonic()

    @property
    def enabled(self) -> bool:
        return self.rate > 0

    def request(self, nbytes: int, priority: int = PRI_LOW) -> float:
        """Block until ``nbytes`` of background I/O budget is granted.

        Returns the seconds spent waiting (0.0 on the fast path). Unlimited
        (rate 0) or non-positive requests return immediately.
        """
        if self.rate <= 0 or nbytes <= 0:
            return 0.0
        me = object()
        t0 = None
        with self._cv:
            if priority == PRI_HIGH:
                # charge the bucket but never wait: the deficit defers
                # queued LOW work instead of stalling the flush path
                self._refill_locked()
                self._available -= nbytes
                return 0.0
            self._waiters.append(me)
            while True:
                self._refill_locked()
                if self._available > 0.0 and self._waiters[0] is me:
                    self._available -= nbytes  # may go negative: deficit
                    self._waiters.popleft()
                    self._cv.notify_all()
                    break
                if t0 is None:
                    t0 = time.monotonic()
                # wake at the next refill edge (or when the head changes)
                self._cv.wait(timeout=self._period)
        if t0 is None:
            return 0.0
        waited = time.monotonic() - t0
        if self._stats is not None:
            self._stats.add("rate_limiter_waits")
            self._stats.add("rate_limiter_wait_seconds", waited)
        return waited

    def _refill_locked(self) -> None:
        now = time.monotonic()
        dt = now - self._last_refill
        if dt > 0:
            self._available = min(self._burst, self._available + dt * self.rate)
            self._last_refill = now
