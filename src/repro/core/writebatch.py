"""WriteBatch — atomic multi-op writes, the unit of group commit.

A batch collects ``put``/``delete`` operations and commits them atomically
via :meth:`DB.write`: all entries share one sequence number and are encoded
into a single CRC-framed WAL record, so crash replay recovers the whole
batch or none of it (RocksDB WriteBatch semantics, minus column families).

``DB.put``/``DB.delete`` are single-entry batches under the hood; the write
pipeline's leader merges many batches from concurrent writers into one WAL
write + fsync (see :mod:`.db`).
"""
from __future__ import annotations

from .record import kTypeDeletion, kTypeRangeDeletion, kTypeValue


class WriteBatch:
    """An ordered list of ops committed atomically by :meth:`DB.write`.

    Ops apply in insertion order, so a later ``put``/``delete`` of the same
    key wins within the batch. Builder-style: ``put``/``delete`` return
    ``self`` for chaining. A batch is reusable after ``clear()``.
    """

    __slots__ = ("_ops", "_nbytes")

    def __init__(self) -> None:
        self._ops: list[tuple[int, bytes, bytes]] = []
        self._nbytes = 0

    def put(self, key: bytes, value: bytes) -> "WriteBatch":
        """Queue ``key -> value`` (separation decided at commit time)."""
        self._ops.append((kTypeValue, key, value))
        self._nbytes += len(key) + len(value)
        return self

    def delete(self, key: bytes) -> "WriteBatch":
        """Queue a tombstone for ``key``."""
        self._ops.append((kTypeDeletion, key, b""))
        self._nbytes += len(key)
        return self

    def delete_range(self, start: bytes, end: bytes) -> "WriteBatch":
        """Queue a range tombstone deleting every key in ``[start, end)``.
        Rides the WAL as a normal entry (key=start, value=end)."""
        if not start < end:
            raise ValueError("delete_range needs start < end")
        self._ops.append((kTypeRangeDeletion, start, end))
        self._nbytes += len(start) + len(end)
        return self

    @classmethod
    def from_entries(
        cls, entries: list[tuple[int, bytes, bytes]]
    ) -> "WriteBatch":
        """Rebuild a batch from decoded ``(type, key, value)`` entries —
        the shape WAL replay and replication apply produce."""
        batch = cls()
        for type_, key, value in entries:
            batch._ops.append((type_, key, value))
            batch._nbytes += len(key) + len(value)
        return batch

    def __iter__(self):
        """Yield ``(type, key, value)`` ops in insertion order."""
        return iter(self._ops)

    def clear(self) -> None:
        """Drop all queued ops, making the batch reusable."""
        self._ops.clear()
        self._nbytes = 0

    def __len__(self) -> int:
        return len(self._ops)

    @property
    def size_bytes(self) -> int:
        """Approximate user payload bytes in this batch."""
        return self._nbytes
