"""Primary/replica WAL shipping: follower apply, bootstrap, promotion.

The replication unit is the committed WAL group — exactly the payloads the
group-commit leader just persisted (BVLSM makes this cheap: big values are
already separated into immutable-once-sealed BValue files, so only the
lightweight key/pointer stream ships in order; the follower pulls value
bytes out of band and drops them at the *same* ``(file_id, offset)``, which
keeps every shipped ValueOffset valid verbatim).

Shape of the system::

    primary._lead_group_locked (publish, seq order)
        └─ Replicator.on_group ── frame ──► Env.ship(stream, wire)
                                                  │ (FaultInjectionEnv may
                                                  │  drop/dup/reorder/corrupt)
                                  Follower.enqueue ◄─ InProcessTransport
                                      │ (scheduler: single-flight repl job)
                                  Follower.drain
                                      ├─ mirror separated values (pread from
                                      │  primary, pwrite + fsync locally)
                                      ├─ append payloads to own WAL
                                      └─ memtable apply at the shipped seq

* **Ordering/dedup** — frames carry contiguous ``(seq, payload)`` runs. The
  follower applies only ``applied+1``-contiguous runs; stale frames are
  duplicates (dropped), future frames buffer until a WAL **catch-up**
  (:class:`~.wal.WALSegmentReader` over the primary's durable segments)
  bridges the gap. The primary *retains* flushed WAL segments until every
  registered follower has acked past them, so a catch-up can always find
  the missing groups.
* **Divergence detection** — the primary folds a rolling CRC over each run
  of ``repl_crc_interval`` consecutive payloads and ships the digest with a
  later frame; the follower folds the same CRC over what it actually
  applied. A mismatch means the streams forked (a flip the frame CRC
  missed, an apply bug, a lost-and-refetched group that differed): the
  follower stops applying and flags ``needs_rebootstrap`` instead of
  silently serving forked data.
* **Bootstrap** — :func:`bootstrap_replica` materializes a checkpoint image
  (optionally incremental against the previous image) and opens it as a
  replica; :func:`attach` registers the follower *before* reading its
  position so WAL retention covers the catch-up window with no gap.
* **Promotion** — :meth:`DB.promote` seals the stream, replays whatever
  suffix survives in the dead primary's durable WAL (in sync mode that is
  every acked write), discards non-contiguous buffered frames, moves the
  BValue id allocator past the mirrored id space, and flips the write
  latch. Idempotent.
"""
from __future__ import annotations

import os
import shutil
import threading
import zlib

import msgpack

from .record import (
    ValueOffset,
    decode_entries,
    frame_record,
    iter_framed_records,
    kTypeValuePtr,
)
from .wal import WALSegmentReader


def _run_of(seq: int, interval: int) -> int:
    return (seq - 1) // interval


class InProcessTransport:
    """Delivers framed batches primary → follower, routing every send
    through the *primary's* ``Env.ship`` hook — a ``FaultInjectionEnv``
    there can drop, duplicate, reorder, or corrupt frames in flight, and a
    simulated primary crash severs the stream (a dead machine cannot
    send)."""

    def __init__(self, env, stream: str):
        self._env = env
        self.stream = stream
        self._deliver = None

    def connect(self, deliver) -> None:
        self._deliver = deliver

    def send(self, wire: bytes) -> None:
        for frame in self._env.ship(self.stream, wire):
            deliver = self._deliver
            if deliver is not None:
                deliver(frame)

    def close(self) -> None:
        self._deliver = None


class Replicator:
    """Primary-side stream state: ships publish-ordered groups to every
    registered follower, tracks acks, and retains flushed WAL segments
    needed for follower catch-up."""

    def __init__(self, db):
        self.db = db
        self._lock = threading.Lock()
        self._sinks: dict[str, InProcessTransport] = {}
        self._acked: dict[str, int] = {}
        self._retained: list[tuple[int, str]] = []  # (last_seq, wal path)
        # rolling divergence CRC: current run index + folded crc, plus
        # completed-run digests waiting to ride the next frame out
        self._run: int | None = None
        self._run_crc = 0
        self._pending_checks: list[tuple[int, int]] = []
        self.shipped_seq = 0

    # -- membership ------------------------------------------------------
    def register(self, follower_id: str, transport: InProcessTransport, acked: int) -> None:
        with self._lock:
            self._sinks[follower_id] = transport
            self._acked[follower_id] = acked

    def unregister(self, follower_id: str) -> None:
        with self._lock:
            sink = self._sinks.pop(follower_id, None)
            self._acked.pop(follower_id, None)
        if sink is not None:
            sink.close()
        self._prune_retained()

    @property
    def active(self) -> bool:
        return bool(self._sinks)

    # -- WAL retention ---------------------------------------------------
    def min_acked(self) -> int:
        with self._lock:
            if not self._acked:
                return 1 << 62
            return min(self._acked.values())

    def should_retain(self, last_seq: int) -> bool:
        return self.min_acked() < last_seq

    def retain_wal(self, path: str, last_seq: int) -> None:
        with self._lock:
            self._retained.append((last_seq, path))
        self.db.stats.add("repl_wals_retained")

    def _prune_retained(self) -> None:
        floor = self.min_acked()
        drop: list[str] = []
        with self._lock:
            keep = []
            for last_seq, path in self._retained:
                if last_seq > floor:
                    keep.append((last_seq, path))
                else:
                    drop.append(path)
            self._retained = keep
        for path in drop:
            try:
                self.db.env.unlink(path)
            except OSError:
                pass

    def ack(self, follower_id: str, seq: int) -> None:
        with self._lock:
            if follower_id not in self._acked:
                return
            if seq > self._acked[follower_id]:
                self._acked[follower_id] = seq
        self._prune_retained()
        self.db.stats.set_gauge("repl_min_acked_seq", self.min_acked())

    # -- shipping --------------------------------------------------------
    def on_group(self, batches: list[tuple[int, bytes]]) -> None:
        """Called by the publish stage, under the DB mutex, strictly in
        sequence order. Folds the divergence CRC, frames the group (split
        at ``repl_batch_bytes``), and ships to every sink. Never raises:
        replication failure must not fail the client write."""
        cfg = self.db.cfg
        interval = max(1, cfg.repl_crc_interval)
        cap = max(1, cfg.repl_batch_bytes)
        frames: list[bytes] = []
        with self._lock:
            if not self._sinks:
                return
            chunk: list[tuple[int, bytes]] = []
            chunk_bytes = 0

            def _flush_chunk():
                nonlocal chunk, chunk_bytes
                if not chunk:
                    return
                checks, self._pending_checks = self._pending_checks, []
                msg = {"b": chunk, "c": checks}
                frames.append(frame_record(msgpack.packb(msg, use_bin_type=True)))
                chunk = []
                chunk_bytes = 0

            for seq, payload in batches:
                run = _run_of(seq, interval)
                if self._run is None:
                    self._run = run
                if run != self._run:
                    self._pending_checks.append((self._run, self._run_crc))
                    self._run, self._run_crc = run, 0
                self._run_crc = zlib.crc32(payload, self._run_crc) & 0xFFFFFFFF
                self.shipped_seq = seq
                if chunk_bytes + len(payload) > cap:
                    _flush_chunk()
                chunk.append((seq, payload))
                chunk_bytes += len(payload)
            _flush_chunk()
            sinks = list(self._sinks.values())
        stats = self.db.stats
        for wire in frames:
            stats.add("repl_bytes_shipped", len(wire))
            for sink in sinks:
                try:
                    sink.send(wire)
                except Exception:
                    stats.add("repl_ship_errors")
        stats.add("repl_batches_shipped", len(batches))
        stats.set_gauge("repl_shipped_seq", self.shipped_seq)

    def close(self) -> None:
        for follower_id in list(self._sinks):
            self.unregister(follower_id)


class Follower:
    """Replica-side stream state: frame inbox, ordered apply (value mirror
    → local WAL → memtable), gap catch-up from the primary's durable WAL,
    and rolling-CRC divergence checks."""

    #: buffered out-of-order frames beyond this are dropped — catch-up
    #: re-reads them from the primary's WAL anyway
    MAX_PENDING = 64
    #: completed CRC runs kept around waiting for the primary's digest
    MAX_RUNS = 64

    def __init__(self, db, primary_path: str, primary_env=None):
        self.db = db
        self.primary_path = primary_path
        # reads of the primary's files (WAL catch-up, value fetch) go
        # through the *replica's* env: they are this machine's I/O, and a
        # crashed primary's disk stays readable
        self._penv = primary_env or db.env
        self._reader = WALSegmentReader(primary_path, env=self._penv)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._drain_lock = threading.Lock()  # one drain at a time; seal joins it
        self._inbox: list[bytes] = []
        self._pending: dict[int, list[tuple[int, bytes]]] = {}  # first_seq -> run
        self._dirty = False
        self.sealed = False
        self.diverged = False
        self.needs_rebootstrap = False
        self.last_shipped_seen = db._seq
        # divergence CRC state: run -> folded crc for runs we applied, and
        # run -> expected crc received from the primary. Runs that started
        # before our bootstrap point were only partially observed — never
        # checkable.
        self._runs: dict[int, int] = {}
        self._expected: dict[int, int] = {}
        self._check_floor = db._seq  # can check run r iff floor <= r*interval
        self._last_gap: int | None = None
        self.on_applied = None  # ack callback, set by attach()
        self._mirror_read_fds: dict[int, int] = {}
        self._mirror_write_fds: dict[int, int] = {}
        self.max_mirrored_file = -1
        # async primaries ship the pointer before the value write thread
        # has necessarily hit the disk — a missed fetch is retried on
        # later drains (the bytes land moments later) instead of leaving
        # a permanent hole in the mirrored file
        self._miss_retry: dict[tuple[int, int], ValueOffset] = {}

    # -- transport-facing -------------------------------------------------
    def enqueue(self, wire: bytes) -> None:
        with self._lock:
            if self.sealed or self.diverged:
                return
            self._inbox.append(wire)
            self._dirty = True
        self.db.bg.maybe_schedule_repl()

    def nudge(self) -> None:
        """Mark work pending (e.g. the stream went quiet after a dropped
        tail frame) so the next drain runs a catch-up read."""
        with self._lock:
            self._dirty = True
        self.db.bg.maybe_schedule_repl()

    def has_work(self) -> bool:
        with self._lock:
            return self._dirty and not self.sealed and not self.diverged

    @property
    def applied_seq(self) -> int:
        return self.db._seq

    @property
    def lag(self) -> int:
        return max(0, self.last_shipped_seen - self.db._seq)

    # -- apply loop (scheduler job) ---------------------------------------
    def drain(self) -> None:
        with self._drain_lock:
            while True:
                with self._lock:
                    self._dirty = False
                    frames, self._inbox = self._inbox, []
                    if self.sealed or self.diverged:
                        return
                for wire in frames:
                    self._ingest(wire)
                progressed = self._apply_ready()
                if not progressed and self._gapped():
                    self._catch_up()
                    self._apply_ready()
                self._retry_misses()
                with self._lock:
                    self._cv.notify_all()
                    if not self._dirty:
                        return

    def _gapped(self) -> bool:
        with self._lock:
            if self._pending:
                return True
        return self.last_shipped_seen > self.db._seq

    def _ingest(self, wire: bytes) -> None:
        stats = self.db.stats
        payloads = list(iter_framed_records(wire))
        if len(payloads) != 1:
            stats.add("repl_frames_corrupt")  # frame CRC caught a flip
            return
        try:
            msg = msgpack.unpackb(payloads[0], raw=False)
            batches = [(int(s), bytes(p)) for s, p in msg["b"]]
            checks = [(int(r), int(c)) for r, c in msg.get("c", ())]
        except Exception:
            stats.add("repl_frames_corrupt")
            return
        with self._lock:
            for run, crc in checks:
                self._expected[run] = crc
        interval = max(1, self.db.cfg.repl_crc_interval)
        # digests may describe runs we already applied — check them now
        self._check_completed_runs(interval)
        if not batches:
            return
        first, last = batches[0][0], batches[-1][0]
        if last <= self.db._seq:
            stats.add("repl_frames_duplicate")
            return
        with self._lock:
            self.last_shipped_seen = max(self.last_shipped_seen, last)
            if first in self._pending and self._pending[first][-1][0] >= last:
                stats.add("repl_frames_duplicate")
                return
            self._pending[first] = batches
            if len(self._pending) > self.MAX_PENDING:
                # drop the farthest-future run: catch-up re-reads it from
                # the primary's (retained) WAL
                del self._pending[max(self._pending)]

    def _apply_ready(self) -> bool:
        """Apply every buffered run that is contiguous with the applied
        sequence. Returns True if anything was applied."""
        progressed = False
        while True:
            applied = self.db._seq
            run = None
            with self._lock:
                for first in sorted(self._pending):
                    if first > applied + 1:
                        break
                    run = self._pending.pop(first)
                    if run[-1][0] > applied:
                        break
                    run = None  # fully stale: keep scanning
            if run is None:
                return progressed
            self._apply_batches([(s, p) for s, p in run if s > applied])
            progressed = True

    def _apply_batches(self, batches: list[tuple[int, bytes]]) -> None:
        """Apply contiguous ``(seq, payload)`` groups: mirror separated
        values first (fsynced — the same value-before-pointer durability
        barrier the primary's sync mode pays), then the local WAL append,
        then the memtable at the shipped sequence numbers."""
        if not batches:
            return
        db = self.db
        cfg = db.cfg
        interval = max(1, cfg.repl_crc_interval)
        decoded = []
        touched: set[int] = set()
        for seq, payload in batches:
            pseq, entries = decode_entries(payload)
            if pseq != seq:
                # header/frame mismatch — treat as corruption, force catch-up
                db.stats.add("repl_frames_corrupt")
                return
            for type_, _key, value in entries:
                if type_ == kTypeValuePtr:
                    self._mirror_value(ValueOffset.decode(value), touched)
            decoded.append((seq, payload, entries))
        for fd in touched:
            try:
                db.env.fsync(fd)
            except OSError:
                pass
        wal = db.wal
        if wal is not None:
            wal.append_many([p for _s, p, _e in decoded])
        with db.mutex:
            retain = max(db._snapshots) if db._snapshots else None
            for seq, payload, entries in decoded:
                if seq != db._seq + 1:
                    continue  # raced a concurrent applier (shouldn't happen)
                prevs = db.mem.add_batch(seq, entries, retain_from=retain)
                for prev in prevs:
                    if prev[1] == kTypeValuePtr:
                        db.dead_tracker.on_dead(ValueOffset.decode(prev[2]))
                db._seq = seq
                run = _run_of(seq, interval)
                with self._lock:
                    self._runs[run] = zlib.crc32(payload, self._runs.get(run, 0)) & 0xFFFFFFFF
            if (
                db.mem.approximate_size >= cfg.memtable_size
                and not db._pending
                # during the promote-time final catch-up the memtable must
                # NOT flush: promote probes it for dangling pointers
                # (values the dead primary never made durable) after the
                # replay, and a flush would bake them into an SSTable
                and not self.sealed
            ):
                db._rotate_memtable_locked()
        self._check_completed_runs(interval)
        db.stats.add("repl_batches_applied", len(batches))
        lag = self.lag
        db.stats.set_gauge("repl_lag_seqs", lag)
        db.stats.set_gauge("repl_applied_seq", db._seq)
        if lag > cfg.repl_lag_warn_seqs:
            db.stats.add("repl_lag_warnings")
        cb = self.on_applied
        if cb is not None:
            try:
                cb(db._seq)
            except Exception:
                pass

    def _mirror_value(self, voff: ValueOffset, touched: set[int]) -> None:
        if self._mirror_once(voff, touched):
            return
        # fetch failed (typically: an async primary's value-writer thread
        # has not landed the bytes yet) — keep the record, count the miss,
        # and queue a retry; reads of this version fall back like any
        # dangling pointer until the retry fills the hole
        self.db.stats.add("repl_value_fetch_misses")
        if len(self._miss_retry) < 4096:
            self._miss_retry[(voff.file_id, voff.offset)] = voff

    def _mirror_once(self, voff: ValueOffset, touched: set[int]) -> bool:
        db = self.db
        name = f"bv_{voff.file_id:06d}.val"
        try:
            rfd = self._mirror_read_fds.get(voff.file_id)
            if rfd is None:
                src = os.path.join(self.primary_path, "bvalue", name)
                rfd = self._penv.open_fd(src, os.O_RDONLY)
                self._mirror_read_fds[voff.file_id] = rfd
            data = self._penv.pread(rfd, voff.size, voff.offset)
            if len(data) != voff.size or (zlib.crc32(data) & 0xFFFFFFFF) != voff.crc:
                raise IOError(f"short/corrupt value read from primary {name}")
            wfd = self._mirror_write_fds.get(voff.file_id)
            if wfd is None:
                dst = db.bvalue.file_path(voff.file_id)
                wfd = db.env.open_fd(dst, os.O_RDWR | os.O_CREAT, 0o644)
                self._mirror_write_fds[voff.file_id] = wfd
            db.env.pwrite(wfd, data, voff.offset)
            touched.add(wfd)
            self.max_mirrored_file = max(self.max_mirrored_file, voff.file_id)
            return True
        except OSError:
            return False

    def _retry_misses(self) -> None:
        if not self._miss_retry:
            return
        touched: set[int] = set()
        for key, voff in list(self._miss_retry.items()):
            if self._mirror_once(voff, touched):
                del self._miss_retry[key]
        for fd in touched:
            try:
                self.db.env.fsync(fd)
            except OSError:
                pass

    # -- catch-up ---------------------------------------------------------
    def _catch_up(self) -> None:
        """Bridge a gap by reading the primary's durable WAL segments
        directly. Applies every contiguous group past our position; a hole
        *below* what the segments still hold means the primary deleted
        logs we never saw (possible only when retention wasn't active for
        us) — that forces a re-bootstrap."""
        db = self.db
        batch: list[tuple[int, bytes]] = []
        gap_seen = False
        # The live primary's WAL file shows written-but-unsynced bytes; a
        # group whose fsync is about to fail must never reach the replica.
        # Publish (and therefore ship) happens after the sync-mode fsync,
        # so last_shipped_seen is a durability floor — cap streaming
        # catch-up there. A sealed (promotion) catch-up reads to the end:
        # the primary is dead and its unsynced tail is already gone.
        cap = None if self.sealed else self.last_shipped_seen
        try:
            for seq, payload in self._reader.read_new():
                if cap is not None and seq > cap:
                    break
                expect = db._seq + len(batch) + 1
                if seq < expect:
                    continue  # already applied / duplicate in older segment
                if seq > expect:
                    # hole inside the durable stream we can observe: either
                    # mid-catch-up corruption or a deleted segment
                    gap_seen = True
                    break
                batch.append((seq, payload))
                if len(batch) >= 128:
                    self._apply_batches(batch)
                    batch = []
        except OSError:
            db.stats.add("repl_catchup_errors")
        if batch:
            self._apply_batches(batch)
        db.stats.add("repl_catchups")
        if gap_seen and self.last_shipped_seen > db._seq:
            # A hole in the durable stream cannot be filled by future
            # frames (everything shipped is in the WAL first), but a
            # reordered frame still in flight could cover it — flag only
            # when a SECOND catch-up finds the same hole unmoved.
            hole = db._seq + 1
            with self._lock:
                if self._last_gap == hole:
                    self.needs_rebootstrap = True
                self._last_gap = hole
        else:
            with self._lock:
                self._last_gap = None

    # -- divergence -------------------------------------------------------
    def _check_completed_runs(self, interval: int) -> None:
        db = self.db
        applied = db._seq
        mismatched = None
        with self._lock:
            horizon = _run_of(max(1, applied), interval) - self.MAX_RUNS
            for run in sorted(self._expected):
                if applied < (run + 1) * interval:
                    break  # run not fully applied yet
                expected = self._expected.pop(run)
                # keep the local fold (popping it would turn a duplicated
                # digest frame — re-check of an already-checked run — into
                # a local=None false divergence); eviction below bounds it
                local = self._runs.get(run)
                if self._check_floor > run * interval:
                    continue  # partially observed (bootstrap mid-run)
                if run < horizon:
                    continue  # local fold already evicted — unknowable
                db.stats.add("repl_crc_checks")
                if local != expected:
                    mismatched = run
                    break
            # bound memory: forget runs far behind the applied frontier
            for d in (self._runs, self._expected):
                for run in [r for r in d if r < horizon]:
                    del d[run]
            if mismatched is not None:
                self.diverged = True
                self.needs_rebootstrap = True
                self._cv.notify_all()
        if mismatched is not None:
            db.stats.add("repl_divergence_detected")

    # -- lifecycle --------------------------------------------------------
    def wait_caught_up(self, target_seq: int, timeout: float = 30.0) -> bool:
        """Block until the applied sequence reaches ``target_seq`` (True),
        or the follower seals/diverges or the timeout passes (False)."""
        import time as _time

        deadline = _time.monotonic() + timeout
        with self._lock:
            while True:
                if self.db._seq >= target_seq and not self._miss_retry:
                    return True
                if self.sealed or self.diverged:
                    return False
                remaining = deadline - _time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(timeout=min(remaining, 0.05))

    def seal(self, final_catch_up: bool = True) -> None:
        """Stop the stream: no further frames are accepted or applied.
        With ``final_catch_up`` (promotion), first replay whatever suffix
        survives in the primary's durable WAL; buffered non-contiguous
        frames — the unacked suffix — are discarded."""
        with self._lock:
            self.sealed = True
        # join any in-flight drain, then run the final catch-up with the
        # drain lock held so nothing else can interleave
        with self._drain_lock:
            if final_catch_up and not self.diverged:
                self._apply_ready()
                self._catch_up()
                self._apply_ready()
                # last chance to fill mirror holes while the primary's
                # disk is still readable; still-missing values are the
                # promote-time dangling-pointer drop's problem
                self._retry_misses()
            with self._lock:
                self._inbox.clear()
                self._pending.clear()
                self._cv.notify_all()
        self.close_fds()

    def close_fds(self) -> None:
        for fds, env in (
            (self._mirror_read_fds, self._penv),
            (self._mirror_write_fds, self.db.env),
        ):
            for fd in fds.values():
                try:
                    env.close_fd(fd)
                except OSError:
                    pass
            fds.clear()


class ReplicationLink:
    """One primary→replica attachment (see :func:`attach`)."""

    def __init__(self, primary, replica, transport, follower, follower_id):
        self.primary = primary
        self.replica = replica
        self.transport = transport
        self.follower = follower
        self.follower_id = follower_id

    def wait_caught_up(self, timeout: float = 30.0) -> bool:
        return self.follower.wait_caught_up(self.primary._seq, timeout=timeout)

    def nudge(self) -> None:
        # advertise the primary's position: a fully-dead wire (every frame
        # dropped) never advances last_shipped_seen, so the follower would
        # otherwise see no gap and skip the catch-up read
        f = self.follower
        with f._lock:
            f.last_shipped_seen = max(f.last_shipped_seen, self.primary._seq)
        f.nudge()

    @property
    def lag(self) -> int:
        return max(0, self.primary._seq - self.replica._seq)

    def detach(self) -> None:
        repl = self.primary._repl
        if repl is not None:
            repl.unregister(self.follower_id)
        self.follower.seal(final_catch_up=False)
        if self.replica._follower is self.follower:
            self.replica._follower = None

    def rebootstrap(self, keep_base: bool = True):
        """Tear the replica down and rebuild it from a fresh checkpoint of
        the primary (the divergence/hole recovery path). With ``keep_base``
        the old image serves as the incremental-checkpoint base, so only
        files the old image lacks are re-materialized. Returns the new
        replica DB (also stored on ``self.replica``)."""
        old = self.replica
        path, cfg = old.path, old.cfg
        self.detach()
        old.close()
        base_dir = path + ".rebase"
        if os.path.exists(base_dir):
            shutil.rmtree(base_dir)
        os.rename(path, base_dir)
        # the old store's SSTables carry the REPLICA's file numbering —
        # its own flushes can collide with primary file numbers, so only
        # the (id-space-mirrored) value files are usable as a base
        for name in os.listdir(base_dir):
            if name.endswith(".sst"):
                os.unlink(os.path.join(base_dir, name))
        try:
            # hardlink=False: the image lives in the replica's failure
            # domain; base links are fine (the old image is replica-local)
            self.primary.checkpoint(
                path, base=base_dir if keep_base else None, hardlink=False
            )
        except BaseException:
            shutil.rmtree(path, ignore_errors=True)
            os.rename(base_dir, path)
            raise
        shutil.rmtree(base_dir, ignore_errors=True)
        new = type(old)(path, cfg, role="replica")
        self.primary.stats.add("repl_rebootstraps")
        link = attach(self.primary, new, follower_id=self.follower_id)
        self.replica = new
        self.transport = link.transport
        self.follower = link.follower
        return new


def attach(primary, replica, transport=None, follower_id=None) -> ReplicationLink:
    """Wire a live stream from ``primary`` to ``replica``.

    Registration order matters: the follower's position is registered
    (activating WAL retention) *before* the initial catch-up computes what
    it missed, so the primary cannot delete a segment in the window."""
    if getattr(replica, "_role", "primary") != "replica":
        raise ValueError("attach: target DB was not opened with role='replica'")
    fid = follower_id or replica.path
    if transport is None:
        transport = InProcessTransport(primary.env, f"repl://{fid}")
    if primary._repl is None:
        primary._repl = Replicator(primary)
    follower = Follower(replica, primary.path, primary_env=replica.env)
    replica._follower = follower
    primary._repl.register(fid, transport, acked=replica._seq)
    repl = primary._repl
    follower.on_applied = lambda seq: repl.ack(fid, seq)
    # everything the primary committed so far is catch-up work, even if no
    # frame ever announces it (the stream may stay quiet from here on)
    follower.last_shipped_seen = max(follower.last_shipped_seen, primary._seq)
    transport.connect(follower.enqueue)
    link = ReplicationLink(primary, replica, transport, follower, fid)
    # initial catch-up: anything committed between checkpoint and attach
    follower.nudge()
    return link


def bootstrap_replica(primary, path: str, cfg=None, base: str | None = None):
    """Materialize a checkpoint of ``primary`` at ``path`` and open it as a
    replica DB (caller attaches it next). ``base`` makes the checkpoint
    incremental against a previous image. Files are *copied*, not
    hard-linked: the replica writes into its value files (mirroring) and
    must not share inodes with the live primary."""
    from .config import DBConfig

    if cfg is None:
        cfg = DBConfig()
    primary.checkpoint(path, base=base, hardlink=False)
    db_cls = type(primary)
    return db_cls(path, cfg, role="replica")
