"""Prioritized background job scheduler + delayed-write controller.

This replaces the single sleep-polling ``BackgroundWorker`` thread: all
background work now runs as discrete **jobs** on a small two-class thread
pool, scheduled *event-driven* (on memtable rotation, on job completion)
instead of being discovered by a 0.2 s poll loop.

Three layers live here:

* :class:`JobScheduler` — the generic pool. ``flush_threads`` serve only
  HIGH-priority jobs (a long compaction can never starve a flush);
  ``background_threads`` serve HIGH first, then LOW (compaction / GC).
  Completion is condition-variable signalled, so ``DB.wait_idle`` and the
  write-stall path block on a CV instead of sleep-polling.
* :class:`BackgroundCoordinator` — the DB-specific orchestration: decides
  *which* jobs exist (single-flight flush of the oldest immutable,
  pick-and-lock compactions up to the thread budget, threshold-triggered
  GC), re-examines the tree on every completion edge, and owns the
  subcompaction worker pool that :meth:`Compactor.run` fans shard work
  onto.
* :class:`WriteController` — the continuous delayed-write controller
  (RocksDB style): instead of the old binary stop/sleep, writers above the
  slowdown thresholds pay a per-byte delay derived from a write rate that
  decays multiplicatively while L0 depth / pending-compaction bytes keep
  growing and recovers once compaction catches up.

Concurrency safety relies on the per-file compaction locks in
:mod:`.manifest`: a file is locked from pick time until its job commits,
so two concurrent compaction jobs can never claim overlapping inputs, and
each job's input set is pinned (locked files are only ever deleted by the
job holding the lock).
"""
from __future__ import annotations

import threading
import time
import traceback
from collections import deque

from .compaction import Compactor
from .errors import JOB_ABORTED, BackgroundError

# job priorities share the rate limiter's definitions: flush is HIGH in
# both domains (thread pool and I/O budget), compaction/GC LOW in both —
# one source of truth keeps the two domains from desynchronizing
from .ratelimiter import PRI_HIGH, PRI_LOW  # noqa: F401  (re-exported)


class Job:
    __slots__ = ("name", "fn", "priority", "kind")

    def __init__(self, name: str, fn, priority: int, kind: str):
        self.name = name
        self.fn = fn
        self.priority = priority
        self.kind = kind


class JobScheduler:
    """Fixed thread pool with two priority classes and CV-signalled
    completion. ``on_job_done(job)`` (if set) runs on the worker thread
    after the job body but *before* the job is counted as finished, so a
    completion hook that submits follow-up work can never leave a window
    where ``outstanding()`` reads zero while more work is schedulable."""

    def __init__(self, flush_threads: int = 1, background_threads: int = 2, stats=None):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queues: tuple[deque[Job], deque[Job]] = (deque(), deque())
        self._outstanding = [0, 0]  # queued + running, per priority
        self._stop = False
        self._discard = False
        self.error: BaseException | None = None
        self.on_job_done = None
        self._stats = stats
        self._threads: list[threading.Thread] = []
        for i in range(max(1, flush_threads)):
            t = threading.Thread(
                target=self._worker, args=(False,), name=f"lsm-flush-{i}", daemon=True
            )
            self._threads.append(t)
        for i in range(max(1, background_threads)):
            t = threading.Thread(
                target=self._worker, args=(True,), name=f"lsm-bg-{i}", daemon=True
            )
            self._threads.append(t)
        for t in self._threads:
            t.start()

    def submit(self, name: str, fn, priority: int, kind: str) -> bool:
        """Enqueue a job; returns False if the scheduler is stopping."""
        with self._cv:
            if self._stop:
                return False
            self._queues[priority].append(Job(name, fn, priority, kind))
            self._outstanding[priority] += 1
            self._cv.notify_all()
            return True

    def outstanding(self, priority: int | None = None) -> int:
        with self._lock:
            if priority is None:
                return sum(self._outstanding)
            return self._outstanding[priority]

    def stop(self, discard_queued: bool = False, timeout: float = 60.0) -> None:
        """Stop the pool. Queued jobs are drained first unless
        ``discard_queued`` (crash close); running jobs always finish."""
        with self._cv:
            self._stop = True
            self._discard = discard_queued
            if discard_queued:
                for pri, q in enumerate(self._queues):
                    self._outstanding[pri] -= len(q)
                    q.clear()
            self._cv.notify_all()
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(0.1, deadline - time.monotonic()))

    @property
    def condition(self) -> threading.Condition:
        """The completion CV — waiters must re-check their predicate."""
        return self._cv

    # -- internals --------------------------------------------------------
    def _pop_locked(self, serve_low: bool) -> Job | None:
        if self._queues[PRI_HIGH]:
            return self._queues[PRI_HIGH].popleft()
        if serve_low and self._queues[PRI_LOW]:
            return self._queues[PRI_LOW].popleft()
        return None

    def _worker(self, serve_low: bool) -> None:
        while True:
            with self._cv:
                while True:
                    job = self._pop_locked(serve_low)
                    if job is not None:
                        break
                    if self._stop:
                        return
                    self._cv.wait()
            t0 = time.monotonic()
            try:
                job.fn()
            except BaseException as e:  # surface instead of dying silently
                with self._cv:
                    if self.error is None:
                        self.error = e
                traceback.print_exc()
            finally:
                if self._stats is not None:
                    self._stats.record_job(job.kind, time.monotonic() - t0)
                hook = self.on_job_done
                if hook is not None:
                    try:
                        hook(job)
                    except BaseException as e:
                        with self._cv:
                            if self.error is None:
                                self.error = e
                        traceback.print_exc()
                with self._cv:
                    self._outstanding[job.priority] -= 1
                    self._cv.notify_all()


class WriteController:
    """Continuous delayed-write controller (RocksDB ``WriteController``
    analogue). ``delay_for`` is called by the commit leader under the DB
    mutex (the sleep itself happens with the mutex released); it returns
    the seconds the leader must sleep so the aggregate ingest rate tracks
    the current delayed-write rate. The rate decays (×0.8) while the stall
    signals — L0 depth, pending-compaction bytes — keep worsening, holds
    while they are unchanged (they only move at flush/compaction commit
    edges, so "unchanged" means sustained pressure, not relief), and
    recovers (×1.25, capped at ``delayed_write_rate``) once they improve —
    a smooth throughput ramp instead of the old binary sleep."""

    def __init__(self, cfg):
        self.cfg = cfg
        self._rate = float(cfg.delayed_write_rate)
        self._active = False
        self._last_l0 = 0
        self._last_pending = 0

    def delay_for(self, l0: int, pending_bytes: int, nbytes: int) -> float:
        cfg = self.cfg
        delayed = (
            l0 >= cfg.l0_slowdown_trigger
            or pending_bytes >= cfg.soft_pending_compaction_bytes
        )
        if not delayed:
            self._active = False
            self._rate = min(float(cfg.delayed_write_rate), self._rate * 1.25)
            return 0.0
        if not self._active:
            self._active = True
            self._rate = float(cfg.delayed_write_rate)
        elif l0 > self._last_l0 or pending_bytes > self._last_pending:
            self._rate = max(float(cfg.delayed_write_min_rate), self._rate * 0.8)
        elif l0 < self._last_l0 or pending_bytes < self._last_pending:
            self._rate = min(float(cfg.delayed_write_rate), self._rate * 1.25)
        # unchanged signals = sustained pressure (they only move at
        # flush/compaction commit edges): HOLD the rate — recovering here
        # would climb back to full rate between edges and reintroduce the
        # on/off oscillation this controller exists to remove
        self._last_l0 = l0
        self._last_pending = pending_bytes
        # cap a single charge's delay so one giant debt can't freeze the
        # writer queue (the sleeping leader still heads it, so every
        # writer queues behind this sleep even though the mutex is free)
        return min(nbytes / self._rate, 0.25)


class BackgroundCoordinator:
    """DB-side orchestration on top of :class:`JobScheduler`.

    Scheduling is edge-triggered: :meth:`maybe_schedule` runs at every
    memtable rotation and after every job, converting available work into
    queued jobs. That makes idleness a pure counter condition —
    ``outstanding() == 0`` and no immutables — which :meth:`wait_idle`
    waits for on the scheduler CV (no polling ``pick()`` calls)."""

    def __init__(self, db):
        self.db = db
        cfg = db.cfg
        self.compactor = Compactor(db)
        self.sched = JobScheduler(
            flush_threads=cfg.flush_threads,
            background_threads=cfg.background_threads,
            stats=db.stats,
        )
        self.sched.on_job_done = self._job_done
        self._state_lock = threading.Lock()
        self._pick_lock = threading.Lock()  # serializes pick-and-lock
        self._gc_lock = threading.Lock()  # manual vs auto GC exclusion
        self._flush_inflight = False
        self._compactions_inflight = 0
        self._gc_inflight = False
        self._repl_inflight = False  # single-flight follower apply/catch-up
        # candidate-set signature of a completed auto-GC pass that made no
        # progress: don't immediately requeue the exact same stuck work
        # (a new dead-ratio edge changes the signature and re-arms GC)
        self._gc_stuck: frozenset | None = None
        # sliced auto-GC hands its remaining work list to the next slice so
        # the O(DB) live-key scan runs once per pass, not once per slice
        self._gc_resume = None
        self._stopping = False
        self._subpool = None  # lazy shared subcompaction pool

    @property
    def error(self) -> BaseException | None:
        return self.sched.error

    # -- scheduling -------------------------------------------------------
    def maybe_schedule(self) -> None:
        """Convert every piece of available background work into jobs:
        one flush (single-flight, oldest immutable first), compactions up
        to the thread budget (inputs locked at pick time), and a GC pass
        when a sealed BValue file crosses the dead-ratio trigger."""
        if self._stopping or self.sched.error is not None:
            return
        db = self.db
        with self._state_lock:
            want_flush = not self._flush_inflight and bool(db.immutables)
            if want_flush:
                self._flush_inflight = True
        if want_flush and not self.sched.submit("flush", self._flush_job, PRI_HIGH, "flush"):
            with self._state_lock:
                self._flush_inflight = False
        while True:
            with self._state_lock:
                if self._compactions_inflight >= db.cfg.background_threads:
                    break
                self._compactions_inflight += 1  # optimistic slot claim
            picked = self._pick_and_lock()
            if picked is None:
                with self._state_lock:
                    self._compactions_inflight -= 1
                break
            ok = self.sched.submit(
                "compact", lambda p=picked: self._compaction_job(p), PRI_LOW, "compaction"
            )
            if not ok:
                level, inputs, overlaps = picked
                db.versions.unlock_files([f.file_no for f in inputs + overlaps])
                with self._state_lock:
                    self._compactions_inflight -= 1
                break
        self._maybe_schedule_gc()
        self.maybe_schedule_repl()

    def maybe_schedule_repl(self) -> None:
        """Follower apply/catch-up job (single-flight, flush-priority pool):
        queued replication frames — or a detected gap that needs a WAL
        catch-up read from the primary — become one drain pass. Re-armed at
        every completion edge like the other job kinds, so a frame that
        arrives mid-drain schedules the next pass instead of being lost."""
        db = self.db
        follower = getattr(db, "_follower", None)
        if (
            follower is None
            or self._stopping
            or getattr(db, "_closed", False)
            or self.sched.error is not None
            or not follower.has_work()
        ):
            return
        with self._state_lock:
            if self._repl_inflight:
                return
            self._repl_inflight = True
        if not self.sched.submit("repl-apply", self._repl_job, PRI_HIGH, "repl_apply"):
            with self._state_lock:
                self._repl_inflight = False

    def _repl_job(self) -> None:
        db = self.db
        try:
            follower = getattr(db, "_follower", None)
            if follower is not None:
                db.errors.run_job(follower.drain, "repl_apply")
        finally:
            with self._state_lock:
                self._repl_inflight = False

    def _pick_and_lock(self):
        db = self.db
        with self._pick_lock:
            # quarantined tables are pick-excluded exactly like locked ones:
            # rewriting them would read the corrupt bytes and fail forever
            picked = self.compactor.pick(
                db.versions.locked_files() | db.versions.quarantined_files()
            )
            if picked is None:
                return None
            level, inputs, overlaps = picked
            if not db.versions.try_lock_files(
                [f.file_no for f in inputs + overlaps]
            ):  # pragma: no cover - pick() already excluded locked files
                return None
            return picked

    def _job_done(self, job: Job) -> None:
        db = self.db
        with db.mutex:
            db.writer_cv.notify_all()  # stalled writers re-check triggers
        self.maybe_schedule()

    # -- job bodies -------------------------------------------------------
    def _flush_job(self) -> None:
        db = self.db
        try:
            with db.mutex:
                mem = db.immutables[0] if db.immutables else None
            if mem is not None:
                res = db.errors.run_job(
                    lambda: self.compactor.flush_memtable(mem), "flush"
                )
                if res is JOB_ABORTED:
                    return  # immutable stays queued for the next edge
                with db.mutex:
                    # crash-close may have cleared the list under us
                    if db.immutables and db.immutables[0] is mem:
                        db.immutables.pop(0)
        finally:
            with self._state_lock:
                self._flush_inflight = False

    def _compaction_job(self, picked) -> None:
        level, inputs, overlaps = picked
        db = self.db
        try:
            db.errors.run_job(
                lambda: self.compactor.run(
                    level, inputs, overlaps, subtasks=self.run_subtasks
                ),
                "compaction",
            )
        finally:
            db.versions.unlock_files([f.file_no for f in inputs + overlaps])
            with self._state_lock:
                self._compactions_inflight -= 1

    def _maybe_schedule_gc(self) -> None:
        db = self.db
        cfg = db.cfg
        # auto-GC needs a second low-priority thread: the pass occupies one
        # for its whole duration, and compactions must keep draining L0 or
        # GC's own rewrites could hard-stall against a pool with no room.
        # The _closed check keeps close()'s drain from launching a fresh
        # full-keyspace GC scan that would only bail at its first file.
        if (
            not cfg.gc_auto
            or self._stopping
            or getattr(db, "_closed", False)
            or cfg.background_threads < 2
            # replicas never GC: their value files mirror the primary's id
            # space byte for byte, and a local rewrite would fork it — the
            # primary's own GC rewrites arrive through the stream instead
            or getattr(db, "_role", "primary") != "primary"
            # a primary with live followers pauses auto-GC too: GC moves
            # value bytes to new file ids without shipping WAL records, so
            # already-shipped pointers would dangle on the replica side.
            # Detach (or rebootstrap) resumes reclamation.
            or (getattr(db, "_repl", None) is not None and db._repl.active)
        ):
            return
        with self._state_lock:
            if self._gc_inflight:
                return
            live = {q.file_id for q in db.bvalue.queues} | set(
                db.versions.quarantined_bvalues
            )
            cands = db.dead_tracker.candidates(cfg.gc_dead_ratio_trigger, exclude=live)
            if not cands:
                return
            if (
                self._gc_stuck is not None
                and db.dead_tracker.signature(cands) == self._gc_stuck
            ):
                return  # same uncollectable set a full pass just failed on
                # (more deaths in these files change the signature → retry)
            self._gc_inflight = True
        if not self.sched.submit("gc", self._gc_job, PRI_LOW, "gc"):
            with self._state_lock:
                self._gc_inflight = False

    def _gc_job(self) -> None:
        """One auto-GC slice: rewrite at most ``gc_slice_bytes`` of live
        values, then yield the LOW thread — the completion edge schedules
        the next slice (which resumes this slice's work list, no repeated
        keyspace scan) while compactions interleave, so one huge candidate
        file can't monopolize a background thread for seconds."""
        from .gc import BValueGC

        db = self.db
        try:
            with self._gc_lock:
                gc = BValueGC(
                    db,
                    db.cfg.gc_dead_ratio_trigger,
                    max_rewrite_bytes=db.cfg.gc_slice_bytes,
                    resume=self._gc_resume,
                )
                res = db.errors.run_job(gc.collect, "gc")
                if res is JOB_ABORTED:
                    # a corrupt file was quarantined mid-pass; keep the
                    # progress counters the pass banked before aborting
                    res = gc._stats()
                self._gc_resume = gc.resume_state
            if res["sliced"]:
                db.stats.add("gc_slices")
            # rewritten_bytes counts every successful move (collected_files
            # only files actually unlinked): a pass that relocated values
            # but couldn't prove any file clean still made progress
            progressed = (
                res["sliced"] or res["collected_files"] or res["rewritten_bytes"]
            )
            with self._state_lock:
                if progressed:
                    self._gc_stuck = None
                else:
                    live = {q.file_id for q in db.bvalue.queues} | set(
                        db.versions.quarantined_bvalues
                    )
                    self._gc_stuck = db.dead_tracker.signature(
                        db.dead_tracker.candidates(
                            db.cfg.gc_dead_ratio_trigger, exclude=live
                        )
                    )
        finally:
            with self._state_lock:
                self._gc_inflight = False

    def submit_scrub(self) -> bool:
        """Queue one integrity scrub (``DB.verify_integrity``) on the
        low-priority pool; its block/value reads are additionally paced by
        the shared I/O token bucket at PRI_LOW."""
        db = self.db
        return self.sched.submit(
            "scrub", lambda: db.errors.run_job(db._scrub, "scrub"), PRI_LOW, "scrub"
        )

    def run_gc(self, threshold: float, max_rewrite_bytes: int = 0) -> dict:
        """One GC pass (``max_rewrite_bytes`` > 0 = one paced slice);
        shared lock means a manual ``gc_collect`` and the auto-triggered
        job can never run concurrently."""
        from .gc import BValueGC

        with self._gc_lock:
            return BValueGC(self.db, threshold, max_rewrite_bytes).collect()

    # -- subcompactions ---------------------------------------------------
    def run_subtasks(self, fns: list) -> list:
        """Run shard thunks for one compaction: the calling job thread
        executes the first shard itself; the rest go to a small shared
        pool (concurrent compaction jobs share it — shards are pure
        functions, so queuing behind each other cannot deadlock)."""
        if len(fns) == 1:
            return [fns[0]()]
        with self._state_lock:  # two jobs racing the lazy init would leak
            if self._subpool is None:  # the loser's executor thread
                from concurrent.futures import ThreadPoolExecutor

                self._subpool = ThreadPoolExecutor(
                    max_workers=max(1, self.db.cfg.max_subcompactions - 1),
                    thread_name_prefix="lsm-subcompact",
                )
        futs = [self._subpool.submit(fn) for fn in fns[1:]]
        out = [fns[0]()]
        out.extend(f.result() for f in futs)
        return out

    # -- idle / lifecycle -------------------------------------------------
    def _idle_locked(self, compactions: bool) -> bool:
        db = self.db
        if db.immutables or self._flush_inflight or self._repl_inflight:
            return False
        if self.sched._outstanding[PRI_HIGH] > 0:
            return False
        if compactions:
            if self.sched._outstanding[PRI_LOW] > 0:
                return False
            if self._compactions_inflight or self._gc_inflight:
                return False
        return True

    def wait_idle(self, compactions: bool = True, timeout: float = 120.0) -> None:
        """Block until background work is quiescent — CV-signalled by job
        completion, no sleep-polling and no ``pick()`` probing while idle
        (scheduling is exhaustive at every completion edge)."""
        deadline = time.monotonic() + timeout
        self.maybe_schedule()
        with self.sched.condition:
            while True:
                if self.sched.error is not None:
                    raise BackgroundError("background job failed") from self.sched.error
                if self._idle_locked(compactions):
                    return
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("wait_idle timed out")
                # bounded wait only as a safety net against lost wakeups
                self.sched.condition.wait(timeout=min(remaining, 1.0))

    def stop(self, crash: bool = False) -> None:
        """Shut the pool down. Non-crash: drain all queued/produced work
        first (close() semantics: pending flushes and compactions finish).
        Crash: discard queued jobs; running ones complete."""
        if not crash:
            try:
                self.wait_idle(compactions=True, timeout=60.0)
            except (TimeoutError, RuntimeError):
                pass
        self._stopping = True
        self.sched.stop(discard_queued=crash)
        if self._subpool is not None:
            self._subpool.shutdown(wait=True)
