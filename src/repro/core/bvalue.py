"""BValue store — the paper's multi-queue parallel big-value log (§III-C).

Each *queue* owns a dedicated append-only BValue file and (in async mode) a
dedicated writer thread: the userspace realization of "one NVMe submission
queue per BValue file" (see DESIGN.md §3 for the hardware-adaptation note).
Offsets are **reserved synchronously** at dispatch time — this is what makes
WAL-time separation possible: the ``ValueOffset`` must be known before the
Key-ValueOffset record is appended to the WAL.

Write modes:

* ``put_sync``  — caller pwrites at its reserved offset and fsyncs before
  returning (WAL-enabled strong-consistency path: value durable before the
  WAL record that references it). Concurrent callers on different queues
  proceed in parallel (pwrite/fsync release the GIL).
* ``put_async`` — reservation returns immediately; the queue's writer thread
  batches contiguous runs to page multiples, pwrites, fsyncs, then unpins
  the corresponding BVCache entries (which held the only copy meanwhile).

``put_many`` is the group-commit fan-out: a WriteBatch's big values are
dispatched across all queues in one call, and in sync mode each queue pays
ONE fsync for its whole share of the batch instead of one per value.

File descriptors are tracked per file-id with a reservation refcount: a
queue may roll to a new file while older reservations are still being
written, so the old file's fd stays open (and is fsynced+closed) only once
every reservation against it has completed — a pwrite can never land in the
wrong file.

Dispatch across queues is round-robin or least-loaded (pending bytes),
matching the paper's "hash or round-robin" scheduler.

Unified I/O budget: when the manager is built with a ``limiter`` (see
:mod:`.ratelimiter`), every dispatched value charges the shared token
bucket at reservation time on the *caller's* thread, at the priority
``io_priority()`` reports for that caller — foreground puts charge
``PRI_FG`` (accounted, never blocked), while a GC rewrite re-entering
this path inherits ``PRI_LOW`` and genuinely waits (priority
inheritance). Charging at dispatch rather than persist time keeps the
accounting identical for the sync and async write modes.
"""
from __future__ import annotations

import os
import queue
import threading
import zlib
from dataclasses import dataclass

from .env import DEFAULT_ENV
from .errors import CorruptionError
from .record import ValueOffset

_SENTINEL = object()


@dataclass(slots=True)
class _Pending:
    file_id: int
    offset: int
    value: bytes
    key: bytes  # for BVCache unpin on completion


class _BValueQueue:
    """One writer queue bound to one (rolling) BValue file."""

    def __init__(self, mgr: "BValueManager", qid: int):
        self.mgr = mgr
        self.qid = qid
        self.file_id = mgr._alloc_file_id(qid)
        self.tail = 0
        self.pending_bytes = 0
        self._pending_items = 0  # async reservations not yet persisted
        self._lock = threading.Lock()
        self._drained = threading.Condition(self._lock)
        # file_id -> (fd, outstanding reservation count); the active file and
        # any rolled-away file with reservations still in flight.
        self._fds: dict[int, int] = {self.file_id: self._open(self.file_id)}
        self._refs: dict[int, int] = {self.file_id: 0}
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        if mgr.async_writes:
            self._thread = threading.Thread(
                target=self._writer_loop, name=f"bvalue-q{qid}", daemon=True
            )
            self._thread.start()

    def _open(self, file_id: int) -> int:
        path = self.mgr.file_path(file_id)
        return self.mgr.env.open_fd(path, os.O_WRONLY | os.O_CREAT, 0o644)

    def reserve(self, size: int) -> tuple[int, int]:
        """Reserve [offset, offset+size) — returns (file_id, offset). The
        reservation holds a reference on the file's fd until the matching
        write completes (see _release)."""
        close_fd = None
        with self._lock:
            if self.tail + size > self.mgr.max_file_bytes and self.tail > 0:
                old = self.file_id
                if self._refs.get(old, 0) == 0:
                    close_fd = self._fds.pop(old)
                    del self._refs[old]
                # else: writes against `old` are still in flight — its fd is
                # closed by _release when the last one completes.
                self.file_id = self.mgr._alloc_file_id(self.qid)
                self._fds[self.file_id] = self._open(self.file_id)
                self._refs[self.file_id] = 0
                self.tail = 0
            off = self.tail
            self.tail += size
            self._refs[self.file_id] += 1
            file_id = self.file_id
        if close_fd is not None:
            self.mgr.env.fsync(close_fd)
            self.mgr.env.close_fd(close_fd)
        return file_id, off

    def _fd_for(self, file_id: int) -> int:
        with self._lock:
            return self._fds[file_id]

    def _release(self, file_id: int) -> None:
        """A reservation against file_id completed (data already fsynced by
        the write path); close rolled-away files once fully drained."""
        close_fd = None
        with self._lock:
            self._refs[file_id] -= 1
            if self._refs[file_id] == 0 and file_id != self.file_id:
                close_fd = self._fds.pop(file_id)
                del self._refs[file_id]
        if close_fd is not None:
            self.mgr.env.close_fd(close_fd)

    # -- sync path ------------------------------------------------------
    def write_sync(self, file_id: int, offset: int, value: bytes) -> None:
        fd = self._fd_for(file_id)
        self.mgr.env.pwrite(fd, value, offset)
        self.mgr.env.fsync(fd)
        self.mgr._account(len(value), fsyncs=1)
        self._release(file_id)

    def _persist_resvs(self, resvs: list[tuple[int, int, bytes]]) -> int:
        """Shared sync/async persistence: coalesce in-order reservations
        [(file_id, offset, value)] into contiguous pwrite runs, fsync each
        distinct file ONCE, account, and release every reservation. Returns
        the number of bytes written."""
        runs: list[list[tuple[int, int, bytes]]] = [[resvs[0]]]
        for r in resvs[1:]:
            last = runs[-1][-1]
            if r[0] == last[0] and r[1] == last[1] + len(last[2]):
                runs[-1].append(r)
            else:
                runs.append([r])
        total = 0
        touched: dict[int, int] = {}
        for run in runs:
            fid = run[0][0]
            fd = touched.get(fid)
            if fd is None:
                fd = touched[fid] = self._fd_for(fid)
            blob = b"".join(v for _, _, v in run)
            self.mgr.env.pwrite(fd, blob, run[0][1])
            total += len(blob)
        for fd in touched.values():
            self.mgr.env.fsync(fd)
        self.mgr._account(total, fsyncs=len(touched))
        for fid, _, _ in resvs:
            self._release(fid)
        return total

    def write_sync_many(self, resvs: list[tuple[int, int, bytes]]) -> None:
        """Persist many reservations with one fsync per distinct file — the
        group-commit amortization for the durable big-value path. resvs must
        be in reservation order (consecutive reserve() calls)."""
        if resvs:
            self._persist_resvs(resvs)

    # -- async path -------------------------------------------------------
    def submit(self, item: _Pending) -> None:
        with self._lock:
            self.pending_bytes += len(item.value)
            self._pending_items += 1
        self._q.put(item)

    def wait_drained(self, timeout: float | None = None) -> bool:
        """Barrier: block until every submitted async write has been
        persisted (condition-variable signalled by the writer thread)."""
        with self._lock:
            return self._drained.wait_for(lambda: self._pending_items == 0, timeout=timeout)

    def _writer_loop(self) -> None:
        import time

        gather_s = self.mgr.gather_window_s
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            batch = [item]
            nbytes = len(item.value)
            # "aggregate small-to-medium writes into full pages": gather
            # within a short window so a slow producer still yields large
            # batches — one fsync per BATCH, not per value (the paper's
            # async page-aligned submission).
            deadline = time.monotonic() + gather_s
            while nbytes < self.mgr.batch_bytes:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=timeout)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    self._flush_batch(batch)
                    return
                batch.append(nxt)
                nbytes += len(nxt.value)
            self._flush_batch(batch)

    def _flush_batch(self, batch: list[_Pending]) -> None:
        if not batch:
            return
        total = self._persist_resvs([(p.file_id, p.offset, p.value) for p in batch])
        # unpin callbacks BEFORE signalling the drain barrier: wait_drained()
        # returning must mean the batch is persisted AND its cache entries
        # are unpinned.
        if self.mgr.on_persisted_many is not None:
            self.mgr.on_persisted_many(
                [(p.key, ValueOffset(p.file_id, p.offset, len(p.value))) for p in batch]
            )
        elif self.mgr.on_persisted is not None:
            for p in batch:
                self.mgr.on_persisted(p.key, ValueOffset(p.file_id, p.offset, len(p.value)))
        with self._lock:
            self.pending_bytes -= total
            self._pending_items -= len(batch)
            if self._pending_items == 0:
                self._drained.notify_all()

    def drain(self) -> None:
        if self._thread is not None:
            self._q.put(_SENTINEL)
            self._thread.join(timeout=30)
            self._thread = None

    def close(self) -> None:
        self.drain()
        with self._lock:
            fds = list(self._fds.values())
            self._fds.clear()
            self._refs.clear()
        for fd in fds:
            try:
                self.mgr.env.fsync(fd)
            except OSError:
                pass
            self.mgr.env.close_fd(fd)


class BValueManager:
    """Dispatches separated big values across N parallel queues."""

    def __init__(
        self,
        directory: str,
        num_queues: int = 4,
        async_writes: bool = True,
        dispatch: str = "round_robin",
        page_size: int = 4096,
        batch_bytes: int = 1 << 18,
        max_file_bytes: int = 256 << 20,
        gather_window_s: float = 0.02,
        stats=None,
        on_persisted=None,
        on_persisted_many=None,
        next_file_id: int = 0,
        limiter=None,
        io_priority=None,
        env=None,
    ):
        assert dispatch in ("round_robin", "least_loaded")
        self.dir = directory
        self.env = env or DEFAULT_ENV
        self.env.makedirs(directory)
        self.async_writes = async_writes
        self.dispatch = dispatch
        self.page_size = page_size
        self.batch_bytes = batch_bytes
        self.max_file_bytes = max_file_bytes
        self.gather_window_s = gather_window_s
        self.stats = stats
        # unified device budget: charge the shared token bucket at dispatch
        # time, at the priority the calling context reports (None = no
        # charging — the pre-unification background-only model)
        self.limiter = limiter
        self.io_priority = io_priority
        self.on_persisted = on_persisted
        self.on_persisted_many = on_persisted_many
        self._file_lock = threading.Lock()
        self._next_file_id = next_file_id
        self._rr = 0
        self.queues = [_BValueQueue(self, q) for q in range(num_queues)]
        self._read_fds: dict[int, int] = {}
        self._read_lock = threading.Lock()

    # -- file naming / ids --------------------------------------------------
    def file_path(self, file_id: int) -> str:
        return os.path.join(self.dir, f"bv_{file_id:06d}.val")

    def _alloc_file_id(self, qid: int) -> int:
        with self._file_lock:
            fid = self._next_file_id
            self._next_file_id += 1
            return fid

    def _account(self, n: int, fsyncs: int = 0) -> None:
        if self.stats:
            self.stats.add("bvalue_bytes", n)
            if fsyncs:
                self.stats.add("bvalue_fsyncs", fsyncs)

    # -- write path -----------------------------------------------------------
    def _pick_queue(self) -> _BValueQueue:
        if self.dispatch == "least_loaded":
            return min(self.queues, key=lambda q: q.pending_bytes)
        q = self.queues[self._rr % len(self.queues)]
        self._rr += 1
        return q

    def _charge(self, nbytes: int) -> None:
        if self.limiter is not None and self.limiter.enabled and nbytes > 0:
            pri = self.io_priority() if self.io_priority is not None else None
            if pri is not None:
                self.limiter.request(nbytes, pri)

    def put(self, key: bytes, value: bytes, sync: bool) -> ValueOffset:
        self._charge(len(value))
        q = self._pick_queue()
        file_id, off = q.reserve(len(value))
        voff = ValueOffset(file_id, off, len(value), zlib.crc32(value) & 0xFFFFFFFF)
        if sync or not self.async_writes:
            q.write_sync(file_id, off, value)
        else:
            q.submit(_Pending(file_id, off, value, key))
        return voff

    def put_many(
        self, items: list[tuple[bytes, bytes]], sync: bool, on_reserved=None
    ) -> list[ValueOffset]:
        """Batched fan-out for group commit: dispatch a WriteBatch's big
        values across all queues, then persist each queue's share with one
        fsync (sync mode) or one submission run (async mode). Returns the
        ValueOffsets in input order.

        ``on_reserved(key, voff, value)`` fires per item BEFORE anything is
        handed to a writer thread — the DB uses it to insert pinned BVCache
        entries so the persist-completion unpin can never race ahead of the
        insert."""
        self._charge(sum(len(v) for _, v in items))
        voffs: list[ValueOffset] = []
        per_q: dict[int, list[tuple[int, int, bytes, bytes]]] = {}
        for key, value in items:
            q = self._pick_queue()
            file_id, off = q.reserve(len(value))
            voff = ValueOffset(file_id, off, len(value), zlib.crc32(value) & 0xFFFFFFFF)
            voffs.append(voff)
            if on_reserved is not None:
                on_reserved(key, voff, value)
            per_q.setdefault(q.qid, []).append((file_id, off, value, key))
        durable = sync or not self.async_writes
        for qid, resvs in per_q.items():
            q = self.queues[qid]
            if durable:
                q.write_sync_many([(fid, off, val) for fid, off, val, _ in resvs])
            else:
                for fid, off, val, key in resvs:
                    q.submit(_Pending(fid, off, val, key))
        return voffs

    # -- read path ------------------------------------------------------------
    def get(self, voff: ValueOffset, verify: bool = False) -> bytes:
        fd = self._reader_fd(voff.file_id)
        buf = self.env.pread(fd, voff.size, voff.offset)
        if len(buf) != voff.size:
            # short read ≠ corruption: it's a truncation/roll race and is
            # retryable (plain IOError, classified transient)
            raise IOError(
                f"short BValue read: file {voff.file_id} off {voff.offset} "
                f"want {voff.size} got {len(buf)}"
            )
        if verify and voff.crc and (zlib.crc32(buf) & 0xFFFFFFFF) != voff.crc:
            raise CorruptionError(
                f"BValue CRC mismatch at file {voff.file_id}+{voff.offset}",
                bvalue_file_id=voff.file_id,
                path=self.file_path(voff.file_id),
            )
        return buf

    def drop_reader(self, file_id: int) -> None:
        with self._read_lock:
            fd = self._read_fds.pop(file_id, None)
            if fd is not None:
                self.env.close_fd(fd)

    def _reader_fd(self, file_id: int) -> int:
        with self._read_lock:
            fd = self._read_fds.get(file_id)
            if fd is None:
                fd = self.env.open_fd(self.file_path(file_id), os.O_RDONLY)
                self._read_fds[file_id] = fd
            return fd

    # -- lifecycle -------------------------------------------------------------
    def flush(self, timeout: float = 120.0) -> None:
        """Barrier: wait for all pending async writes to hit disk."""
        for q in self.queues:
            if not q.wait_drained(timeout=timeout):
                raise TimeoutError(f"BValue queue {q.qid} did not drain in {timeout}s")

    def seal_active(self, force: bool = False) -> None:
        """Roll every queue with a non-empty active file to a fresh one.

        Checkpoints hard-link BValue files, and a link shares the inode —
        an active append tail must never be linked, or the checkpoint's
        copy would keep growing underneath it. Sealing first makes every
        existing file immutable from this point on (the same roll
        ``reserve`` performs at the size cap; in-flight reservations keep
        the old fd open until they drain).

        ``force=True`` also rolls queues whose active file is still empty.
        Replica promotion needs this: a replica's idle queue files can
        share ids with value files mirrored from the old primary, and an
        append at the queue's (zero) tail would overwrite mirrored bytes —
        after bumping the allocator past the mirrored id space, a forced
        roll moves every queue onto a guaranteed-fresh file."""
        for q in self.queues:
            close_fd = None
            with q._lock:
                if q.tail == 0 and not force:
                    continue  # empty active file: nothing to seal
                sealed_nonempty = q.tail > 0
                old = q.file_id
                if q._refs.get(old, 0) == 0:
                    close_fd = q._fds.pop(old)
                    del q._refs[old]
                q.file_id = self._alloc_file_id(q.qid)
                q._fds[q.file_id] = q._open(q.file_id)
                q._refs[q.file_id] = 0
                q.tail = 0
            if close_fd is not None:
                if sealed_nonempty:
                    self.env.fsync(close_fd)
                self.env.close_fd(close_fd)

    def ensure_next_file_id(self, n: int) -> None:
        """Raise the id allocator floor to at least ``n`` (promotion: never
        allocate an id the old primary already used for a mirrored file)."""
        with self._file_lock:
            if n > self._next_file_id:
                self._next_file_id = n

    @property
    def next_file_id(self) -> int:
        with self._file_lock:
            return self._next_file_id

    def close(self) -> None:
        for q in self.queues:
            q.close()
        with self._read_lock:
            for fd in self._read_fds.values():
                self.env.close_fd(fd)
            self._read_fds.clear()
