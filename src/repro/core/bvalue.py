"""BValue store — the paper's multi-queue parallel big-value log (§III-C).

Each *queue* owns a dedicated append-only BValue file and (in async mode) a
dedicated writer thread: the userspace realization of "one NVMe submission
queue per BValue file" (see DESIGN.md §3 for the hardware-adaptation note).
Offsets are **reserved synchronously** at dispatch time — this is what makes
WAL-time separation possible: the ``ValueOffset`` must be known before the
Key-ValueOffset record is appended to the WAL.

Write modes:

* ``put_sync``  — caller pwrites at its reserved offset and fsyncs before
  returning (WAL-enabled strong-consistency path: value durable before the
  WAL record that references it). Concurrent callers on different queues
  proceed in parallel (pwrite/fsync release the GIL).
* ``put_async`` — reservation returns immediately; the queue's writer thread
  batches contiguous runs to page multiples, pwrites, fsyncs, then unpins
  the corresponding BVCache entries (which held the only copy meanwhile).

Dispatch across queues is round-robin or least-loaded (pending bytes),
matching the paper's "hash or round-robin" scheduler.
"""
from __future__ import annotations

import os
import queue
import threading
import zlib
from dataclasses import dataclass

from .record import ValueOffset

_SENTINEL = object()


@dataclass(slots=True)
class _Pending:
    file_id: int
    offset: int
    value: bytes
    key: bytes  # for BVCache unpin on completion


class _BValueQueue:
    """One writer queue bound to one (rolling) BValue file."""

    def __init__(self, mgr: "BValueManager", qid: int):
        self.mgr = mgr
        self.qid = qid
        self.file_id = mgr._alloc_file_id(qid)
        self.tail = 0
        self.pending_bytes = 0
        self._fd = self._open(self.file_id)
        self._lock = threading.Lock()
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        if mgr.async_writes:
            self._thread = threading.Thread(
                target=self._writer_loop, name=f"bvalue-q{qid}", daemon=True
            )
            self._thread.start()

    def _open(self, file_id: int) -> int:
        path = self.mgr.file_path(file_id)
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        # append-only file but we pwrite at reserved offsets:
        os.close(fd)
        return os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)

    def reserve(self, size: int) -> tuple[int, int]:
        """Reserve [offset, offset+size) — returns (file_id, offset)."""
        with self._lock:
            if self.tail + size > self.mgr.max_file_bytes and self.tail > 0:
                os.fsync(self._fd)
                os.close(self._fd)
                self.file_id = self.mgr._alloc_file_id(self.qid)
                self._fd = self._open(self.file_id)
                self.tail = 0
            off = self.tail
            self.tail += size
            return self.file_id, off

    # -- sync path ------------------------------------------------------
    def write_sync(self, file_id: int, offset: int, value: bytes) -> None:
        os.pwrite(self._fd_for(file_id), value, offset)
        os.fsync(self._fd_for(file_id))
        self.mgr._account(len(value))

    def _fd_for(self, file_id: int) -> int:
        # the queue only ever writes to its current file; rolls are fsynced.
        return self._fd

    # -- async path -------------------------------------------------------
    def submit(self, item: _Pending) -> None:
        with self._lock:
            self.pending_bytes += len(item.value)
        self._q.put(item)

    def _writer_loop(self) -> None:
        import time

        gather_s = self.mgr.gather_window_s
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            batch = [item]
            nbytes = len(item.value)
            # "aggregate small-to-medium writes into full pages": gather
            # within a short window so a slow producer still yields large
            # batches — one fsync per BATCH, not per value (the paper's
            # async page-aligned submission).
            deadline = time.monotonic() + gather_s
            while nbytes < self.mgr.batch_bytes:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=timeout)
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    self._flush_batch(batch)
                    return
                batch.append(nxt)
                nbytes += len(nxt.value)
            self._flush_batch(batch)

    def _flush_batch(self, batch: list[_Pending]) -> None:
        if not batch:
            return
        # contiguous-run coalescing: reservations on this queue are ordered,
        # so adjacent pendings usually form one pwrite.
        runs: list[list[_Pending]] = [[batch[0]]]
        for it in batch[1:]:
            last = runs[-1][-1]
            if it.file_id == last.file_id and it.offset == last.offset + len(last.value):
                runs[-1].append(it)
            else:
                runs.append([it])
        total = 0
        for run in runs:
            blob = b"".join(p.value for p in run)
            os.pwrite(self._fd_for(run[0].file_id), blob, run[0].offset)
            total += len(blob)
        os.fsync(self._fd)
        self.mgr._account(total)
        with self._lock:
            self.pending_bytes -= total
        if self.mgr.on_persisted_many is not None:
            self.mgr.on_persisted_many(
                [(p.key, ValueOffset(p.file_id, p.offset, len(p.value))) for p in batch]
            )
        elif self.mgr.on_persisted is not None:
            for p in batch:
                self.mgr.on_persisted(p.key, ValueOffset(p.file_id, p.offset, len(p.value)))

    def drain(self) -> None:
        if self._thread is not None:
            self._q.put(_SENTINEL)
            self._thread.join(timeout=30)
            self._thread = None

    def close(self) -> None:
        self.drain()
        try:
            os.fsync(self._fd)
        except OSError:
            pass
        os.close(self._fd)


class BValueManager:
    """Dispatches separated big values across N parallel queues."""

    def __init__(
        self,
        directory: str,
        num_queues: int = 4,
        async_writes: bool = True,
        dispatch: str = "round_robin",
        page_size: int = 4096,
        batch_bytes: int = 1 << 18,
        max_file_bytes: int = 256 << 20,
        gather_window_s: float = 0.02,
        stats=None,
        on_persisted=None,
        on_persisted_many=None,
        next_file_id: int = 0,
    ):
        assert dispatch in ("round_robin", "least_loaded")
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.async_writes = async_writes
        self.dispatch = dispatch
        self.page_size = page_size
        self.batch_bytes = batch_bytes
        self.max_file_bytes = max_file_bytes
        self.gather_window_s = gather_window_s
        self.stats = stats
        self.on_persisted = on_persisted
        self.on_persisted_many = on_persisted_many
        self._file_lock = threading.Lock()
        self._next_file_id = next_file_id
        self._rr = 0
        self.queues = [_BValueQueue(self, q) for q in range(num_queues)]
        self._read_fds: dict[int, int] = {}
        self._read_lock = threading.Lock()

    # -- file naming / ids --------------------------------------------------
    def file_path(self, file_id: int) -> str:
        return os.path.join(self.dir, f"bv_{file_id:06d}.val")

    def _alloc_file_id(self, qid: int) -> int:
        with self._file_lock:
            fid = self._next_file_id
            self._next_file_id += 1
            return fid

    def _account(self, n: int) -> None:
        if self.stats:
            self.stats.add("bvalue_bytes", n)

    # -- write path -----------------------------------------------------------
    def _pick_queue(self) -> _BValueQueue:
        if self.dispatch == "least_loaded":
            return min(self.queues, key=lambda q: q.pending_bytes)
        q = self.queues[self._rr % len(self.queues)]
        self._rr += 1
        return q

    def put(self, key: bytes, value: bytes, sync: bool) -> ValueOffset:
        q = self._pick_queue()
        file_id, off = q.reserve(len(value))
        voff = ValueOffset(file_id, off, len(value), zlib.crc32(value) & 0xFFFFFFFF)
        if sync or not self.async_writes:
            q.write_sync(file_id, off, value)
        else:
            q.submit(_Pending(file_id, off, value, key))
        return voff

    # -- read path ------------------------------------------------------------
    def get(self, voff: ValueOffset, verify: bool = False) -> bytes:
        fd = self._reader_fd(voff.file_id)
        buf = os.pread(fd, voff.size, voff.offset)
        if len(buf) != voff.size:
            raise IOError(
                f"short BValue read: file {voff.file_id} off {voff.offset} "
                f"want {voff.size} got {len(buf)}"
            )
        if verify and voff.crc and (zlib.crc32(buf) & 0xFFFFFFFF) != voff.crc:
            raise IOError(f"BValue CRC mismatch at file {voff.file_id}+{voff.offset}")
        return buf

    def drop_reader(self, file_id: int) -> None:
        with self._read_lock:
            fd = self._read_fds.pop(file_id, None)
            if fd is not None:
                os.close(fd)

    def _reader_fd(self, file_id: int) -> int:
        with self._read_lock:
            fd = self._read_fds.get(file_id)
            if fd is None:
                fd = os.open(self.file_path(file_id), os.O_RDONLY)
                self._read_fds[file_id] = fd
            return fd

    # -- lifecycle -------------------------------------------------------------
    def flush(self) -> None:
        """Barrier: wait for all pending async writes to hit disk."""
        for q in self.queues:
            while q.pending_bytes > 0 or not q._q.empty():
                import time

                time.sleep(0.001)

    @property
    def next_file_id(self) -> int:
        with self._file_lock:
            return self._next_file_id

    def close(self) -> None:
        for q in self.queues:
            q.close()
        with self._read_lock:
            for fd in self._read_fds.values():
                os.close(fd)
            self._read_fds.clear()
