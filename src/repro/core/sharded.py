"""ShardedDB — horizontal keyspace sharding behind one KVStore surface.

The paper's multi-queue parallel value store, lifted one level up
(ROADMAP item 1): partition the whole engine so N independent
WAL / value-queue / scheduler stacks run in parallel, each shard a full
:class:`~.db.DB` with its own directory, behind a single router that
satisfies the same :class:`~.api.KVStore` protocol as one ``DB``.

Layout::

    <path>/ROUTER            msgpack manifest: shard count + partitioner
                             (atomic tmp+rename; its presence commits the
                             store — mirrors the per-shard MANIFEST)
    <path>/ROUTER_LOG        cross-shard batch durability log (CRC-framed,
                             torn-tail tolerant — same framing as the WAL)
    <path>/shard_00000/ …    one full DB per shard

Partitioning
------------

``HashPartitioner`` (default) places each key by ``crc32(key) % N`` —
stable across processes and Python versions (``hash()`` is salted), and
uniform enough that every shard sees ~1/N of the keyspace. Because hash
placement scatters any key interval across all shards, a range delete
fans out to *every* shard (each applies the full ``[start, end)``
tombstone — keys it doesn't own simply aren't covered by it).

``RangePartitioner(boundaries)`` keeps key order: shard ``i`` owns
``[boundaries[i-1], boundaries[i])`` (unbounded at the edges). Range
deletes clip to the overlapping shards only, and merged scans read
shards mostly in sequence instead of interleaving.

The choice is persisted in ``ROUTER`` and validated on reopen: opening
with a different shard count or partitioner than the store was created
with raises ``ValueError`` (config-mismatch detection) — rebalancing is
an explicit offline operation, not something a typo'd ``open()`` should
silently begin.

Cross-shard WriteBatch atomicity
--------------------------------

A batch whose ops land on ONE shard is exactly that shard's atomic
``write`` — one WAL record, crash-atomic, nothing extra. A batch
spanning shards cannot be made atomic by the shards alone (each commits
its own WAL independently), so the router adds a lightweight write-ahead
intent log:

1. **intent**: the full batch (ops grouped per shard) is appended to
   ``ROUTER_LOG`` and — under sync WAL — fsynced *before* any shard
   sees it;
2. **apply**: each shard commits its sub-batch atomically (fanned out in
   parallel when ``router_parallel_fanout``);
3. **commit**: a commit record for the batch id is appended (and fsynced
   under sync WAL) — only then is the write acknowledged.

Cross-shard batches are serialized by a router lock, so at a crash at
most the tail batches of the log lack commit records. Reopen replays
every uncommitted intent *forward* into the shards (re-applying a
sub-batch that already committed is state-idempotent: same puts, same
tombstones), flushes them, and truncates the log. A crash therefore
never exposes a torn batch *silently*: either the intent was durable and
the batch is completed at recovery, or the intent never hit the log and
no shard saw any of it (the fsync-before-apply ordering). The guarantee
is exactly as strong as the WAL mode — under ``async``, a sub-batch a
shard acked may be lost with that shard's WAL tail, the same
lose-the-tail semantics a single async DB documents. Note the replay is
*forward-only*: a batch the client never saw acknowledged may become
visible after recovery — a legal serialization (the write was in
flight), the same contract a single DB's group commit gives a crashed
writer.

Readers between steps 2 and 3 can observe a half-applied batch (each
shard publishes independently) — the router provides per-shard
atomicity plus crash completion, not cross-shard isolation. Snapshots
narrow this: ``snapshot()`` takes the per-shard snapshots under the
same router lock that serializes cross-shard commits, so a
``ShardedSnapshot`` never straddles one (it sees all of a cross-shard
batch or none of it). The cut is still not a single global instant —
independent single-shard writes may land between the per-shard
acquisitions.

``checkpoint(dir)`` fans out per-shard online checkpoints under that
same lock (single-shard writes continue; cross-shard batches stall for
the duration), writing the ``ROUTER`` manifest last as the commit
marker — the image opens as a ``ShardedDB`` with the same guarantee:
no torn cross-shard batch, per-shard consistency, not one global
instant.

Scans merge the per-shard cursors: a heap for forward order, a
max-of-candidates walk for reverse — keys are unique across shards
(each key has exactly one home), so no tie-breaking is needed.
"""
from __future__ import annotations

import bisect
import heapq
import os
import threading
import warnings
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import replace as dc_replace

import msgpack

from .config import DBConfig
from .db import DB, Cursor, Snapshot
from .env import DEFAULT_ENV
from .errors import CorruptionError
from .record import frame_record, iter_framed_records, kTypeRangeDeletion
from .writebatch import WriteBatch

ROUTER_NAME = "ROUTER"
ROUTER_LOG_NAME = "ROUTER_LOG"
SHARD_DIR_FMT = "shard_%05d"


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

class HashPartitioner:
    """``crc32(key) % N`` placement — process-stable, order-destroying."""

    name = "hash"

    def __init__(self, num_shards: int):
        self.num_shards = num_shards

    def shard_of(self, key: bytes) -> int:
        return zlib.crc32(key) % self.num_shards

    def shards_for_range(self, start: bytes, end: bytes):
        """Hash placement scatters every interval: all shards, unclipped."""
        return [(i, start, end) for i in range(self.num_shards)]

    def manifest(self) -> dict:
        return {"partitioner": self.name}


class RangePartitioner:
    """Order-preserving split: shard ``i`` owns ``[b[i-1], b[i])`` with
    ``b = boundaries`` (sorted, unique; edges unbounded)."""

    name = "range"

    def __init__(self, boundaries):
        bs = [bytes(b) for b in boundaries]
        if sorted(set(bs)) != bs:
            raise ValueError("range boundaries must be sorted and unique")
        self.boundaries = bs
        self.num_shards = len(bs) + 1

    def shard_of(self, key: bytes) -> int:
        return bisect.bisect_right(self.boundaries, key)

    def shards_for_range(self, start: bytes, end: bytes):
        """Overlapping shards only, the range clipped to each shard's
        span (``end`` exclusive: the last shard touched owns ``end``'s
        predecessor, hence ``bisect_left``)."""
        lo = self.shard_of(start)
        hi = bisect.bisect_left(self.boundaries, end)
        out = []
        for i in range(lo, hi + 1):
            s = start if i == lo else self.boundaries[i - 1]
            e = end if i == hi else self.boundaries[i]
            if s < e:
                out.append((i, s, e))
        return out

    def manifest(self) -> dict:
        return {"partitioner": self.name, "boundaries": self.boundaries}


def _make_partitioner(kind: str, num_shards: int, boundaries):
    if kind == "hash":
        return HashPartitioner(num_shards)
    if kind == "range":
        if boundaries is None or len(boundaries) != num_shards - 1:
            raise ValueError(
                "range partitioning needs exactly shards-1 boundaries"
            )
        return RangePartitioner(boundaries)
    raise ValueError(f"unknown partitioner {kind!r} (hash | range)")


# ---------------------------------------------------------------------------
# router durability log
# ---------------------------------------------------------------------------

class _RouterLog:
    """Append-only CRC-framed log of cross-shard batch intents/commits.

    Records are msgpack maps: ``{"t": "i", "id": n, "ops": [[shard,
    [[type, key, value], …]], …]}`` and ``{"t": "c", "id": n}``. Framing
    (:func:`~.record.frame_record`) matches the WAL, so a torn tail is
    dropped, never misread."""

    def __init__(self, path: str, env):
        self.path = path
        self.env = env
        self.size = env.getsize(path) if env.exists(path) else 0
        self._f = env.open(path, "ab")

    def append(self, rec: dict, sync: bool) -> None:
        buf = frame_record(msgpack.packb(rec, use_bin_type=True))
        self._f.write(buf)
        self._f.flush()
        if sync:
            self.env.fsync(self._f)
        self.size += len(buf)

    def read_records(self) -> list[dict]:
        if not self.env.exists(self.path):
            return []
        with self.env.open(self.path, "rb") as f:
            buf = f.read()
        return [
            msgpack.unpackb(p, raw=False) for p in iter_framed_records(buf)
        ]

    def truncate(self) -> None:
        """Drop everything logged (caller has made the shards cover it)."""
        self._f.close()
        self.env.unlink(self.path)
        self._f = self.env.open(self.path, "ab")
        self.size = 0

    def close(self) -> None:
        self._f.close()


# ---------------------------------------------------------------------------
# snapshots / merged cursor
# ---------------------------------------------------------------------------

class ShardedSnapshot:
    """One pinned read point per shard, taken under the router's
    cross-shard commit lock — the cut never splits a cross-shard batch
    (see the module docstring for what it does *not* promise)."""

    __slots__ = ("_snaps", "_released")

    def __init__(self, snaps: list[Snapshot]):
        self._snaps = snaps
        self._released = False

    def for_shard(self, idx: int) -> Snapshot:
        return self._snaps[idx]

    @property
    def seqs(self) -> list[int]:
        return [s.seq for s in self._snaps]

    def release(self) -> None:
        if not self._released:
            self._released = True
            for s in self._snaps:
                s.release()

    def __enter__(self) -> "ShardedSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "released" if self._released else "live"
        return f"<ShardedSnapshot shards={len(self._snaps)} {state}>"


class MergedCursor:
    """Bidirectional cursor over all shards at one ``ShardedSnapshot``.

    Holds one pinned per-shard :class:`~.db.Cursor` each. Forward
    iteration is a heap of ``(key, shard)`` heads; reverse iteration
    keeps a predecessor candidate per shard and takes the max. Keys are
    unique across shards (one home each), so neither direction needs a
    tie-break. Direction switches re-seek the per-shard cursors around
    the current key — ``seek(k)`` lands on the first key ≥ ``k``, so its
    ``prev()`` is exactly the largest key < ``k``."""

    def __init__(self, sdb: "ShardedDB", snapshot: ShardedSnapshot | None = None):
        self._own_snap = snapshot is None
        self._snap = sdb.snapshot() if snapshot is None else snapshot
        self._curs: list[Cursor] = [
            Cursor(shard, self._snap.for_shard(i))
            for i, shard in enumerate(sdb.shards)
        ]
        self._dir: str | None = None
        self._heap: list[tuple[bytes, int]] = []
        self._cands: list[tuple[bytes, bytes] | None] = []
        self._src = -1  # shard that produced the current position
        self.key: bytes | None = None
        self.value: bytes | None = None
        self.valid = False
        self._closed = False

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.valid = False
        for c in self._curs:
            c.close()
        if self._own_snap:
            self._snap.release()

    def __enter__(self) -> "MergedCursor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- forward ---------------------------------------------------------
    def seek(self, target: bytes) -> bool:
        """Position on the first visible key >= ``target``; returns
        ``valid``."""
        self._dir = "fwd"
        self._heap = []
        for i, c in enumerate(self._curs):
            if c.seek(target):
                self._heap.append((c.key, i))
        heapq.heapify(self._heap)
        return self._pop_fwd()

    def seek_to_first(self) -> bool:
        return self.seek(b"")

    def _pop_fwd(self) -> bool:
        if not self._heap:
            self.key = None
            self.value = None
            self.valid = False
            return False
        key, i = heapq.heappop(self._heap)
        self._src = i
        self.key = key
        self.value = self._curs[i].value  # cursor still parked on ``key``
        self.valid = True
        return True

    def next(self) -> bool:
        """Advance to the next visible key; returns ``valid``."""
        if self._dir == "fwd":
            c = self._curs[self._src]
            if c.next():
                heapq.heappush(self._heap, (c.key, self._src))
            return self._pop_fwd()
        # switching out of reverse (or never positioned): step past the
        # current key — only its home shard re-seeks ONTO it
        if not self.valid:
            return False
        key = self.key
        self._dir = "fwd"
        self._heap = []
        for i, c in enumerate(self._curs):
            ok = c.seek(key)
            if ok and c.key == key:
                ok = c.next()
            if ok:
                self._heap.append((c.key, i))
        heapq.heapify(self._heap)
        return self._pop_fwd()

    # -- reverse ---------------------------------------------------------
    def prev(self) -> bool:
        """Step to the largest visible key strictly below the current one
        (below infinity when invalid). Returns ``valid``."""
        if self._dir == "bwd":
            c = self._curs[self._src]
            self._cands[self._src] = (c.key, c.value) if c.prev() else None
        else:
            bound = self.key if self.valid else None
            self._dir = "bwd"
            self._cands = []
            for c in self._curs:
                if bound is not None:
                    c.seek(bound)  # parks ≥ bound (or exhausts the shard)
                # bound None ⇒ the merged cursor is invalid ⇒ every shard
                # cursor is too, and an invalid prev() is a seek-to-last
                self._cands.append((c.key, c.value) if c.prev() else None)
        best_i = -1
        for i, cand in enumerate(self._cands):
            if cand is not None and (
                best_i < 0 or cand[0] > self._cands[best_i][0]
            ):
                best_i = i
        if best_i < 0:
            self.key = None
            self.value = None
            self.valid = False
            return False
        self._src = best_i
        self.key, self.value = self._cands[best_i]
        self.valid = True
        return True


# ---------------------------------------------------------------------------
# the router
# ---------------------------------------------------------------------------

class ShardedDB:
    """N full ``DB`` engines behind one ``KVStore`` router.

    See the module docstring for placement, cross-shard batch, snapshot
    and checkpoint semantics. Canonical constructor:
    ``ShardedDB.open(path, shards=N, config=None)``."""

    def __init__(
        self,
        path: str,
        shards: int | None = None,
        cfg: DBConfig | None = None,
        partitioner: str = "hash",
        boundaries=None,
    ):
        self.path = path
        self.cfg = cfg or DBConfig()
        self.env = self.cfg.env or DEFAULT_ENV
        self.env.makedirs(path)
        manifest_path = os.path.join(path, ROUTER_NAME)
        existing = self._load_manifest(manifest_path)
        if existing is not None:
            # config-mismatch-on-reopen detection: adopt what's persisted,
            # reject explicit arguments that contradict it
            if shards is not None and shards != existing["shards"]:
                raise ValueError(
                    f"shard-count mismatch: store at {path!r} has "
                    f"{existing['shards']} shards, open() asked for {shards}"
                )
            if partitioner != "hash" and partitioner != existing["partitioner"]:
                raise ValueError(
                    f"partitioner mismatch: store at {path!r} uses "
                    f"{existing['partitioner']!r}, open() asked for "
                    f"{partitioner!r}"
                )
            shards = existing["shards"]
            partitioner = existing["partitioner"]
            if partitioner == "range":
                boundaries = existing["boundaries"]
        elif shards is None:
            raise ValueError(
                f"no sharded store at {path!r}: pass shards=N to create one"
            )
        elif shards < 1:
            raise ValueError("shards must be >= 1")
        self.partitioner = _make_partitioner(partitioner, shards, boundaries)
        shard_cfg = self._shard_config(shards)
        self.shards: list[DB] = [
            DB(os.path.join(path, SHARD_DIR_FMT % i), shard_cfg)
            for i in range(shards)
        ]
        # serializes cross-shard commits; snapshot()/checkpoint() take it
        # so their per-shard cuts never split a cross-shard batch
        self._batch_lock = threading.Lock()
        self._pool = (
            ThreadPoolExecutor(
                max_workers=min(shards, 16),
                thread_name_prefix="shard-router",
            )
            if self.cfg.router_parallel_fanout and shards > 1
            else None
        )
        self._router_stats = {
            "single_shard_batches": 0,
            "cross_shard_batches": 0,
            "replayed_batches": 0,
            "log_truncations": 0,
        }
        self._log = _RouterLog(os.path.join(path, ROUTER_LOG_NAME), self.env)
        self._log_sync = self.cfg.wal_mode == "sync"
        self._next_batch_id = 1
        self._closed = False
        self._replay_log()
        if existing is None:
            # manifest LAST: its presence commits the store, so a crash
            # mid-create leaves a directory open() refuses half-made
            self._write_manifest(manifest_path)

    # -- construction helpers -------------------------------------------
    @classmethod
    def open(
        cls,
        path: str,
        shards: int | None = None,
        config: DBConfig | None = None,
        **kw,
    ) -> "ShardedDB":
        """Canonical constructor: open the sharded store at ``path``,
        creating it with ``shards`` engines if absent. On reopen the
        persisted shard count/partitioner win; passing a contradicting
        ``shards`` raises ``ValueError``."""
        return cls(path, shards, config, **kw)

    def _shard_config(self, n: int) -> DBConfig:
        cfg = self.cfg
        if not cfg.shard_divide_cache_budget or n <= 1:
            return cfg
        # divide the cache budgets so N shards cost what the config names
        return dc_replace(
            cfg,
            block_cache_bytes=cfg.block_cache_bytes // n,
            bvcache_bytes=cfg.bvcache_bytes // n,
        )

    def _load_manifest(self, manifest_path: str) -> dict | None:
        if not self.env.exists(manifest_path):
            return None
        with self.env.open(manifest_path, "rb") as f:
            raw = f.read()
        try:
            meta = msgpack.unpackb(raw, raw=False)
        except Exception as e:
            raise CorruptionError(f"unreadable ROUTER manifest: {e}") from e
        if meta.get("partitioner") == "range":
            meta["boundaries"] = [bytes(b) for b in meta["boundaries"]]
        return meta

    def _write_manifest(self, manifest_path: str) -> None:
        meta = {"shards": len(self.shards)}
        meta.update(self.partitioner.manifest())
        tmp = manifest_path + ".tmp"
        f = self.env.open(tmp, "wb")
        try:
            f.write(msgpack.packb(meta, use_bin_type=True))
            f.flush()
            self.env.fsync(f)
        finally:
            f.close()
        self.env.rename(tmp, manifest_path)

    def _replay_log(self) -> None:
        """Complete every intent the log holds no commit record for (the
        crash-recovery half of the cross-shard batch protocol)."""
        records = self._log.read_records()
        outstanding: dict[int, list] = {}
        max_id = 0
        for rec in records:
            max_id = max(max_id, rec["id"])
            if rec["t"] == "i":
                outstanding[rec["id"]] = rec["ops"]
            else:
                outstanding.pop(rec["id"], None)
        self._next_batch_id = max_id + 1
        if not outstanding:
            if records:
                self._truncate_log_locked()
            return
        touched = set()
        for bid in sorted(outstanding):
            for shard_idx, entries in outstanding[bid]:
                self.shards[shard_idx].write(WriteBatch.from_entries(entries))
                touched.add(shard_idx)
            self._router_stats["replayed_batches"] += 1
        # the shards' WALs now cover the replayed ops; flush before the
        # log is dropped so a crash right here cannot lose them again
        self._fan([self.shards[i].flush for i in sorted(touched)])
        self._truncate_log_locked()

    # -- fan-out plumbing ------------------------------------------------
    def _fan(self, fns):
        """Run the thunks, in parallel when the router pool exists; the
        result list is aligned with ``fns``."""
        if self._pool is None or len(fns) <= 1:
            return [fn() for fn in fns]
        return [f.result() for f in [self._pool.submit(fn) for fn in fns]]

    def _truncate_log_locked(self) -> None:
        self._log.truncate()
        self._router_stats["log_truncations"] += 1

    def shard_of(self, key: bytes) -> int:
        """The shard index ``key`` lives on (routing is deterministic)."""
        return self.partitioner.shard_of(key)

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    # -- write path ------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        """Route ``key -> value`` to its home shard (that shard's ``put``
        durability semantics apply unchanged)."""
        self.shards[self.partitioner.shard_of(key)].put(key, value)

    def delete(self, key: bytes) -> None:
        self.shards[self.partitioner.shard_of(key)].delete(key)

    def delete_range(self, start: bytes, end: bytes) -> None:
        """Range-tombstone ``[start, end)``. Under hash partitioning every
        shard gets the full tombstone (an interval scatters across all of
        them); under range partitioning only the overlapping shards get
        their clipped pieces. Multi-shard fan-out runs through the
        cross-shard batch protocol, so a crash completes it at reopen
        instead of leaving some shards un-tombstoned silently."""
        batch = WriteBatch().delete_range(start, end)
        self.write(batch)

    def write(self, batch: WriteBatch) -> None:
        """Apply a :class:`WriteBatch`. One-shard batches are that shard's
        native atomic commit; multi-shard batches run the logged
        intent/apply/commit protocol (module docstring: per-shard atomic,
        crash-completed, not cross-shard isolated)."""
        per_shard: dict[int, list] = {}
        for type_, key, value in batch:
            if type_ == kTypeRangeDeletion:
                for idx, s, e in self.partitioner.shards_for_range(key, value):
                    per_shard.setdefault(idx, []).append((type_, s, e))
            else:
                idx = self.partitioner.shard_of(key)
                per_shard.setdefault(idx, []).append((type_, key, value))
        if not per_shard:
            return
        if len(per_shard) == 1:
            idx, entries = next(iter(per_shard.items()))
            self.shards[idx].write(WriteBatch.from_entries(entries))
            self._router_stats["single_shard_batches"] += 1
            return
        ops = sorted(per_shard.items())
        with self._batch_lock:
            bid = self._next_batch_id
            self._next_batch_id += 1
            self._log.append(
                {
                    "t": "i",
                    "id": bid,
                    "ops": [
                        [idx, [list(e) for e in entries]]
                        for idx, entries in ops
                    ],
                },
                sync=self._log_sync,
            )
            self._fan(
                [
                    (lambda s=self.shards[idx], es=entries:
                        s.write(WriteBatch.from_entries(es)))
                    for idx, entries in ops
                ]
            )
            # commit durable before the ack: a post-ack write must never
            # be clobbered by this batch's replay after a crash
            self._log.append({"t": "c", "id": bid}, sync=self._log_sync)
            self._router_stats["cross_shard_batches"] += 1
            if self._log.size > self.cfg.router_log_max_bytes:
                # everything logged is committed (commits are serialized
                # under this lock); flush the shards so their WALs cover
                # it, then drop the log
                self._fan([s.flush for s in self.shards])
                self._truncate_log_locked()

    # -- read path -------------------------------------------------------
    def get(
        self, key: bytes, snapshot: ShardedSnapshot | None = None
    ) -> bytes | None:
        idx = self.partitioner.shard_of(key)
        snap = None if snapshot is None else snapshot.for_shard(idx)
        return self.shards[idx].get(key, snapshot=snap)

    def multi_get(
        self, keys, snapshot: ShardedSnapshot | None = None
    ) -> list[bytes | None]:
        """Batched lookup: keys group by home shard, each shard runs ONE
        ``multi_get`` over its group (PR 9's vectorized bloom probes +
        same-block coalescing apply per shard), fanned out in parallel;
        results re-align with ``keys``."""
        keys = [bytes(k) for k in keys]
        if not keys:
            return []
        groups: dict[int, list[int]] = {}
        for pos, key in enumerate(keys):
            groups.setdefault(self.partitioner.shard_of(key), []).append(pos)
        order = sorted(groups)
        results = self._fan(
            [
                (lambda i=idx: self.shards[i].multi_get(
                    [keys[p] for p in groups[i]],
                    snapshot=None if snapshot is None else snapshot.for_shard(i),
                ))
                for idx in order
            ]
        )
        out: list[bytes | None] = [None] * len(keys)
        for idx, vals in zip(order, results):
            for pos, val in zip(groups[idx], vals):
                out[pos] = val
        return out

    def range(
        self,
        start: bytes = b"",
        end: bytes | None = None,
        limit: int | None = None,
        snapshot: ShardedSnapshot | None = None,
    ):
        """Stream live ``(key, value)`` pairs with ``start <= key``
        (``< end`` when given), globally ascending across every shard, up
        to ``limit`` — same contract as :meth:`DB.range`, served from a
        :class:`MergedCursor`."""
        if limit is not None and limit <= 0:
            return
        n = 0
        with MergedCursor(self, snapshot) as cur:
            ok = cur.seek(start)
            while ok:
                key = cur.key
                if end is not None and key >= end:
                    return
                yield key, cur.value
                n += 1
                if limit is not None and n >= limit:
                    return
                ok = cur.next()

    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Deprecated: use ``range(start, limit=count)``."""
        warnings.warn(
            "ShardedDB.scan(start, count) is deprecated; use "
            "ShardedDB.range(start, limit=count)",
            DeprecationWarning,
            stacklevel=2,
        )
        return list(self.range(start, limit=count))

    def iterator(self, snapshot: ShardedSnapshot | None = None) -> MergedCursor:
        """A bidirectional :class:`MergedCursor` over all shards at one
        stable read point (``snapshot``, or one taken now and released on
        close)."""
        return MergedCursor(self, snapshot)

    def snapshot(self) -> ShardedSnapshot:
        """Pin one read point per shard under the cross-shard commit lock
        — the cut never splits a cross-shard batch (module docstring)."""
        with self._batch_lock:
            return ShardedSnapshot([s.snapshot() for s in self.shards])

    # -- maintenance / lifecycle ----------------------------------------
    def flush(self) -> None:
        """Per-shard durability barriers, fanned out."""
        self._fan([s.flush for s in self.shards])

    def wait_idle(self, compactions: bool = True, timeout: float = 120.0) -> None:
        for s in self.shards:
            s.wait_idle(compactions=compactions, timeout=timeout)

    def compact_all(self) -> None:
        self._fan([s.compact_all for s in self.shards])

    def gc_collect(self, threshold: float = 0.5) -> dict:
        """Run value GC on every shard; numeric stats summed across them."""
        reports = self._fan(
            [(lambda s=s: s.gc_collect(threshold=threshold)) for s in self.shards]
        )
        agg: dict = {}
        for rep in reports:
            for k, v in rep.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                agg[k] = agg.get(k, 0) + v
        agg["per_shard"] = reports
        return agg

    def checkpoint(self, directory: str) -> None:
        """Consistent online copy of the whole sharded store: per-shard
        online checkpoints under the cross-shard commit lock (the cut
        never splits a cross-shard batch; single-shard writes continue),
        then the ``ROUTER`` manifest — written last, via tmp+rename — as
        the commit marker. The image opens with ``ShardedDB.open(dir)``;
        no ``ROUTER_LOG`` is copied because under the lock nothing is
        uncommitted and each shard's checkpoint flushes first."""
        self.env.makedirs(directory)
        with self._batch_lock:
            self._fan(
                [
                    (lambda s=s, i=i: s.checkpoint(
                        os.path.join(directory, SHARD_DIR_FMT % i)
                    ))
                    for i, s in enumerate(self.shards)
                ]
            )
            self._write_manifest(os.path.join(directory, ROUTER_NAME))

    def stats(self) -> dict:
        """Aggregate + per-shard engine counters, plus router counters.

        ``aggregate`` sums every numeric counter across shards (ratios are
        recomputed from the summed inputs where that's meaningful:
        ``write_amp``, ``block_cache_hit_rate``); ``per_shard`` keeps the
        full per-engine dicts for tail analysis."""
        per = [s.stats() for s in self.shards]
        agg: dict = {}
        for p in per:
            for k, v in p.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                agg[k] = agg.get(k, 0) + v
        if agg.get("user_bytes"):
            agg["write_amp"] = agg.get("device_bytes", 0) / agg["user_bytes"]
        probes = agg.get("block_cache_hits", 0) + agg.get("block_cache_misses", 0)
        if probes:
            agg["block_cache_hit_rate"] = agg.get("block_cache_hits", 0) / probes
        return {
            "shards": len(per),
            "router": dict(self._router_stats),
            "router_log_bytes": self._log.size,
            "aggregate": agg,
            "per_shard": per,
        }

    def verify_integrity(self, fail_fast: bool = False) -> dict:
        """Inline scrub of every shard; counts summed, findings merged
        (each finding annotated with its shard index)."""
        report = {
            "shards": len(self.shards),
            "sst_files": 0,
            "blocks_verified": 0,
            "values_verified": 0,
            "corruptions": [],
            "findings": [],
            "per_shard": [],
        }
        for i, s in enumerate(self.shards):
            rep = s.verify_integrity(fail_fast=fail_fast)
            report["per_shard"].append(rep)
            for k in ("sst_files", "blocks_verified", "values_verified"):
                report[k] += rep.get(k, 0)
            report["corruptions"].extend(
                f"shard {i}: {c}" for c in rep.get("corruptions", ())
            )
            for f in rep.get("findings", ()):
                report["findings"].append({**f, "shard": i})
        return report

    def close(self, crash: bool = False) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._fan([(lambda s=s: s.close(crash=crash)) for s in self.shards])
        finally:
            self._log.close()
            if self._pool is not None:
                self._pool.shutdown(wait=True)

    def __enter__(self) -> "ShardedDB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
