"""Flush + leveled compaction, run as jobs on the background scheduler.

The write-amplification mechanics the paper targets live here: with
``separation_mode="none"`` every compaction rewrites full values across
levels; with ``"flush"`` (BlobDB) values leave the pipeline at flush time;
with ``"wal"`` (BVLSM) they never enter it. All three modes share this exact
code — the benchmark deltas isolate the separation stage.

Jitter engineering (the paper's Fig. 9 claim) is layered on top:

* **Lock-aware picking** — :meth:`Compactor.pick` skips files whose
  compaction lock (see :class:`~repro.core.manifest.VersionSet`) is held by
  a running job, so several compaction jobs proceed concurrently on
  disjoint input sets. Levels are tried in descending score order: if the
  hottest level is locked out, the next-most-urgent one runs instead.
* **Partitioned subcompactions** — one level-N→N+1 compaction splits its
  key range at input-file boundaries into up to ``max_subcompactions``
  shards; each shard heap-merges only its range and writes its own output
  tables. All shards commit as ONE atomic manifest edit, so a crash
  mid-subcompaction leaves either the old file set or the new one — never
  a mix (orphan outputs are swept on reopen).
* **Rate-limited writes** — every flush/compaction output byte draws from
  the DB's shared token bucket (:mod:`.ratelimiter`), flushes at high
  priority, compactions at low, so a merge burst cannot starve foreground
  WAL/BValue fsyncs.
"""
from __future__ import annotations

import heapq
import os

from .ratelimiter import IO_CHUNK, PRI_HIGH, PRI_LOW
from .record import ValueOffset, kTypeDeletion, kTypeValue, kTypeValuePtr
from .sstable import SSTableWriter, table_path


def _merge_iters(iters):
    """Heap-merge (key, seq, type, value) streams; newest version first per
    key; yields every version (caller dedups)."""
    heap = []
    for i, it in enumerate(iters):
        it = iter(it)
        for key, seq, type_, value in it:
            heapq.heappush(heap, (key, -seq, i, type_, value, it))
            break
    while heap:
        key, nseq, i, type_, value, it = heapq.heappop(heap)
        yield key, -nseq, type_, value
        for k2, s2, t2, v2 in it:
            heapq.heappush(heap, (k2, -s2, i, t2, v2, it))
            break


class Compactor:
    def __init__(self, db):
        self.db = db  # back-reference; uses db.versions, db.cfg, db.stats

    # ------------------------------------------------------------------
    # flush
    # ------------------------------------------------------------------
    def flush_memtable(self, mem) -> None:
        db = self.db
        cfg = db.cfg
        limiter = db.rate_limiter
        file_no = db.versions.new_file_no()
        writer = SSTableWriter(
            table_path(db.path, file_no), cfg.block_size, cfg.compression,
            cfg.sstable_format_version, cfg.block_restart_interval,
        )
        n_written = 0
        pending_io = 0
        for key, seq, type_, value in mem.sorted_items():
            if (
                cfg.separation_mode == "flush"
                and type_ == kTypeValue
                and len(value) >= cfg.value_threshold
            ):
                # BlobDB/WiscKey: separate at flush — value goes to the value
                # log now; only the pointer reaches L0.
                voff = db.bvalue.put(key, value, sync=cfg.sync_flush_io)
                writer.add(key, seq, kTypeValuePtr, voff.encode())
            else:
                writer.add(key, seq, type_, value)
            n_written += 1
            pending_io += len(key) + len(value)
            if pending_io >= IO_CHUNK:
                limiter.request(pending_io, PRI_HIGH)
                pending_io = 0
        limiter.request(pending_io, PRI_HIGH)
        if n_written == 0:
            writer.abandon()
            return
        meta = writer.finish(file_no)
        db.stats.add("flush_bytes", meta.size)
        db.stats.add("flush_count")
        db.versions.log_and_apply(
            {
                "add": [(0, meta.to_wire())],
                "last_seq": mem.last_seq,
                "bvalue_next_file_id": db.bvalue.next_file_id,
            }
        )
        # this memtable's WAL is now redundant — delete it
        if getattr(mem, "wal_no", None) is not None:
            try:
                os.unlink(db._wal_path(mem.wal_no))
            except OSError:
                pass

    # ------------------------------------------------------------------
    # compaction picking
    # ------------------------------------------------------------------
    def pick(self, locked=frozenset()):
        """Returns (level, [input files Ln], [input files Ln+1]) or None,
        never selecting a file whose compaction lock is held (``locked``).
        Levels are tried in descending score order so a locked-out hottest
        level doesn't block all background progress."""
        db = self.db
        cfg = db.cfg
        v = db.versions.current
        scored: list[tuple[float, int]] = []
        score0 = len(v.levels[0]) / cfg.l0_compaction_trigger
        if score0 >= 1.0:
            scored.append((score0, 0))
        for level in range(1, cfg.num_levels - 1):
            score = v.level_bytes(level) / cfg.level_max_bytes(level)
            if score > 1.0:
                scored.append((score, level))
        scored.sort(reverse=True)
        for _score, level in scored:
            picked = self._pick_level(v, level, locked)
            if picked is not None:
                return picked
        return None

    def _pick_level(self, v, level: int, locked):
        db = self.db
        cfg = db.cfg
        if level == 0:
            inputs = list(v.levels[0])
            if not inputs or any(f.file_no in locked for f in inputs):
                # L0 files overlap arbitrarily — at most one L0 job at a time
                return None
            smallest = min(f.smallest for f in inputs)
            largest = max(f.largest for f in inputs)
            overlaps = v.files_touching(1, smallest, largest)
            if any(f.file_no in locked for f in overlaps):
                return None
            return 0, inputs, overlaps
        # round-robin pointer within the level (LevelDB style), skipping
        # files locked by running jobs. The full Ln+1 overlap set always
        # rides along: truncating it (as the pre-scheduler code did) left
        # the merged output overlapping the dropped files, breaking the
        # sorted-level disjointness that point lookups binary-search on.
        # max_compaction_input_bytes instead steers the *choice*: prefer a
        # file whose job fits the cap, falling back to the smallest
        # oversized one so progress is still guaranteed.
        files = v.levels[level]
        if not files:
            return None
        ptr = db.versions.compaction_ptr.get(level, b"")
        start = next((i for i, f in enumerate(files) if f.smallest > ptr), 0)
        fallback = None  # (total, pick_file, overlaps) of the smallest oversized job
        for off in range(len(files)):
            pick_file = files[(start + off) % len(files)]
            if pick_file.file_no in locked:
                continue
            overlaps = v.files_touching(level + 1, pick_file.smallest, pick_file.largest)
            if any(f.file_no in locked for f in overlaps):
                continue
            total = pick_file.size + sum(f.size for f in overlaps)
            if total > cfg.max_compaction_input_bytes:
                if fallback is None or total < fallback[0]:
                    fallback = (total, pick_file, overlaps)
                continue
            db.versions.compaction_ptr[level] = pick_file.smallest
            return level, [pick_file], overlaps
        if fallback is not None:
            _total, pick_file, overlaps = fallback
            db.versions.compaction_ptr[level] = pick_file.smallest
            return level, [pick_file], overlaps
        return None

    # ------------------------------------------------------------------
    # compaction run
    # ------------------------------------------------------------------
    def run(self, level: int, inputs, overlaps, subtasks=None) -> None:
        """Merge ``inputs`` (Ln) + ``overlaps`` (Ln+1) into new Ln+1 tables
        and commit the swap as one atomic manifest edit.

        ``subtasks`` (callable: list of thunks → list of results) fans the
        key-range shards out across the scheduler's subcompaction pool;
        None runs them sequentially (same result, one thread)."""
        db = self.db
        cfg = db.cfg
        out_level = level + 1
        v = db.versions.current
        bottom = all(not v.levels[l] for l in range(out_level + 1, cfg.num_levels))
        fill = not cfg.block_cache_compaction_bypass
        read_bytes = sum(f.size for f in inputs + overlaps)

        bounds = self._subcompaction_bounds(inputs, overlaps, cfg.max_subcompactions)
        ranges = list(zip([None] + bounds, bounds + [None]))

        def shard_thunk(lo, hi):
            def go():
                try:
                    return self._run_range(level, inputs, overlaps, lo, hi, bottom, fill), None
                except BaseException as e:
                    return [], e

            return go

        thunks = [shard_thunk(lo, hi) for lo, hi in ranges]
        if len(thunks) == 1 or subtasks is None:
            results = [t() for t in thunks]
        else:
            results = subtasks(thunks)
            db.stats.add("subcompactions", len(thunks))
        metas = []
        err: BaseException | None = None
        for shard_metas, shard_err in results:
            metas.extend(shard_metas)
            if shard_err is not None and err is None:
                err = shard_err
        if err is not None:
            # no manifest edit happened: drop every shard's output so the
            # live process never leaks tables (reopen would sweep them too)
            for m in metas:
                try:
                    os.unlink(table_path(db.path, m.file_no))
                except OSError:
                    pass
            raise err
        metas.sort(key=lambda m: m.smallest)

        written = sum(m.size for m in metas)
        db.stats.add("compaction_bytes", written)
        db.stats.add("compaction_read_bytes", read_bytes)
        db.stats.add("compaction_count")
        edit = {
            "add": [(out_level, m.to_wire()) for m in metas],
            "delete": [(level, f.file_no) for f in inputs]
            + [(out_level, f.file_no) for f in overlaps],
        }
        db.versions.log_and_apply(edit)
        for f in inputs + overlaps:
            db.versions.drop_reader(f.file_no)
            try:
                os.unlink(table_path(db.path, f.file_no))
            except OSError:
                pass

    def _subcompaction_bounds(self, inputs, overlaps, max_shards: int) -> list[bytes]:
        """Choose up to ``max_shards - 1`` split keys from the input files'
        natural boundaries, weighted by file size so shards carry roughly
        equal bytes. When file boundaries alone can't split the range —
        the common L0→L1 case where every L0 file spans the whole key
        window — fall back to sampling block boundaries from the largest
        input's index. Returns an ascending list of keys; shard i covers
        ``[bounds[i-1], bounds[i])`` (half-open, first/last unbounded)."""
        if max_shards <= 1:
            return []
        points = sorted((f.smallest, f.size) for f in inputs + overlaps)
        total = sum(sz for _, sz in points)
        if len(points) < 2 or total <= 0:
            return []
        bounds: list[bytes] = []
        acc = 0
        target = total / min(max_shards, len(points))
        for key, sz in points:
            if acc >= target * (len(bounds) + 1) and (not bounds or key > bounds[-1]):
                bounds.append(key)
                if len(bounds) >= max_shards - 1:
                    break
            acc += sz
        if len(bounds) < max_shards - 1:
            bounds = self._augment_bounds_from_index(
                inputs + overlaps, bounds, max_shards
            )
        return bounds

    def _augment_bounds_from_index(self, files, bounds: list[bytes], max_shards: int):
        """Merge index-block boundary keys of the largest input into the
        split set and re-pick evenly — overlapping inputs then still shard
        into balanced ranges. Best-effort: any failure (reader gone, empty
        index) keeps the file-boundary bounds."""
        try:
            big = max(files, key=lambda f: f.size)
            index = self.db.versions.reader(big.file_no).index
            if len(index) < 2:
                return bounds
            lo, hi = min(f.smallest for f in files), max(f.largest for f in files)
            cand = sorted(
                {k for k, _off, _len in index[:-1] if lo < k <= hi} | set(bounds)
            )
            if not cand:
                return bounds
            n = min(max_shards - 1, len(cand))
            step = len(cand) / (n + 1)
            picked = sorted({cand[min(len(cand) - 1, int(step * (i + 1)))] for i in range(n)})
            return picked
        except Exception:
            return bounds

    def _run_range(self, level, inputs, overlaps, lo, hi, bottom, fill):
        """One subcompaction shard: merge keys in ``[lo, hi)`` (None =
        unbounded) into fresh Ln+1 tables; returns their FileMetadata.
        Shards touch disjoint key ranges, so per-shard version dedup and
        dead-pointer tracking are exactly as correct as the serial merge."""
        db = self.db
        cfg = db.cfg
        limiter = db.rate_limiter
        iters = []
        for f in inputs + overlaps:
            if lo is not None and f.largest < lo:
                continue
            if hi is not None and f.smallest >= hi:
                continue
            r = db.versions.reader(f.file_no)
            iters.append(
                r.iter_from(lo, fill_cache=fill) if lo is not None else r.iter_all(fill_cache=fill)
            )

        target = max(cfg.memtable_size, 4 << 20)
        writer = None
        file_no = None
        metas = []

        def roll():
            nonlocal writer, file_no
            if writer is not None and writer._count > 0:
                metas.append(writer.finish(file_no))
                writer = None
            elif writer is not None:
                writer.abandon()
                writer = None

        last_key = None
        pending_io = 0
        try:
            for key, seq, type_, value in _merge_iters(iters):
                if hi is not None and key >= hi:
                    break  # the next shard owns [hi, ...)
                if key == last_key:
                    if type_ == kTypeValuePtr:  # shadowed big value → dead
                        db.dead_tracker.on_dead(ValueOffset.decode(value))
                    continue  # older version shadowed (no snapshots)
                last_key = key
                if type_ == kTypeDeletion and bottom:
                    continue  # tombstone reached the bottom — drop it
                if writer is None:
                    file_no = db.versions.new_file_no()
                    writer = SSTableWriter(
                        table_path(db.path, file_no), cfg.block_size, cfg.compression,
                        cfg.sstable_format_version, cfg.block_restart_interval,
                    )
                writer.add(key, seq, type_, value)
                pending_io += len(key) + len(value)
                if pending_io >= IO_CHUNK:
                    limiter.request(pending_io, PRI_LOW)
                    pending_io = 0
                if writer._offset >= target:
                    roll()
            roll()
        except BaseException:
            # a failed shard must not leak its outputs: abandon the
            # in-progress writer (closes + unlinks) and drop the tables it
            # already rolled — run() only cleans up *returned* metas
            if writer is not None:
                try:
                    writer.abandon()
                except OSError:
                    pass
            for m in metas:
                try:
                    os.unlink(table_path(db.path, m.file_no))
                except OSError:
                    pass
            raise
        limiter.request(pending_io, PRI_LOW)
        return metas
