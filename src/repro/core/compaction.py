"""Flush + leveled compaction, run as jobs on the background scheduler.

The write-amplification mechanics the paper targets live here: with
``separation_mode="none"`` every compaction rewrites full values across
levels; with ``"flush"`` (BlobDB) values leave the pipeline at flush time;
with ``"wal"`` (BVLSM) they never enter it. All three modes share this exact
code — the benchmark deltas isolate the separation stage.

Write-amp-aware picking asks one question of every candidate job: how many
bytes must the device rewrite per byte this job actually moves down?

* **Overlap-ratio scoring** (``compaction_pick_policy="overlap"``) — a
  job's write amplification is ``1 + overlap_bytes / input_bytes`` (the
  target-level bytes it drags through the merge). Each over-trigger
  level's urgency (fullness) is discounted by its cheapest job's
  amplification, and within a level the file with the smallest overlap
  ratio is picked — the same debt is cleared for fewer device writes.
  ``"fullness"`` restores the legacy hottest-level / round-robin-file
  policy (the write-amp benchmark's ablation baseline).
* **Trivial moves** — a picked file with zero target-level overlap is
  promoted by ONE manifest edit: no read, no merge, no tables written
  (guarded by a grandparent-overlap cap so a wide file is not parked
  where it makes the next level's future jobs more expensive). Safety
  rests on the per-file locks plus an interval argument: every concurrent
  job's output span is closed over the files it locked at pick time, none
  of which touched the moved file's range — so no later output can
  straddle it and break sorted-level disjointness.

Jitter engineering (the paper's Fig. 9 claim) is layered on top:

* **Lock-aware picking** — :meth:`Compactor.pick` skips files whose
  compaction lock (see :class:`~repro.core.manifest.VersionSet`) is held by
  a running job, so several compaction jobs proceed concurrently on
  disjoint input sets. Levels are tried in descending score order: if the
  hottest level is locked out, the next-most-urgent one runs instead.
* **Partitioned subcompactions** — one level-N→N+1 compaction splits its
  key range at input-file boundaries into up to ``max_subcompactions``
  shards; each shard heap-merges only its range and writes its own output
  tables. All shards commit as ONE atomic manifest edit, so a crash
  mid-subcompaction leaves either the old file set or the new one — never
  a mix (orphan outputs are swept on reopen). The shard count is adaptive
  (``subcompaction_adaptive``): chosen from the live input size and an
  EWMA of historical per-shard merge throughput, so tiny jobs skip the
  fan-out entirely and big ones target ``subcompaction_target_seconds``
  of wall time per shard.
* **Rate-limited writes** — every flush/compaction output byte draws from
  the DB's shared token bucket (:mod:`.ratelimiter`), flushes at high
  priority, compactions at low, so a merge burst cannot starve foreground
  WAL/BValue fsyncs.
"""
from __future__ import annotations

import heapq
import time
from bisect import bisect_left

from .ratelimiter import IO_CHUNK, PRI_HIGH, PRI_LOW
from .record import ValueOffset, kTypeDeletion, kTypeValue, kTypeValuePtr
from .sstable import SSTableWriter, table_path


def _merge_iters(iters):
    """Heap-merge (key, seq, type, value) streams; newest version first per
    key; yields every version (caller dedups)."""
    heap = []
    for i, it in enumerate(iters):
        it = iter(it)
        for key, seq, type_, value in it:
            heapq.heappush(heap, (key, -seq, i, type_, value, it))
            break
    while heap:
        key, nseq, i, type_, value, it = heapq.heappop(heap)
        yield key, -nseq, type_, value
        for k2, s2, t2, v2 in it:
            heapq.heappush(heap, (k2, -s2, i, t2, v2, it))
            break


def _coalesce_tombstones(tombs):
    """Merge same-seq touching/overlapping range-tombstone fragments back
    into maximal runs (compaction clipping fragments them; re-coalescing
    keeps the per-table range blocks from growing without bound). Returns
    a new list sorted by (start, end, seq)."""
    by_seq: dict[int, list[tuple[bytes, bytes]]] = {}
    for seq, start, end in tombs:
        by_seq.setdefault(seq, []).append((start, end))
    out: list[tuple[int, bytes, bytes]] = []
    for seq, frags in by_seq.items():
        frags.sort()
        cs, ce = frags[0]
        for s, e in frags[1:]:
            if s <= ce:
                ce = max(ce, e)
            else:
                out.append((seq, cs, ce))
                cs, ce = s, e
        out.append((seq, cs, ce))
    out.sort(key=lambda t: (t[1], t[2], t[0]))
    return out


class Compactor:
    def __init__(self, db):
        self.db = db  # back-reference; uses db.versions, db.cfg, db.stats
        # historical per-shard merge throughput (EWMA, bytes of input per
        # shard-second) — feeds the adaptive subcompaction shard count
        self._shard_bytes_per_s = 0.0

    # ------------------------------------------------------------------
    # flush
    # ------------------------------------------------------------------
    def flush_memtable(self, mem) -> None:
        db = self.db
        cfg = db.cfg
        limiter = db.rate_limiter
        file_no = db.versions.new_file_no()
        writer = SSTableWriter(
            table_path(db.path, file_no), cfg.block_size, cfg.compression,
            cfg.sstable_format_version, cfg.block_restart_interval,
            env=db.env,
        )
        n_written = 0
        try:
            pending_io = 0
            for key, seq, type_, value in mem.sorted_items():
                if (
                    cfg.separation_mode == "flush"
                    and type_ == kTypeValue
                    and len(value) >= cfg.value_threshold
                ):
                    # BlobDB/WiscKey: separate at flush — value goes to the value
                    # log now; only the pointer reaches L0. Under the unified
                    # budget the BValue dispatch charges the value's bytes
                    # itself, so the flush only accounts the pointer entry here.
                    voff = db.bvalue.put(key, value, sync=cfg.sync_flush_io)
                    enc = voff.encode()
                    writer.add(key, seq, kTypeValuePtr, enc)
                    pending_io += len(key) + (
                        len(enc) if db.bvalue.limiter is not None else len(value)
                    )
                else:
                    writer.add(key, seq, type_, value)
                    pending_io += len(key) + len(value)
                n_written += 1
                if pending_io >= IO_CHUNK:
                    limiter.request(pending_io, PRI_HIGH)
                    pending_io = 0
            limiter.request(pending_io, PRI_HIGH)
            tombs = mem.range_tombstones
            if tombs and cfg.range_tombstone_coalesce:
                tombs = _coalesce_tombstones(tombs)
            if n_written == 0 and not tombs:
                writer.abandon()
                return
            meta = writer.finish(file_no, tombs)
        except BaseException:
            # remove the partial output so a retry of this flush (transient
            # error policy) starts from a clean slate with a fresh file_no
            try:
                writer.abandon()
            except OSError:
                pass
            raise
        db.stats.add("flush_bytes", meta.size)
        db.stats.add("flush_count")
        # value-durability barrier: under a buffered WAL the memtable's
        # ValueOffset entries may point at values still sitting in the
        # BValue queue buffers — the manifest commit below makes those
        # pointers durable, so their values must be durable FIRST, or a
        # crash leaves a live table full of dangling pointers.
        db.bvalue.flush()
        db.versions.log_and_apply(
            {
                "add": [(0, meta.to_wire())],
                "last_seq": mem.last_seq,
                "bvalue_next_file_id": db.bvalue.next_file_id,
            }
        )
        # this memtable's WAL — and, for a memtable rebuilt by recovery, the
        # replayed logs it carried — are now redundant: the data is durable
        # in the L0 table the manifest just committed. Delete them only now;
        # deleting earlier would widen the crash window. (With followers
        # attached, _release_wal retains segments a lagging replica still
        # needs for catch-up instead of unlinking.)
        logs = list(getattr(mem, "recovery_logs", None) or ())
        if getattr(mem, "wal_no", None) is not None:
            logs.append(db._wal_path(mem.wal_no))
        for log_path in logs:
            db._release_wal(log_path, mem.last_seq)

    # ------------------------------------------------------------------
    # compaction picking
    # ------------------------------------------------------------------
    def pick(self, locked=frozenset()):
        """Returns (level, [input files Ln], [input files Ln+1]) or None,
        never selecting a file whose compaction lock is held (``locked``).

        ``compaction_pick_policy="fullness"``: levels are tried in
        descending fullness order and the first pickable one wins (the
        legacy, write-amp-blind policy). ``"overlap"``: every
        over-trigger level nominates its cheapest job and the winner is
        the one clearing the most urgency per byte rewritten — fullness
        divided by the job's write amplification (1 + overlap/input)."""
        db = self.db
        cfg = db.cfg
        v = db.versions.current
        scored: list[tuple[float, int]] = []
        score0 = len(v.levels[0]) / cfg.l0_compaction_trigger
        if score0 >= 1.0:
            scored.append((score0, 0))
        for level in range(1, cfg.num_levels - 1):
            score = v.level_bytes(level) / cfg.level_max_bytes(level)
            if score > 1.0:
                scored.append((score, level))
        scored.sort(reverse=True)
        if cfg.compaction_pick_policy != "overlap":
            for _score, level in scored:
                picked = self._pick_level(v, level, locked)
                if picked is not None:
                    return picked
            return None
        best = None
        best_score = 0.0
        for fullness, level in scored:
            picked = self._pick_level(v, level, locked)
            if picked is None:
                continue
            _lvl, inputs, overlaps = picked
            in_bytes = max(1, sum(f.size for f in inputs))
            ov_bytes = sum(f.size for f in overlaps)
            score = fullness / (1.0 + ov_bytes / in_bytes)
            if best is None or score > best_score:
                best, best_score = picked, score
        if best is not None and best[0] >= 1:
            # advance the legacy rotation pointer for the WINNING level
            # only (evaluated-but-skipped candidates never ran), so
            # flipping back to "fullness" resumes from a sane position
            db.versions.compaction_ptr[best[0]] = best[1][0].smallest
        return best

    def _pick_level(self, v, level: int, locked):
        db = self.db
        cfg = db.cfg
        if level == 0:
            inputs = list(v.levels[0])
            if not inputs or any(f.file_no in locked for f in inputs):
                # L0 files overlap arbitrarily — at most one L0 job at a time
                return None
            smallest = min(f.smallest for f in inputs)
            largest = max(f.largest for f in inputs)
            overlaps = v.files_touching(1, smallest, largest)
            if any(f.file_no in locked for f in overlaps):
                return None
            return 0, inputs, overlaps
        files = v.levels[level]
        if not files:
            return None
        if cfg.compaction_pick_policy == "overlap":
            return self._pick_file_overlap(v, level, files, locked)
        # round-robin pointer within the level (LevelDB style), skipping
        # files locked by running jobs. The full Ln+1 overlap set always
        # rides along: truncating it (as the pre-scheduler code did) left
        # the merged output overlapping the dropped files, breaking the
        # sorted-level disjointness that point lookups binary-search on.
        # max_compaction_input_bytes instead steers the *choice*: prefer a
        # file whose job fits the cap, falling back to the smallest
        # oversized one so progress is still guaranteed.
        ptr = db.versions.compaction_ptr.get(level, b"")
        start = next((i for i, f in enumerate(files) if f.smallest > ptr), 0)
        fallback = None  # (total, pick_file, overlaps) of the smallest oversized job
        for off in range(len(files)):
            pick_file = files[(start + off) % len(files)]
            if pick_file.file_no in locked:
                continue
            overlaps = v.files_touching(level + 1, pick_file.smallest, pick_file.largest)
            if any(f.file_no in locked for f in overlaps):
                continue
            total = pick_file.size + sum(f.size for f in overlaps)
            if total > cfg.max_compaction_input_bytes:
                if fallback is None or total < fallback[0]:
                    fallback = (total, pick_file, overlaps)
                continue
            db.versions.compaction_ptr[level] = pick_file.smallest
            return level, [pick_file], overlaps
        if fallback is not None:
            _total, pick_file, overlaps = fallback
            db.versions.compaction_ptr[level] = pick_file.smallest
            return level, [pick_file], overlaps
        return None

    def _pick_file_overlap(self, v, level: int, files, locked):
        """Within-level choice under overlap scoring: the unlocked file
        whose job rewrites the fewest target-level bytes per input byte
        (tie-broken by smaller total job size). The full overlap set
        always rides along — ``max_compaction_input_bytes`` steers the
        choice among cap-fitting jobs; if none fits, the smallest
        oversized job runs so progress is still guaranteed."""
        db = self.db
        cfg = db.cfg
        best = None  # (ratio, total, pick_file, overlaps), job fits the cap
        fallback = None  # (total, pick_file, overlaps), smallest oversized
        for pick_file in files:
            if pick_file.file_no in locked:
                continue
            overlaps = v.files_touching(level + 1, pick_file.smallest, pick_file.largest)
            if any(f.file_no in locked for f in overlaps):
                continue
            ov_bytes = sum(f.size for f in overlaps)
            total = pick_file.size + ov_bytes
            if total > cfg.max_compaction_input_bytes:
                if fallback is None or total < fallback[0]:
                    fallback = (total, pick_file, overlaps)
                continue
            ratio = ov_bytes / max(1, pick_file.size)
            if best is None or (ratio, total) < (best[0], best[1]):
                best = (ratio, total, pick_file, overlaps)
                if ov_bytes == 0:
                    break  # zero overlap is the minimum — a trivial-move
                    # candidate; no later file can score better on ratio
        if best is not None:
            _ratio, _total, pick_file, overlaps = best
        elif fallback is not None:
            _total, pick_file, overlaps = fallback
        else:
            return None
        return level, [pick_file], overlaps

    # ------------------------------------------------------------------
    # compaction run
    # ------------------------------------------------------------------
    def run(self, level: int, inputs, overlaps, subtasks=None) -> None:
        """Merge ``inputs`` (Ln) + ``overlaps`` (Ln+1) into new Ln+1 tables
        and commit the swap as one atomic manifest edit — unless the job
        qualifies as a **trivial move** (single input, zero target-level
        overlap), which promotes the file by manifest edit alone.

        ``subtasks`` (callable: list of thunks → list of results) fans the
        key-range shards out across the scheduler's subcompaction pool;
        None runs them sequentially (same result, one thread)."""
        db = self.db
        cfg = db.cfg
        if self._maybe_trivial_move(level, inputs, overlaps):
            return
        out_level = level + 1
        v = db.versions.current
        bottom = all(not v.levels[lvl] for lvl in range(out_level + 1, cfg.num_levels))
        fill = not cfg.block_cache_compaction_bypass
        read_bytes = sum(f.size for f in inputs + overlaps)
        # snapshot-aware dedup: sample the live snapshot seqs ONCE per job.
        # Any snapshot taken after this point holds a seq >= every sequence
        # in these (already flushed) inputs, so it reads each key's newest
        # input version — which the stripe dedup below always keeps.
        snaps = sorted(db.snapshot_seqs())

        bounds = self._subcompaction_bounds(
            inputs, overlaps, self._choose_shards(read_bytes)
        )
        ranges = list(zip([None] + bounds, bounds + [None]))

        def shard_thunk(lo, hi):
            def go():
                t0 = time.monotonic()
                try:
                    metas = self._run_range(
                        level, inputs, overlaps, lo, hi, bottom, fill, snaps
                    )
                    return metas, None, time.monotonic() - t0
                except BaseException as e:
                    return [], e, time.monotonic() - t0

            return go

        thunks = [shard_thunk(lo, hi) for lo, hi in ranges]
        if len(thunks) == 1 or subtasks is None:
            results = [t() for t in thunks]
        else:
            results = subtasks(thunks)
            db.stats.add("subcompactions", len(thunks))
        metas = []
        err: BaseException | None = None
        shard_seconds = 0.0
        for shard_metas, shard_err, shard_s in results:
            metas.extend(shard_metas)
            shard_seconds += shard_s
            if shard_err is not None and err is None:
                err = shard_err
        if err is None:
            self._note_shard_rate(read_bytes, shard_seconds)
        if err is not None:
            # no manifest edit happened: drop every shard's output so the
            # live process never leaks tables (reopen would sweep them too)
            for m in metas:
                try:
                    db.env.unlink(table_path(db.path, m.file_no))
                except OSError:
                    pass
            raise err
        metas.sort(key=lambda m: m.smallest)

        written = sum(m.size for m in metas)
        db.stats.add("compaction_bytes", written)
        db.stats.add("compaction_read_bytes", read_bytes)
        db.stats.add("compaction_count")
        edit = {
            "add": [(out_level, m.to_wire()) for m in metas],
            "delete": [(level, f.file_no) for f in inputs]
            + [(out_level, f.file_no) for f in overlaps],
        }
        db.versions.log_and_apply(edit)
        for f in inputs + overlaps:
            db.versions.drop_reader(f.file_no)
            try:
                # an open cursor pins the pre-edit version: its input files
                # stay on disk (and their readers parked) until it unpins
                db.versions.defer_or_unlink(table_path(db.path, f.file_no))
            except OSError:
                pass

    def _maybe_trivial_move(self, level: int, inputs, overlaps) -> bool:
        """Promote a no-overlap single file to the next level by manifest
        edit alone — zero bytes read, zero bytes written, no new tables.

        Eligibility: exactly one input, an empty target-level overlap set,
        and (when ``trivial_move_max_gp_bytes`` > 0) bounded grandparent
        overlap — parking a file on top of a wide grandparent range only
        converts this job's savings into a more expensive future job one
        level down. Safe under concurrency: the input is compaction-locked
        and every running job's output span is interval-closed over files
        that were live (and not overlapping this range) at its own pick
        time, so no concurrent commit can slide a target-level file under
        the move (see the module docstring)."""
        db = self.db
        cfg = db.cfg
        out_level = level + 1
        if (
            not cfg.trivial_move
            or overlaps
            or len(inputs) != 1
            or out_level >= cfg.num_levels
        ):
            return False
        f = inputs[0]
        if cfg.trivial_move_max_gp_bytes > 0 and out_level + 1 < cfg.num_levels:
            v = db.versions.current
            gp = v.overlap_bytes(out_level + 1, f.smallest, f.largest)
            if gp > cfg.trivial_move_max_gp_bytes:
                return False
        db.versions.log_and_apply(
            {
                "add": [(out_level, f.to_wire())],
                "delete": [(level, f.file_no)],
            }
        )
        db.stats.add("trivial_moves")
        db.stats.add("trivial_move_bytes", f.size)
        return True

    def _choose_shards(self, input_bytes: int) -> int:
        """Adaptive subcompaction fan-out: shard count follows the live
        input size over a per-shard byte target — the historical per-shard
        merge throughput (EWMA) times ``subcompaction_target_seconds``,
        floored at ``subcompaction_min_bytes`` (also the cold-start
        target). Tiny inputs degrade to 1 (no fan-out overhead); the
        result never exceeds ``max_subcompactions``."""
        cfg = self.db.cfg
        if cfg.max_subcompactions <= 1:
            return 1
        if not cfg.subcompaction_adaptive:
            return cfg.max_subcompactions
        target = max(1, cfg.subcompaction_min_bytes)
        if self._shard_bytes_per_s > 0.0:
            target = max(
                target, int(self._shard_bytes_per_s * cfg.subcompaction_target_seconds)
            )
        self.db.stats.set_gauge("subcompaction_target_bytes", target)
        return int(min(cfg.max_subcompactions, max(1, input_bytes // target)))

    def _note_shard_rate(self, input_bytes: int, shard_seconds: float) -> None:
        """Fold one completed compaction into the per-shard throughput
        EWMA (input bytes per cumulative shard-second)."""
        if shard_seconds <= 1e-6 or input_bytes <= 0:
            return
        rate = input_bytes / shard_seconds
        self._shard_bytes_per_s = (
            rate
            if self._shard_bytes_per_s == 0.0
            else 0.7 * self._shard_bytes_per_s + 0.3 * rate
        )
        self.db.stats.set_gauge("subcompaction_bytes_per_s", self._shard_bytes_per_s)

    def _subcompaction_bounds(self, inputs, overlaps, max_shards: int) -> list[bytes]:
        """Choose up to ``max_shards - 1`` split keys from the input files'
        natural boundaries, weighted by file size so shards carry roughly
        equal bytes. When file boundaries alone can't split the range —
        the common L0→L1 case where every L0 file spans the whole key
        window — fall back to sampling block boundaries from the largest
        input's index. Returns an ascending list of keys; shard i covers
        ``[bounds[i-1], bounds[i])`` (half-open, first/last unbounded)."""
        if max_shards <= 1:
            return []
        points = sorted((f.smallest, f.size) for f in inputs + overlaps)
        total = sum(sz for _, sz in points)
        if len(points) < 2 or total <= 0:
            return []
        bounds: list[bytes] = []
        acc = 0
        target = total / min(max_shards, len(points))
        for key, sz in points:
            if acc >= target * (len(bounds) + 1) and (not bounds or key > bounds[-1]):
                bounds.append(key)
                if len(bounds) >= max_shards - 1:
                    break
            acc += sz
        if len(bounds) < max_shards - 1:
            bounds = self._augment_bounds_from_index(
                inputs + overlaps, bounds, max_shards
            )
        return bounds

    def _augment_bounds_from_index(self, files, bounds: list[bytes], max_shards: int):
        """Merge index-block boundary keys of the largest input into the
        split set and re-pick evenly — overlapping inputs then still shard
        into balanced ranges. Best-effort: any failure (reader gone, empty
        index) keeps the file-boundary bounds."""
        try:
            big = max(files, key=lambda f: f.size)
            index = self.db.versions.reader(big.file_no).index
            if len(index) < 2:
                return bounds
            lo, hi = min(f.smallest for f in files), max(f.largest for f in files)
            cand = sorted(
                {k for k, _off, _len in index[:-1] if lo < k <= hi} | set(bounds)
            )
            if not cand:
                return bounds
            n = min(max_shards - 1, len(cand))
            step = len(cand) / (n + 1)
            picked = sorted({cand[min(len(cand) - 1, int(step * (i + 1)))] for i in range(n)})
            return picked
        except Exception:
            return bounds

    def _run_range(self, level, inputs, overlaps, lo, hi, bottom, fill, snaps=()):
        """One subcompaction shard: merge keys in ``[lo, hi)`` (None =
        unbounded) into fresh Ln+1 tables; returns their FileMetadata.
        Shards touch disjoint key ranges, so per-shard version dedup and
        dead-pointer tracking are exactly as correct as the serial merge.

        ``snaps`` is the sorted live snapshot seq list sampled at job start.
        It partitions sequence space into *stripes* (RocksDB-style): a
        version is droppable only against a newer version/tombstone in the
        SAME stripe — no snapshot can observe the difference. With no
        snapshots everything is one stripe and the dedup degenerates to the
        classic newest-version-wins.

        Range tombstones from the input files are clipped to the shard,
        drop covered same-stripe entries, and are redistributed to the
        output tables clipped at each table's first key — so a sorted
        level's (tombstone-extended) file ranges stay disjoint-or-touching
        and a point lookup finds any covering tombstone in the same
        candidate file(s) it already reads."""
        db = self.db
        cfg = db.cfg
        limiter = db.rate_limiter
        # meter the merge's block READS against the unified budget at LOW
        # priority, charged at pread time (cache hits never pay). Batched
        # into IO_CHUNK lumps like the write side so the token bucket's
        # lock isn't taken once per 4 KiB block.
        meter = None
        pending_read = 0
        if cfg.compaction_read_metering and limiter.enabled:

            def meter(nbytes: int) -> None:
                nonlocal pending_read
                pending_read += nbytes
                if pending_read >= IO_CHUNK:
                    limiter.request(pending_read, PRI_LOW)
                    db.stats.add("compaction_read_metered_bytes", pending_read)
                    pending_read = 0

        iters = []
        shard_tombs: list[tuple[int, bytes, bytes]] = []
        for f in inputs + overlaps:
            if lo is not None and f.largest < lo:
                continue
            if hi is not None and f.smallest >= hi:
                continue
            r = db.versions.reader(f.file_no)
            for ts, a, b in r.range_tombstones:
                a2 = a if lo is None else max(a, lo)
                b2 = b if hi is None else min(b, hi)
                if a2 < b2:
                    shard_tombs.append((ts, a2, b2))
            iters.append(
                r.iter_from(lo, fill_cache=fill, meter=meter)
                if lo is not None
                else r.iter_all(fill_cache=fill, meter=meter)
            )

        def bucket(seq):
            return bisect_left(snaps, seq)  # snapshots strictly below seq

        def covering(key, seq):
            """OLDEST collected tombstone newer than ``seq`` covering ``key``
            (0 if none). The minimal such ts is the one to test for
            droppability: ``bucket`` is monotone in ts, so the entry shares a
            stripe with SOME covering tombstone iff it shares one with the
            oldest — using the max instead would let a newer cross-stripe
            tombstone mask an in-stripe one, keeping the entry while the
            in-stripe tombstone gets dropped at the bottom (resurrection).
            Tombstone lists are small; linear is fine."""
            best = 0
            for ts, a, b in shard_tombs:
                if ts > seq and a <= key < b and (best == 0 or ts < best):
                    best = ts
            return best

        # a bottom-level tombstone with no snapshot below it has done its
        # work (every covered entry is droppable, below) — drop it from the
        # output; it still participates in `covering` either way
        out_tombs = [
            t for t in shard_tombs if not (bottom and bucket(t[0]) == 0)
        ]
        if out_tombs and cfg.range_tombstone_coalesce:
            out_tombs = _coalesce_tombstones(out_tombs)
        pending = sorted(out_tombs, key=lambda t: (t[1], t[2]))

        target = max(cfg.memtable_size, 4 << 20)
        writer = None
        file_no = None
        metas = []

        def roll(boundary):
            """Finish the current table. ``boundary`` (the next table's
            first key, or None at shard end) splits the surviving range
            tombstones: fragments below it land in this table, the rest
            carry over — every table's range block stays inside its own
            key span."""
            nonlocal writer, file_no, pending
            if boundary is None:
                mine, pending = pending, []
            else:
                mine, rest = [], []
                for ts, a, b in pending:
                    if a < boundary:
                        mine.append((ts, a, min(b, boundary)))
                        if b > boundary:
                            rest.append((ts, boundary, b))
                    else:
                        rest.append((ts, a, b))
                pending = rest
            if writer is None and mine:
                # tombstone-only table: a shard can drop every point entry
                # yet still owe its tombstones to deeper levels
                file_no = db.versions.new_file_no()
                writer = SSTableWriter(
                    table_path(db.path, file_no), cfg.block_size, cfg.compression,
                    cfg.sstable_format_version, cfg.block_restart_interval,
                    env=db.env,
                )
            if writer is not None and (writer._count > 0 or mine):
                metas.append(writer.finish(file_no, mine))
                writer = None
            elif writer is not None:
                writer.abandon()
                writer = None

        last_key = None
        last_bucket = None  # stripe of the last kept/suppressing version
        pending_io = 0
        try:
            for key, seq, type_, value in _merge_iters(iters):
                if hi is not None and key >= hi:
                    break  # the next shard owns [hi, ...)
                new_key = key != last_key
                if new_key:
                    last_key = key
                    last_bucket = None
                elif last_bucket is not None and bucket(seq) == last_bucket:
                    if type_ == kTypeValuePtr:  # shadowed big value → dead
                        db.dead_tracker.on_dead(ValueOffset.decode(value))
                    continue  # older version in an already-served stripe
                b = bucket(seq)
                ts = covering(key, seq)
                if ts and bucket(ts) == b:
                    # range-tombstone covered with no snapshot in between;
                    # safe at ANY level — the tombstone itself survives in
                    # the output until it reaches the bottom
                    if type_ == kTypeValuePtr:
                        db.dead_tracker.on_dead(ValueOffset.decode(value))
                    last_bucket = b  # same-stripe older versions drop too
                    continue
                if type_ == kTypeDeletion and bottom and b == 0:
                    last_bucket = b
                    continue  # tombstone reached the bottom, no snapshot below
                if new_key and writer is not None and writer._offset >= target:
                    # roll only between user keys: a key's version run never
                    # splits across tables, and the incoming key becomes the
                    # next table's first key = this table's tombstone clip
                    roll(key)
                if writer is None:
                    file_no = db.versions.new_file_no()
                    writer = SSTableWriter(
                        table_path(db.path, file_no), cfg.block_size, cfg.compression,
                        cfg.sstable_format_version, cfg.block_restart_interval,
                        env=db.env,
                    )
                writer.add(key, seq, type_, value)
                last_bucket = b
                pending_io += len(key) + len(value)
                if pending_io >= IO_CHUNK:
                    limiter.request(pending_io, PRI_LOW)
                    pending_io = 0
            roll(None)
        except BaseException:
            # a failed shard must not leak its outputs: abandon the
            # in-progress writer (closes + unlinks) and drop the tables it
            # already rolled — run() only cleans up *returned* metas
            if writer is not None:
                try:
                    writer.abandon()
                except OSError:
                    pass
            for m in metas:
                try:
                    db.env.unlink(table_path(db.path, m.file_no))
                except OSError:
                    pass
            raise
        limiter.request(pending_io, PRI_LOW)
        if pending_read:
            limiter.request(pending_read, PRI_LOW)
            db.stats.add("compaction_read_metered_bytes", pending_read)
        return metas
