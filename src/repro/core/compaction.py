"""Flush + leveled compaction, run by a background worker thread.

The write-amplification mechanics the paper targets live here: with
``separation_mode="none"`` every compaction rewrites full values across
levels; with ``"flush"`` (BlobDB) values leave the pipeline at flush time;
with ``"wal"`` (BVLSM) they never enter it. All three modes share this exact
code — the benchmark deltas isolate the separation stage.

Stall behaviour mirrors RocksDB: L0 at ``slowdown_trigger`` delays writers,
at ``stop_trigger`` blocks them — the source of the I/O jitter in the
paper's Fig. 2/9.
"""
from __future__ import annotations

import heapq
import threading
import traceback

from .record import ValueOffset, kTypeDeletion, kTypeValue, kTypeValuePtr
from .sstable import SSTableWriter, table_path


def _merge_iters(iters):
    """Heap-merge (key, seq, type, value) streams; newest version first per
    key; yields every version (caller dedups)."""
    heap = []
    for i, it in enumerate(iters):
        it = iter(it)
        for key, seq, type_, value in it:
            heapq.heappush(heap, (key, -seq, i, type_, value, it))
            break
    while heap:
        key, nseq, i, type_, value, it = heapq.heappop(heap)
        yield key, -nseq, type_, value
        for k2, s2, t2, v2 in it:
            heapq.heappush(heap, (k2, -s2, i, t2, v2, it))
            break


class Compactor:
    def __init__(self, db):
        self.db = db  # back-reference; uses db.versions, db.cfg, db.stats

    # ------------------------------------------------------------------
    # flush
    # ------------------------------------------------------------------
    def flush_memtable(self, mem) -> None:
        db = self.db
        cfg = db.cfg
        file_no = db.versions.new_file_no()
        writer = SSTableWriter(
            table_path(db.path, file_no), cfg.block_size, cfg.compression,
            cfg.sstable_format_version, cfg.block_restart_interval,
        )
        n_written = 0
        for key, seq, type_, value in mem.sorted_items():
            if (
                cfg.separation_mode == "flush"
                and type_ == kTypeValue
                and len(value) >= cfg.value_threshold
            ):
                # BlobDB/WiscKey: separate at flush — value goes to the value
                # log now; only the pointer reaches L0.
                voff = db.bvalue.put(key, value, sync=cfg.sync_flush_io)
                writer.add(key, seq, kTypeValuePtr, voff.encode())
            else:
                writer.add(key, seq, type_, value)
            n_written += 1
        if n_written == 0:
            writer.abandon()
            return
        meta = writer.finish(file_no)
        db.stats.add("flush_bytes", meta.size)
        db.stats.add("flush_count")
        db.versions.log_and_apply(
            {
                "add": [(0, meta.to_wire())],
                "last_seq": mem.last_seq,
                "bvalue_next_file_id": db.bvalue.next_file_id,
            }
        )
        # this memtable's WAL is now redundant — delete it
        if getattr(mem, "wal_no", None) is not None:
            try:
                import os

                os.unlink(db._wal_path(mem.wal_no))
            except OSError:
                pass

    # ------------------------------------------------------------------
    # compaction picking
    # ------------------------------------------------------------------
    def pick(self):
        """Returns (level, [input files Ln], [input files Ln+1]) or None."""
        db = self.db
        cfg = db.cfg
        v = db.versions.current
        # L0 score by file count; deeper levels by byte ratio.
        best_level, best_score = -1, 1.0
        score0 = len(v.levels[0]) / cfg.l0_compaction_trigger
        if score0 >= best_score:
            best_level, best_score = 0, score0
        for level in range(1, cfg.num_levels - 1):
            score = v.level_bytes(level) / cfg.level_max_bytes(level)
            if score > best_score:
                best_level, best_score = level, score
        if best_level < 0:
            return None
        level = best_level
        if level == 0:
            inputs = list(v.levels[0])
            if not inputs:
                return None
            smallest = min(f.smallest for f in inputs)
            largest = max(f.largest for f in inputs)
        else:
            # round-robin pointer within the level (LevelDB style)
            ptr = db.versions.compaction_ptr.get(level, b"")
            files = v.levels[level]
            pick_file = next((f for f in files if f.smallest > ptr), files[0])
            db.versions.compaction_ptr[level] = pick_file.smallest
            inputs = [pick_file]
            smallest, largest = pick_file.smallest, pick_file.largest
        overlaps = v.files_touching(level + 1, smallest, largest)
        total = sum(f.size for f in inputs) + sum(f.size for f in overlaps)
        if level > 0 and total > cfg.max_compaction_input_bytes and len(overlaps) > 1:
            overlaps = overlaps[: max(1, len(overlaps) // 2)]
        return level, inputs, overlaps

    # ------------------------------------------------------------------
    # compaction run
    # ------------------------------------------------------------------
    def run(self, level: int, inputs, overlaps) -> None:
        db = self.db
        cfg = db.cfg
        out_level = level + 1
        v = db.versions.current
        bottom = all(not v.levels[l] for l in range(out_level + 1, cfg.num_levels))
        # read through the shared block cache but (by default) never
        # populate it: a one-shot merge stream would evict the foreground
        # working set for blocks it touches exactly once.
        fill = not cfg.block_cache_compaction_bypass
        iters = [
            db.versions.reader(f.file_no).iter_all(fill_cache=fill)
            for f in inputs + overlaps
        ]
        read_bytes = sum(f.size for f in inputs + overlaps)

        target = max(cfg.memtable_size, 4 << 20)
        writer = None
        file_no = None
        metas = []

        def roll():
            nonlocal writer, file_no
            if writer is not None and writer._count > 0:
                metas.append(writer.finish(file_no))
                writer = None
            elif writer is not None:
                writer.abandon()
                writer = None

        last_key = None
        for key, seq, type_, value in _merge_iters(iters):
            if key == last_key:
                if type_ == kTypeValuePtr:  # shadowed big value → dead
                    db.dead_tracker.on_dead(ValueOffset.decode(value))
                continue  # older version shadowed (no snapshots)
            last_key = key
            if type_ == kTypeDeletion and bottom:
                continue  # tombstone reached the bottom — drop it
            if writer is None:
                file_no = db.versions.new_file_no()
                writer = SSTableWriter(
                    table_path(db.path, file_no), cfg.block_size, cfg.compression,
                    cfg.sstable_format_version, cfg.block_restart_interval,
                )
            writer.add(key, seq, type_, value)
            if writer._offset >= target:
                roll()
        roll()

        written = sum(m.size for m in metas)
        db.stats.add("compaction_bytes", written)
        db.stats.add("compaction_read_bytes", read_bytes)
        db.stats.add("compaction_count")
        edit = {
            "add": [(out_level, m.to_wire()) for m in metas],
            "delete": [(level, f.file_no) for f in inputs]
            + [(out_level, f.file_no) for f in overlaps],
        }
        db.versions.log_and_apply(edit)
        for f in inputs + overlaps:
            db.versions.drop_reader(f.file_no)
            try:
                import os

                os.unlink(table_path(db.path, f.file_no))
            except OSError:
                pass


class BackgroundWorker(threading.Thread):
    """Single background thread servicing flushes then compactions,
    mirroring a 1-thread RocksDB pool (container has 1 vCPU)."""

    def __init__(self, db):
        super().__init__(name="lsm-background", daemon=True)
        self.db = db
        self.cv = threading.Condition()
        self._stop_requested = False
        self.error: Exception | None = None
        self.compactor = Compactor(db)

    def signal(self) -> None:
        with self.cv:
            self.cv.notify()

    def stop(self) -> None:
        with self.cv:
            self._stop_requested = True
            self.cv.notify()
        self.join(timeout=60)

    def _work_available(self) -> bool:
        db = self.db
        if db.immutables:
            return True
        return self.compactor.pick() is not None

    def run(self) -> None:
        db = self.db
        try:
            while True:
                with self.cv:
                    while not self._stop_requested and not self._work_available():
                        self.cv.wait(timeout=0.2)
                    if self._stop_requested and not self._work_available():
                        return
                # 1) flushes take priority (unblock writers)
                mem = None
                with db.mutex:
                    if db.immutables:
                        mem = db.immutables[0]
                if mem is not None:
                    self.compactor.flush_memtable(mem)
                    with db.mutex:
                        # crash-close may have cleared the list under us
                        if db.immutables and db.immutables[0] is mem:
                            db.immutables.pop(0)
                        db.writer_cv.notify_all()
                    continue
                # 2) one compaction step
                picked = self.compactor.pick()
                if picked is not None:
                    self.compactor.run(*picked)
                    with db.mutex:
                        db.writer_cv.notify_all()
        except Exception as e:  # surface to foreground instead of dying silently
            self.error = e
            traceback.print_exc()
            with db.mutex:
                db.writer_cv.notify_all()
