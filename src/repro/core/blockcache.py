"""Shared LRU cache over decoded SSTable data blocks.

One cache instance is owned by the DB and handed to every
:class:`~repro.core.sstable.SSTableReader` through the
:class:`~repro.core.manifest.VersionSet`, so foreground gets, scans, and
compaction all read the same decoded blocks. Entries are keyed
``(file_no, block_idx)`` and charged by decoded payload bytes
(:attr:`Block.charge`) — the cache holds *decoded* blocks, so a hit skips
both the pread and the decompress/trailer parse.

Lock sharding: the key hash picks one of ``shards`` independent
(lock, OrderedDict) pairs, so concurrent readers on different blocks never
serialize on one mutex. Each shard gets ``capacity / shards`` bytes;
eviction is plain LRU within the shard.

Dropped files need no explicit invalidation: file numbers are never
reused (``VersionSet.next_file_no`` is monotonic), so a dead file's blocks
simply age out of the LRU order. ``evict_file`` exists to reclaim them
eagerly after compaction unlinks an input.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class _Shard:
    __slots__ = ("lock", "map", "bytes", "capacity", "hits", "misses", "evictions")

    def __init__(self, capacity: int):
        self.lock = threading.Lock()
        # value = [block, charged_bytes]: the charge is remembered at
        # insert/recharge time so accounting stays exact even though a
        # block's live charge grows when it materializes
        self.map: OrderedDict[tuple[int, int], list] = OrderedDict()
        self.bytes = 0
        self.capacity = capacity
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _evict_locked(self) -> None:
        while self.bytes > self.capacity and self.map:
            _, (_, charged) = self.map.popitem(last=False)
            self.bytes -= charged
            self.evictions += 1


class BlockCache:
    """Sharded LRU over decoded blocks; thread-safe; ``capacity_bytes <= 0``
    disables caching entirely (every ``get`` misses, ``put`` is a no-op)."""

    def __init__(self, capacity_bytes: int, shards: int = 8):
        self.capacity = max(0, capacity_bytes)
        n = max(1, shards)
        self._shards = [_Shard(self.capacity // n) for _ in range(n)]
        self._n = n

    def _shard(self, key: tuple[int, int]) -> _Shard:
        return self._shards[hash(key) % self._n]

    def get(self, key: tuple[int, int]):
        s = self._shard(key)
        with s.lock:
            ent = s.map.get(key)
            if ent is None:
                s.misses += 1
                return None
            s.map.move_to_end(key)
            s.hits += 1
            return ent[0]

    def peek(self, key: tuple[int, int]):
        """Read-through lookup for bypass streams (compaction): returns the
        cached block WITHOUT promoting it to MRU and without touching the
        hit/miss counters, so one-shot background sweeps neither reorder
        the foreground working set nor dilute the foreground hit rate."""
        s = self._shard(key)
        with s.lock:
            ent = s.map.get(key)
            return None if ent is None else ent[0]

    def put(self, key: tuple[int, int], block) -> None:
        if self.capacity <= 0:
            return
        # the block will re-charge itself here when it materializes its
        # parsed form (Block._materialize), keeping the byte budget honest
        block._cache = self
        block._cache_key = key
        charge = block.charge
        s = self._shard(key)
        with s.lock:
            old = s.map.pop(key, None)
            if old is not None:
                s.bytes -= old[1]
            s.map[key] = [block, charge]
            s.bytes += charge
            s._evict_locked()

    def recharge(self, key: tuple[int, int], block) -> None:
        """Re-account one resident block whose live ``charge`` grew (it
        materialized its parsed entries); evicts if now over budget.
        No-op if the block was evicted or replaced in the meantime."""
        s = self._shard(key)
        with s.lock:
            ent = s.map.get(key)
            if ent is None or ent[0] is not block:
                return
            new = block.charge
            s.bytes += new - ent[1]
            ent[1] = new
            s._evict_locked()

    def evict_file(self, file_no: int) -> None:
        """Drop every cached block of one (just-unlinked) table."""
        for s in self._shards:
            with s.lock:
                dead = [k for k in s.map if k[0] == file_no]
                for k in dead:
                    s.bytes -= s.map.pop(k)[1]

    @property
    def size_bytes(self) -> int:
        return sum(s.bytes for s in self._shards)

    def stats(self) -> dict:
        hits = sum(s.hits for s in self._shards)
        misses = sum(s.misses for s in self._shards)
        total = hits + misses
        return {
            "block_cache_hits": hits,
            "block_cache_misses": misses,
            "block_cache_evictions": sum(s.evictions for s in self._shards),
            "block_cache_bytes": self.size_bytes,
            "block_cache_entries": sum(len(s.map) for s in self._shards),
            "block_cache_hit_rate": hits / total if total else 0.0,
        }
