"""Shared cache over decoded SSTable data blocks (2Q or plain LRU).

One cache instance is owned by the DB and handed to every
:class:`~repro.core.sstable.SSTableReader` through the
:class:`~repro.core.manifest.VersionSet`, so foreground gets, scans, and
compaction all read the same decoded blocks. Entries are keyed
``(file_no, block_idx)`` and charged by decoded payload bytes
(:attr:`Block.charge`) — the cache holds *decoded* blocks, so a hit skips
both the pread and the decompress/trailer parse.

Admission (``policy="2q"``, the default): a first-touch block enters a
probationary FIFO (**A1in**). It is promoted to the main LRU (**Am**) only
when it proves reuse — a second reference while still probationary, or
readmission while its key is remembered by the **A1out** ghost list (keys
of recently evicted probationary blocks, held at zero byte cost). One-shot
sequential sweeps (cursor scans, non-bypass compaction reads) therefore
churn only the A1in fraction of the budget and can never flush the
point-get working set out of Am. Eviction takes the A1in FIFO head while
A1in exceeds its fraction of the shard budget (its key moving to the
ghost), otherwise the Am LRU tail. ``policy="lru"`` restores the plain
LRU of PR 3 (everything lives in Am).

Lock sharding: the key hash picks one of ``shards`` independent shards, so
concurrent readers on different blocks never serialize on one mutex. Each
shard gets ``capacity / shards`` bytes.

Dropped files need no explicit invalidation: file numbers are never
reused (``VersionSet.next_file_no`` is monotonic), so a dead file's blocks
simply age out. ``evict_file`` exists to reclaim them eagerly after
compaction unlinks an input.

Accounting invariant: ``size_bytes`` is the sum of the remembered
per-entry charges, adjusted only under the owning shard's lock. A
``recharge`` (a resident block grew by materializing its parsed form)
re-checks, lock-held, that the SAME block object is still resident — a
block evicted or replaced by a concurrent ``evict_file``/``put`` must not
have its delta applied, or the shard's byte count would drift permanently.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class _Shard:
    __slots__ = (
        "lock", "am", "a1in", "ghost", "bytes", "a1_bytes", "capacity",
        "a1_capacity", "ghost_cap", "two_q", "hits", "misses", "evictions",
        "promotions", "ghost_hits",
    )

    def __init__(self, capacity: int, two_q: bool, a1_fraction: float):
        self.lock = threading.Lock()
        # value = [block, charged_bytes]: the charge is remembered at
        # insert/recharge time so accounting stays exact even though a
        # block's live charge grows when it materializes
        self.am: OrderedDict[tuple[int, int], list] = OrderedDict()
        self.a1in: OrderedDict[tuple[int, int], list] = OrderedDict()
        # ghost: key-only memory of recently evicted probationary blocks
        # (value unused); ~one slot per 8 KiB of budget. Kept proportional
        # to the shard's capacity measured in blocks: an oversized A1out
        # would remember an entire repeated sweep, readmitting every swept
        # block straight to Am and silently degrading 2Q back to LRU.
        self.ghost: OrderedDict[tuple[int, int], None] = OrderedDict()
        self.bytes = 0
        self.a1_bytes = 0
        self.capacity = capacity
        self.a1_capacity = int(capacity * a1_fraction)
        self.ghost_cap = max(16, capacity // 8192)
        self.two_q = two_q
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.promotions = 0
        self.ghost_hits = 0

    def _evict_locked(self) -> None:
        while self.bytes > self.capacity and (self.am or self.a1in):
            if self.a1in and (not self.am or self.a1_bytes > self.a1_capacity):
                key, (_, charged) = self.a1in.popitem(last=False)
                self.a1_bytes -= charged
                # remember the key so a prompt re-read earns Am directly
                self.ghost[key] = None
                if len(self.ghost) > self.ghost_cap:
                    self.ghost.popitem(last=False)
            else:
                _, (_, charged) = self.am.popitem(last=False)
            self.bytes -= charged
            self.evictions += 1


class BlockCache:
    """Sharded 2Q/LRU over decoded blocks; thread-safe; ``capacity_bytes
    <= 0`` disables caching entirely (every ``get`` misses, ``put`` is a
    no-op)."""

    def __init__(
        self,
        capacity_bytes: int,
        shards: int = 8,
        policy: str = "2q",
        a1_fraction: float = 0.25,
    ):
        if policy not in ("2q", "lru"):
            raise ValueError(f"unknown block cache policy {policy!r}")
        self.capacity = max(0, capacity_bytes)
        self.policy = policy
        n = max(1, shards)
        two_q = policy == "2q"
        self._shards = [
            _Shard(self.capacity // n, two_q, a1_fraction) for _ in range(n)
        ]
        self._n = n

    def _shard(self, key: tuple[int, int]) -> _Shard:
        return self._shards[hash(key) % self._n]

    def get(self, key: tuple[int, int]):
        s = self._shard(key)
        with s.lock:
            ent = s.am.get(key)
            if ent is not None:
                s.am.move_to_end(key)
                s.hits += 1
                return ent[0]
            ent = s.a1in.get(key)
            if ent is not None:
                # re-referenced while probationary → it has proven reuse;
                # promote to the protected main queue
                del s.a1in[key]
                s.a1_bytes -= ent[1]
                s.am[key] = ent
                s.promotions += 1
                s.hits += 1
                return ent[0]
            s.misses += 1
            return None

    def peek(self, key: tuple[int, int]):
        """Read-through lookup for bypass streams (compaction): returns the
        cached block WITHOUT promoting it (no A1in→Am, no MRU move) and
        without touching the hit/miss counters, so one-shot background
        sweeps neither reorder the foreground working set nor dilute the
        foreground hit rate."""
        s = self._shard(key)
        with s.lock:
            ent = s.am.get(key)
            if ent is None:
                ent = s.a1in.get(key)
            return None if ent is None else ent[0]

    def put(self, key: tuple[int, int], block) -> None:
        if self.capacity <= 0:
            return
        # the block will re-charge itself here when it materializes its
        # parsed form (Block._materialize), keeping the byte budget honest
        block._cache = self
        block._cache_key = key
        charge = block.charge
        s = self._shard(key)
        with s.lock:
            old = s.am.pop(key, None)
            if old is not None:
                s.bytes -= old[1]
            old = s.a1in.pop(key, None)
            if old is not None:
                s.bytes -= old[1]
                s.a1_bytes -= old[1]
            ent = [block, charge]
            if not s.two_q:
                s.am[key] = ent
            elif key in s.ghost:
                # evicted from probation recently and read again — that IS
                # the re-reference; admit straight to Am
                del s.ghost[key]
                s.ghost_hits += 1
                s.promotions += 1
                s.am[key] = ent
            else:
                s.a1in[key] = ent
                s.a1_bytes += charge
            s.bytes += charge
            s._evict_locked()

    def recharge(self, key: tuple[int, int], block) -> None:
        """Re-account one resident block whose live ``charge`` grew (it
        materialized its parsed entries); evicts if now over budget.
        No-op if the block was evicted or replaced in the meantime — the
        lock-held identity check below is what keeps a recharge racing an
        ``evict_file`` from permanently inflating ``size_bytes``."""
        s = self._shard(key)
        with s.lock:
            in_a1 = False
            ent = s.am.get(key)
            if ent is None:
                ent = s.a1in.get(key)
                in_a1 = ent is not None
            if ent is None or ent[0] is not block:
                return
            delta = block.charge - ent[1]
            s.bytes += delta
            if in_a1:
                s.a1_bytes += delta
            ent[1] = block.charge
            s._evict_locked()

    def evict_file(self, file_no: int) -> None:
        """Drop every cached block of one (just-unlinked) table."""
        for s in self._shards:
            with s.lock:
                for k in [k for k in s.am if k[0] == file_no]:
                    s.bytes -= s.am.pop(k)[1]
                for k in [k for k in s.a1in if k[0] == file_no]:
                    charged = s.a1in.pop(k)[1]
                    s.bytes -= charged
                    s.a1_bytes -= charged
                for k in [k for k in s.ghost if k[0] == file_no]:
                    del s.ghost[k]

    @property
    def size_bytes(self) -> int:
        return sum(s.bytes for s in self._shards)

    def stats(self) -> dict:
        hits = sum(s.hits for s in self._shards)
        misses = sum(s.misses for s in self._shards)
        total = hits + misses
        return {
            "block_cache_hits": hits,
            "block_cache_misses": misses,
            "block_cache_evictions": sum(s.evictions for s in self._shards),
            "block_cache_bytes": self.size_bytes,
            "block_cache_entries": sum(
                len(s.am) + len(s.a1in) for s in self._shards
            ),
            "block_cache_hit_rate": hits / total if total else 0.0,
            "block_cache_promotions": sum(s.promotions for s in self._shards),
            "block_cache_ghost_hits": sum(s.ghost_hits for s in self._shards),
            "block_cache_a1_bytes": sum(s.a1_bytes for s in self._shards),
        }
