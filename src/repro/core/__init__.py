"""BVLSM core — the paper's contribution: an LSM-tree KV store with WAL-time
key-value separation, multi-queue BValue store, and BVCache.

``DBConfig.separation_mode`` selects the three systems the paper compares:
``"none"`` (RocksDB baseline), ``"flush"`` (BlobDB/WiscKey), ``"wal"``
(BVLSM).
"""
from .config import DBConfig
from .db import DB
from .record import ValueOffset
from .writebatch import WriteBatch

__all__ = ["DB", "DBConfig", "ValueOffset", "WriteBatch"]
