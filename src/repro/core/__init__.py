"""BVLSM core — the paper's contribution: an LSM-tree KV store with WAL-time
key-value separation, multi-queue BValue store, and BVCache.

``DBConfig.separation_mode`` selects the three systems the paper compares:
``"none"`` (RocksDB baseline), ``"flush"`` (BlobDB/WiscKey), ``"wal"``
(BVLSM).

Failure handling (see :mod:`.errors` / :mod:`.env`): every filesystem call
routes through a pluggable ``Env`` (``DBConfig.env``), background errors are
severity-classified (transient → bounded retry, hard → read-only mode until
``DB.resume()``, corruption → file quarantine), and ``FaultInjectionEnv``
drives the crash/fault test matrix.
"""
from .api import KVStore
from .config import DBConfig
from .db import DB, Cursor, Snapshot
from .env import DEFAULT_ENV, Env, FaultInjectionEnv, FaultRule
from .errors import (
    BackgroundError,
    CorruptionError,
    DBError,
    DBReadOnlyError,
    ReplicaDivergedError,
    SimulatedCrashError,
    SnapshotUnstableError,
)
from .record import ValueOffset
from .replication import (
    InProcessTransport,
    ReplicationLink,
    attach,
    bootstrap_replica,
)
from .sharded import (
    HashPartitioner,
    MergedCursor,
    RangePartitioner,
    ShardedDB,
    ShardedSnapshot,
)
from .writebatch import WriteBatch

__all__ = [
    "DB",
    "ShardedDB",
    "KVStore",
    "Snapshot",
    "ShardedSnapshot",
    "Cursor",
    "MergedCursor",
    "HashPartitioner",
    "RangePartitioner",
    "DBConfig",
    "ValueOffset",
    "WriteBatch",
    "Env",
    "FaultInjectionEnv",
    "FaultRule",
    "DEFAULT_ENV",
    "DBError",
    "DBReadOnlyError",
    "BackgroundError",
    "SnapshotUnstableError",
    "CorruptionError",
    "SimulatedCrashError",
    "ReplicaDivergedError",
    "ReplicationLink",
    "InProcessTransport",
    "attach",
    "bootstrap_replica",
]
