"""Record encodings shared by the WAL, MemTable, SSTables and BValue files.

Layout decisions follow LevelDB/RocksDB conventions where that keeps the
engine honest as a baseline:

* varint32/64 length prefixes,
* internal keys = ``user_key . seq(7B big-endian) . type(1B)`` so that a
  plain bytewise sort orders by (user_key asc, seq desc),
* CRC-framed log records so torn tails are detected on replay.

Value kinds:

* ``kTypeValue``      — inline value (RocksDB baseline path, and small values)
* ``kTypeDeletion``   — tombstone
* ``kTypeValuePtr``   — BVLSM/BlobDB pointer: payload is an encoded
                        :class:`ValueOffset` instead of the value bytes.
* ``kTypeRangeDeletion`` — range tombstone: key is the *start* (inclusive)
                        and the value payload is the *end* (exclusive) user
                        key. Rides the existing WAL entry encoding unchanged;
                        SSTables store these in a dedicated side block.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

kTypeDeletion = 0x0
kTypeValue = 0x1
kTypeValuePtr = 0x2
kTypeRangeDeletion = 0x3

MAX_SEQ = (1 << 56) - 1


# ---------------------------------------------------------------------------
# varints
# ---------------------------------------------------------------------------

def encode_varint(n: int) -> bytes:
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def decode_varint(buf: bytes, pos: int) -> tuple[int, int]:
    shift = 0
    result = 0
    while True:
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, pos
        shift += 7


# ---------------------------------------------------------------------------
# internal keys
# ---------------------------------------------------------------------------

def pack_internal_key(user_key: bytes, seq: int, type_: int) -> bytes:
    # seq is stored inverted so that bytewise ascending order gives seq DESC
    # (newest first) within the same user key.
    inv = MAX_SEQ - seq
    return user_key + inv.to_bytes(7, "big") + bytes([type_])


def unpack_internal_key(ikey: bytes) -> tuple[bytes, int, int]:
    user_key = ikey[:-8]
    inv = int.from_bytes(ikey[-8:-1], "big")
    return user_key, MAX_SEQ - inv, ikey[-1]


# ---------------------------------------------------------------------------
# ValueOffset — the paper's Key-ValueOffset metadata
# ---------------------------------------------------------------------------

_VOFF = struct.Struct("<IQII")  # file_id, offset, size, crc32(value)


@dataclass(frozen=True, slots=True)
class ValueOffset:
    """Location of a separated big value inside a BValue file."""

    file_id: int
    offset: int
    size: int
    crc: int = 0

    def encode(self) -> bytes:
        return _VOFF.pack(self.file_id, self.offset, self.size, self.crc)

    @staticmethod
    def decode(buf: bytes) -> "ValueOffset":
        f, o, s, c = _VOFF.unpack(buf[: _VOFF.size])
        return ValueOffset(f, o, s, c)


VOFF_SIZE = _VOFF.size


# ---------------------------------------------------------------------------
# WAL record framing:  [crc32 u32][len u32][payload]
#   payload = seq(varint) count(varint) then per-entry:
#     type(1B) klen(varint) key vlen(varint) value_or_voff
# ---------------------------------------------------------------------------

_HDR = struct.Struct("<II")
WAL_HEADER_SIZE = _HDR.size


def frame_record(payload: bytes) -> bytes:
    return _HDR.pack(zlib.crc32(payload) & 0xFFFFFFFF, len(payload)) + payload


def frame_records(payloads) -> bytes:
    """Frame many payloads into one contiguous blob (group commit: the WAL
    writer issues a single write+fsync for the whole group, but each payload
    keeps its own CRC frame so replay-atomicity stays per-batch)."""
    return b"".join(frame_record(p) for p in payloads)


def encode_entries(seq: int, entries: list[tuple[int, bytes, bytes]]) -> bytes:
    """entries: list of (type, key, value_bytes_or_encoded_voff)."""
    parts = [encode_varint(seq), encode_varint(len(entries))]
    for type_, key, val in entries:
        parts.append(bytes([type_]))
        parts.append(encode_varint(len(key)))
        parts.append(key)
        parts.append(encode_varint(len(val)))
        parts.append(val)
    return b"".join(parts)


def decode_entries(payload: bytes) -> tuple[int, list[tuple[int, bytes, bytes]]]:
    seq, pos = decode_varint(payload, 0)
    count, pos = decode_varint(payload, pos)
    out = []
    for _ in range(count):
        type_ = payload[pos]
        pos += 1
        klen, pos = decode_varint(payload, pos)
        key = payload[pos : pos + klen]
        pos += klen
        vlen, pos = decode_varint(payload, pos)
        val = payload[pos : pos + vlen]
        pos += vlen
        out.append((type_, key, val))
    return seq, out


def iter_framed_records(buf: bytes):
    """Yield payloads from a CRC-framed log; stop at the first corrupt/torn
    record (standard WAL tail-truncation semantics)."""
    for payload, _end in iter_framed_records_ex(buf):
        yield payload


def iter_framed_records_ex(buf: bytes):
    """Like :func:`iter_framed_records` but yields ``(payload, end_offset)``
    where ``end_offset`` is the byte position just past the record's frame —
    recovery uses the last good offset to truncate a torn tail in place."""
    pos = 0
    n = len(buf)
    while pos + WAL_HEADER_SIZE <= n:
        crc, length = _HDR.unpack_from(buf, pos)
        body_start = pos + WAL_HEADER_SIZE
        if body_start + length > n:
            return  # torn tail
        payload = buf[body_start : body_start + length]
        if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
            return  # corrupt record — stop replay here
        pos = body_start + length
        yield payload, pos
