"""MemTable.

RocksDB uses a concurrent skip list; this engine is single-writer (DB-level
lock), so we keep a hash map of ``user_key -> (seq, type, value)`` holding
the *newest* version plus exact byte accounting, and materialize sorted
order at flush/scan time. Behaviourally equivalent for a single writer; the
paper's MemTable argument is about *capacity* (big values exhausting it),
which the byte accounting models exactly.

``approximate_size`` counts key+value+fixed overhead, mirroring RocksDB
arena accounting — this is what makes the paper's point measurable: with
WAL-time separation a 64 KiB value contributes only ~VOFF_SIZE bytes here.
"""
from __future__ import annotations

from .record import kTypeDeletion

ENTRY_OVERHEAD = 24  # node/arena bookkeeping per entry (approximation)


class MemTable:
    __slots__ = ("_table", "_bytes", "first_seq", "last_seq", "wal_no")

    def __init__(self) -> None:
        self._table: dict[bytes, tuple[int, int, bytes]] = {}
        self._bytes = 0
        self.first_seq: int | None = None
        self.last_seq = 0
        self.wal_no: int | None = None  # WAL file backing this memtable

    def __len__(self) -> int:
        return len(self._table)

    @property
    def approximate_size(self) -> int:
        return self._bytes

    def add(self, seq: int, type_: int, key: bytes, value: bytes):
        """Returns the superseded (seq, type, value) record, if any."""
        prev = self._table.get(key)
        if prev is not None:
            self._bytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
        self._table[key] = (seq, type_, value)
        self._bytes += len(key) + len(value) + ENTRY_OVERHEAD
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)
        return prev

    def get(self, key: bytes):
        """Returns (found, type, value). found=False means fall through to
        older tables / SSTs; a found tombstone terminates the lookup."""
        hit = self._table.get(key)
        if hit is None:
            return False, kTypeDeletion, b""
        seq, type_, value = hit
        return True, type_, value

    def sorted_items(self):
        """Yield (key, seq, type, value) in ascending user-key order."""
        for key in sorted(self._table):
            seq, type_, value = self._table[key]
            yield key, seq, type_, value

    def range_items(self, start: bytes, end: bytes | None):
        for key in sorted(self._table):
            if key < start:
                continue
            if end is not None and key >= end:
                break
            seq, type_, value = self._table[key]
            yield key, seq, type_, value
