"""MemTable.

RocksDB uses a concurrent skip list; this engine is single-writer (DB-level
lock), so we keep a hash map of ``user_key -> (seq, type, value)`` holding
the *newest* version plus exact byte accounting, and materialize sorted
order at flush/scan time. Behaviourally equivalent for a single writer; the
paper's MemTable argument is about *capacity* (big values exhausting it),
which the byte accounting models exactly.

``approximate_size`` counts key+value+fixed overhead, mirroring RocksDB
arena accounting — this is what makes the paper's point measurable: with
WAL-time separation a 64 KiB value contributes only ~VOFF_SIZE bytes here.

Three write-pipeline optimizations:

* ``add_batch`` applies a whole group-commit batch with one pass (the
  leader calls it once per follower batch instead of per entry);
* ``add_group_sharded`` fans a huge commit group out across a worker pool,
  partitioned by key hash — each key lives entirely in one shard and each
  shard applies its entries in sequence order, so the result is
  bit-identical to the sequential apply (per-key last-writer-wins is a
  per-shard property). Individual dict get/set ops are GIL-atomic, so the
  shards can share ``_table`` without a lock;
* the sorted key view is cached and only rebuilt when a *new* key has been
  inserted — overwrites keep it — so repeated ``range_items`` /
  ``sorted_items`` calls (scans, flush) stop re-sorting the entire dict.

The cache is versioned because readers run WITHOUT the DB mutex (scan
iterates after releasing it): writers bump ``_version`` on every new-key
insert, and a reader publishes its sorted list tagged with the version it
started from — a list built while a write raced in carries a stale tag and
is simply rebuilt, it can never masquerade as fresh.
"""
from __future__ import annotations

from bisect import bisect_left

from .record import kTypeDeletion

ENTRY_OVERHEAD = 24  # node/arena bookkeeping per entry (approximation)


class MemTable:
    __slots__ = ("_table", "_bytes", "_version", "_sorted_cache",
                 "first_seq", "last_seq", "wal_no", "recovery_logs")

    def __init__(self) -> None:
        self._table: dict[bytes, tuple[int, int, bytes]] = {}
        self._bytes = 0
        self._version = 0  # bumped on new-key insert (key set changed)
        self._sorted_cache: tuple[int, list[bytes]] | None = None  # (version, keys)
        self.first_seq: int | None = None
        self.last_seq = 0
        self.wal_no: int | None = None  # WAL file backing this memtable
        # WAL files this memtable was rebuilt from at recovery; they are the
        # ONLY durable copy of its entries, so flush deletes them strictly
        # after the L0 manifest commit (see compaction.flush_memtable)
        self.recovery_logs: list[str] | None = None

    def __len__(self) -> int:
        return len(self._table)

    @property
    def approximate_size(self) -> int:
        return self._bytes

    def add(self, seq: int, type_: int, key: bytes, value: bytes):
        """Returns the superseded (seq, type, value) record, if any."""
        prev = self._table.get(key)
        if prev is not None:
            self._bytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
        self._table[key] = (seq, type_, value)
        self._bytes += len(key) + len(value) + ENTRY_OVERHEAD
        if prev is None:
            # bump AFTER the insert (like add_batch): a lock-free reader that
            # sorted between a bump and the insert could otherwise publish a
            # list missing this key under a fresh version tag.
            self._version += 1
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)
        return prev

    def add_batch(self, seq: int, entries) -> list:
        """Apply a group-commit batch of (type, key, value) entries sharing
        one sequence number. Returns the superseded records (same contract
        as ``add``) for entries that overwrote an existing key."""
        table = self._table
        nbytes = 0
        new_keys = 0
        prevs = []
        for type_, key, value in entries:
            prev = table.get(key)
            if prev is not None:
                nbytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
                prevs.append(prev)
            else:
                new_keys += 1
            table[key] = (seq, type_, value)
            nbytes += len(key) + len(value) + ENTRY_OVERHEAD
        if new_keys:
            self._version += 1
        self._bytes += nbytes
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)
        return prevs

    def add_group_sharded(self, applies, pool, nshards: int) -> list:
        """Apply a whole commit group — ``applies`` is ``[(seq, entries),
        ...]`` in ascending seq order — sharded by key hash across ``pool``.

        Returns the combined superseded records (same contract as
        ``add_batch``). The version bump happens once, AFTER every shard has
        joined, preserving the lock-free reader protocol: a reader that
        sorted mid-apply publishes under a pre-bump tag and rebuilds.
        """
        buckets: list[list] = [[] for _ in range(nshards)]
        for seq, entries in applies:
            for entry in entries:
                buckets[hash(entry[1]) % nshards].append((seq, entry))
        futures = [pool.submit(self._apply_shard, b) for b in buckets if b]
        nbytes = 0
        new_keys = 0
        prevs: list = []
        for f in futures:
            b, n, p = f.result()
            nbytes += b
            new_keys += n
            prevs.extend(p)
        self._bytes += nbytes
        if new_keys:
            self._version += 1
        if applies:
            if self.first_seq is None:
                self.first_seq = applies[0][0]
            self.last_seq = max(self.last_seq, applies[-1][0])
        return prevs

    def _apply_shard(self, items) -> tuple[int, int, list]:
        """One shard's slice of a group: ``[(seq, (type, key, value)), ...]``
        in seq order. Touches only this shard's keys; returns the byte
        delta, new-key count, and superseded records."""
        table = self._table
        nbytes = 0
        new_keys = 0
        prevs = []
        for seq, (type_, key, value) in items:
            prev = table.get(key)
            if prev is not None:
                nbytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
                prevs.append(prev)
            else:
                new_keys += 1
            table[key] = (seq, type_, value)
            nbytes += len(key) + len(value) + ENTRY_OVERHEAD
        return nbytes, new_keys, prevs

    def get(self, key: bytes):
        """Returns (found, type, value). found=False means fall through to
        older tables / SSTs; a found tombstone terminates the lookup."""
        hit = self._table.get(key)
        if hit is None:
            return False, kTypeDeletion, b""
        seq, type_, value = hit
        return True, type_, value

    def _sorted(self) -> list[bytes]:
        while True:
            version = self._version
            cached = self._sorted_cache
            if cached is not None and cached[0] == version:
                return cached[1]
            try:
                keys = sorted(self._table)
            except RuntimeError:  # dict mutated mid-sort by a racing writer
                continue
            if self._version != version:
                continue  # key set changed while sorting — rebuild
            # a racing publish after this point carries its own (older or
            # equal) version tag, so readers can never see a fresh tag on a
            # stale list; tuple assignment is atomic under the GIL.
            self._sorted_cache = (version, keys)
            return keys

    def sorted_items(self):
        """Yield (key, seq, type, value) in ascending user-key order."""
        table = self._table
        for key in self._sorted():
            seq, type_, value = table[key]
            yield key, seq, type_, value

    def range_items(self, start: bytes, end: bytes | None):
        keys = self._sorted()
        table = self._table
        for i in range(bisect_left(keys, start), len(keys)):
            key = keys[i]
            if end is not None and key >= end:
                break
            seq, type_, value = table[key]
            yield key, seq, type_, value
