"""MemTable.

RocksDB uses a concurrent skip list; this engine is single-writer (DB-level
lock), so we keep a hash map of ``user_key -> (seq, type, value)`` holding
the *newest* version plus exact byte accounting, and materialize sorted
order at flush/scan time. Behaviourally equivalent for a single writer; the
paper's MemTable argument is about *capacity* (big values exhausting it),
which the byte accounting models exactly.

``approximate_size`` counts key+value+fixed overhead, mirroring RocksDB
arena accounting — this is what makes the paper's point measurable: with
WAL-time separation a 64 KiB value contributes only ~VOFF_SIZE bytes here.

Three write-pipeline optimizations:

* ``add_batch`` applies a whole group-commit batch with one pass (the
  leader calls it once per follower batch instead of per entry);
* ``add_group_sharded`` fans a huge commit group out across a worker pool,
  partitioned by key hash — each key lives entirely in one shard and each
  shard applies its entries in sequence order, so the result is
  bit-identical to the sequential apply (per-key last-writer-wins is a
  per-shard property). Individual dict get/set ops are GIL-atomic, so the
  shards can share ``_table`` without a lock;
* the sorted key view is cached and only rebuilt when a *new* key has been
  inserted — overwrites keep it — so repeated ``range_items`` /
  ``sorted_items`` calls (scans, flush) stop re-sorting the entire dict.

The cache is versioned because readers run WITHOUT the DB mutex (scan
iterates after releasing it): writers bump ``_version`` on every new-key
insert, and a reader publishes its sorted list tagged with the version it
started from — a list built while a write raced in carries a stale tag and
is simply rebuilt, it can never masquerade as fresh.

MVCC extensions (PR 7):

* ``_history`` retains *superseded* versions (oldest-first per key) while a
  live snapshot could still read them — the apply paths take a
  ``retain_from`` watermark (the oldest live snapshot's seq) and keep the
  overwritten record iff ``prev_seq <= retain_from``. With no snapshots the
  fast newest-only path is byte-identical to before. History lists are
  append-only and the previous version is appended BEFORE the table slot is
  overwritten, so a lock-free reader that sees the new head always finds
  the superseded version in history (``reversed()`` captures its end index
  at creation — racing appends are invisible to it).
* ``range_tombstones`` holds ``(seq, start, end)`` range-delete records
  (end exclusive); point reads consult :meth:`covering_tombstone_seq`.
"""
from __future__ import annotations

from bisect import bisect_left

from .record import MAX_SEQ, kTypeDeletion, kTypeRangeDeletion

ENTRY_OVERHEAD = 24  # node/arena bookkeeping per entry (approximation)


class MemTable:
    __slots__ = ("_table", "_bytes", "_version", "_sorted_cache", "_history",
                 "range_tombstones", "first_seq", "last_seq", "wal_no",
                 "recovery_logs")

    def __init__(self) -> None:
        self._table: dict[bytes, tuple[int, int, bytes]] = {}
        self._bytes = 0
        self._version = 0  # bumped on new-key insert (key set changed)
        self._sorted_cache: tuple[int, list[bytes]] | None = None  # (version, keys)
        # superseded-but-snapshot-visible versions, oldest-first per key
        self._history: dict[bytes, list[tuple[int, int, bytes]]] = {}
        # (seq, start, end-exclusive) range tombstones, insertion order
        self.range_tombstones: list[tuple[int, bytes, bytes]] = []
        self.first_seq: int | None = None
        self.last_seq = 0
        self.wal_no: int | None = None  # WAL file backing this memtable
        # WAL files this memtable was rebuilt from at recovery; they are the
        # ONLY durable copy of its entries, so flush deletes them strictly
        # after the L0 manifest commit (see compaction.flush_memtable)
        self.recovery_logs: list[str] | None = None

    def __len__(self) -> int:
        return len(self._table)

    @property
    def approximate_size(self) -> int:
        return self._bytes

    def add(self, seq: int, type_: int, key: bytes, value: bytes,
            retain_from: int | None = None):
        """Returns the superseded (seq, type, value) record, if any.

        ``retain_from`` is the newest live snapshot's sequence number (None
        = no snapshots): a superseded version with ``seq <= retain_from``
        is still visible to some snapshot and moves into ``_history``
        instead of being dropped."""
        if type_ == kTypeRangeDeletion:
            self._add_range_tombstone(seq, key, value)
            return None
        prev = self._table.get(key)
        if prev is not None:
            if retain_from is not None and prev[0] <= retain_from:
                # append BEFORE overwriting the head (lock-free readers)
                self._history.setdefault(key, []).append(prev)
                self._bytes += len(key) + ENTRY_OVERHEAD  # history node cost
            self._bytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
        self._table[key] = (seq, type_, value)
        self._bytes += len(key) + len(value) + ENTRY_OVERHEAD
        if prev is None:
            # bump AFTER the insert (like add_batch): a lock-free reader that
            # sorted between a bump and the insert could otherwise publish a
            # list missing this key under a fresh version tag.
            self._version += 1
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)
        return prev

    def _add_range_tombstone(self, seq: int, start: bytes, end: bytes) -> None:
        self.range_tombstones.append((seq, start, end))
        self._bytes += len(start) + len(end) + ENTRY_OVERHEAD
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)

    def add_batch(self, seq: int, entries, retain_from: int | None = None) -> list:
        """Apply a group-commit batch of (type, key, value) entries sharing
        one sequence number. Returns the superseded records (same contract
        as ``add``) for entries that overwrote an existing key."""
        table = self._table
        history = self._history
        nbytes = 0
        new_keys = 0
        prevs = []
        for type_, key, value in entries:
            if type_ == kTypeRangeDeletion:
                self._add_range_tombstone(seq, key, value)
                continue
            prev = table.get(key)
            if prev is not None:
                if retain_from is not None and prev[0] <= retain_from:
                    history.setdefault(key, []).append(prev)
                    nbytes += len(key) + ENTRY_OVERHEAD
                else:
                    # a retained version is still live (some snapshot reads
                    # it) — only non-retained supersessions are reported so
                    # the caller's dead-value accounting stays truthful
                    prevs.append(prev)
                nbytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
            else:
                new_keys += 1
            table[key] = (seq, type_, value)
            nbytes += len(key) + len(value) + ENTRY_OVERHEAD
        if new_keys:
            self._version += 1
        self._bytes += nbytes
        if self.first_seq is None:
            self.first_seq = seq
        self.last_seq = max(self.last_seq, seq)
        return prevs

    def add_group_sharded(self, applies, pool, nshards: int,
                          retain_from: int | None = None) -> list:
        """Apply a whole commit group — ``applies`` is ``[(seq, entries),
        ...]`` in ascending seq order — sharded by key hash across ``pool``.

        Returns the combined superseded records (same contract as
        ``add_batch``). The version bump happens once, AFTER every shard has
        joined, preserving the lock-free reader protocol: a reader that
        sorted mid-apply publishes under a pre-bump tag and rebuilds.
        """
        buckets: list[list] = [[] for _ in range(nshards)]
        for seq, entries in applies:
            for entry in entries:
                if entry[0] == kTypeRangeDeletion:
                    # range tombstones span shards — the leader applies them
                    # directly (applies are in ascending seq order)
                    self._add_range_tombstone(seq, entry[1], entry[2])
                else:
                    buckets[hash(entry[1]) % nshards].append((seq, entry))
        futures = [
            pool.submit(self._apply_shard, b, retain_from) for b in buckets if b
        ]
        nbytes = 0
        new_keys = 0
        prevs: list = []
        for f in futures:
            b, n, p = f.result()
            nbytes += b
            new_keys += n
            prevs.extend(p)
        self._bytes += nbytes
        if new_keys:
            self._version += 1
        if applies:
            if self.first_seq is None:
                self.first_seq = applies[0][0]
            self.last_seq = max(self.last_seq, applies[-1][0])
        return prevs

    def _apply_shard(self, items, retain_from: int | None = None) -> tuple[int, int, list]:
        """One shard's slice of a group: ``[(seq, (type, key, value)), ...]``
        in seq order. Touches only this shard's keys; returns the byte
        delta, new-key count, and superseded records."""
        table = self._table
        history = self._history
        nbytes = 0
        new_keys = 0
        prevs = []
        for seq, (type_, key, value) in items:
            prev = table.get(key)
            if prev is not None:
                if retain_from is not None and prev[0] <= retain_from:
                    history.setdefault(key, []).append(prev)
                    nbytes += len(key) + ENTRY_OVERHEAD
                else:
                    prevs.append(prev)  # see add_batch: retained = still live
                nbytes -= len(key) + len(prev[2]) + ENTRY_OVERHEAD
            else:
                new_keys += 1
            table[key] = (seq, type_, value)
            nbytes += len(key) + len(value) + ENTRY_OVERHEAD
        return nbytes, new_keys, prevs

    def get(self, key: bytes):
        """Returns (found, type, value). found=False means fall through to
        older tables / SSTs; a found tombstone terminates the lookup.

        NOTE: does not consult range tombstones — the DB read path tracks
        the max covering tombstone seq across tables itself (a tombstone
        here may shadow a point hit in an older table)."""
        hit = self._table.get(key)
        if hit is None:
            return False, kTypeDeletion, b""
        seq, type_, value = hit
        return True, type_, value

    def get_at(self, key: bytes, read_seq: int):
        """Snapshot read: returns (found, seq, type, value) for the newest
        version of ``key`` with ``seq <= read_seq``."""
        hit = self._table.get(key)
        if hit is None:
            return False, 0, kTypeDeletion, b""
        if hit[0] <= read_seq:
            return True, hit[0], hit[1], hit[2]
        for rec in reversed(self._history.get(key, ())):
            if rec[0] <= read_seq:
                return True, rec[0], rec[1], rec[2]
        return False, 0, kTypeDeletion, b""

    def covering_tombstone_seq(self, key: bytes, read_seq: int = MAX_SEQ) -> int:
        """Max seq of a range tombstone covering ``key`` visible at
        ``read_seq`` (0 if none). The list is tiny per memtable, so a
        linear scan is fine."""
        best = 0
        for seq, start, end in self.range_tombstones:
            if seq <= read_seq and start <= key < end and seq > best:
                best = seq
        return best

    def _sorted(self) -> list[bytes]:
        while True:
            version = self._version
            cached = self._sorted_cache
            if cached is not None and cached[0] == version:
                return cached[1]
            try:
                keys = sorted(self._table)
            except RuntimeError:  # dict mutated mid-sort by a racing writer
                continue
            if self._version != version:
                continue  # key set changed while sorting — rebuild
            # a racing publish after this point carries its own (older or
            # equal) version tag, so readers can never see a fresh tag on a
            # stale list; tuple assignment is atomic under the GIL.
            self._sorted_cache = (version, keys)
            return keys

    def sorted_items(self):
        """Yield (key, seq, type, value) in (user-key asc, seq desc) order —
        every retained version, newest first per key (single-version when no
        snapshot history exists, identical to the pre-MVCC behaviour)."""
        table = self._table
        history = self._history
        for key in self._sorted():
            seq, type_, value = table[key]
            yield key, seq, type_, value
            if history:
                for hseq, htype, hvalue in reversed(history.get(key, ())):
                    yield key, hseq, htype, hvalue

    def range_items(self, start: bytes, end: bytes | None):
        """Newest version per key in [start, end) — the latest-read scan
        view (snapshot readers use :meth:`iter_versions_from`)."""
        keys = self._sorted()
        table = self._table
        for i in range(bisect_left(keys, start), len(keys)):
            key = keys[i]
            if end is not None and key >= end:
                break
            seq, type_, value = table[key]
            yield key, seq, type_, value

    def iter_versions_from(self, start: bytes):
        """Yield (key, seq, type, value) for EVERY retained version from
        ``start`` on, newest first per key — the cursor's memtable source."""
        keys = self._sorted()
        table = self._table
        history = self._history
        for i in range(bisect_left(keys, start), len(keys)):
            key = keys[i]
            seq, type_, value = table[key]
            yield key, seq, type_, value
            for hseq, htype, hvalue in reversed(history.get(key, ())):
                yield key, hseq, htype, hvalue

    def largest_key_below(self, bound: bytes | None) -> bytes | None:
        """Largest user key strictly below ``bound`` (reverse-cursor step).
        ``None`` bound means unbounded: the largest key overall."""
        keys = self._sorted()
        if bound is None:
            return keys[-1] if keys else None
        i = bisect_left(keys, bound)
        return keys[i - 1] if i else None
