"""BValue garbage collection — a beyond-paper extension.

The paper describes no reclamation story for BValue files: overwritten or
deleted keys leave dead values behind forever (WiscKey/Titan both need GC;
BVLSM §III-C is silent). This module adds the standard vLog GC, adapted to
the multi-queue layout:

* ``DeadValueTracker`` — the write/compaction paths report superseded
  ValueOffsets (overwrite in MemTable, drop during compaction, delete);
  dead bytes are accumulated per BValue file.
* ``collect()`` — for every sealed file whose dead ratio ≥ threshold, scan
  the LIVE key space (the LSM tree is the source of truth), rewrite each
  live value through the normal multi-queue write path (getting a fresh
  ValueOffset), re-insert the Key-ValueOffset record, and delete the file.
  Crash-safe by construction: the old file is unlinked only after the
  re-pointed records are durable (same WAL-ordering argument as checkpoint
  commit), and a crash mid-GC leaves only duplicate live values, never
  missing ones.
"""
from __future__ import annotations

import os
import threading
from collections import defaultdict

from .record import ValueOffset, kTypeValuePtr


class DeadValueTracker:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.dead_bytes: dict[int, int] = defaultdict(int)
        self.total_bytes: dict[int, int] = defaultdict(int)

    def on_write(self, voff: ValueOffset) -> None:
        with self._lock:
            self.total_bytes[voff.file_id] += voff.size

    def on_dead(self, voff: ValueOffset) -> None:
        with self._lock:
            self.dead_bytes[voff.file_id] += voff.size

    def dead_ratio(self, file_id: int) -> float:
        with self._lock:
            total = self.total_bytes.get(file_id, 0)
            return self.dead_bytes.get(file_id, 0) / total if total else 0.0

    def candidates(self, threshold: float, exclude: set[int]) -> list[int]:
        with self._lock:
            out = []
            for fid, total in self.total_bytes.items():
                if fid in exclude or not total:
                    continue
                if self.dead_bytes.get(fid, 0) / total >= threshold:
                    out.append(fid)
            return out

    def forget(self, file_id: int) -> None:
        with self._lock:
            self.dead_bytes.pop(file_id, None)
            self.total_bytes.pop(file_id, None)


class BValueGC:
    def __init__(self, db, threshold: float = 0.5):
        self.db = db
        self.threshold = threshold
        self.collected_files = 0
        self.reclaimed_bytes = 0
        self.rewritten_values = 0

    def _live_files(self) -> set[int]:
        """Files still being appended to (never collect the active tail)."""
        return {q.file_id for q in self.db.bvalue.queues}

    def collect(self) -> dict:
        """One GC pass. Returns stats. Runs from the caller's thread (the
        benchmark/TEST calls it explicitly; a deployment would hang it off
        the background worker on a dead-ratio trigger)."""
        db = self.db
        cands = db.dead_tracker.candidates(self.threshold, exclude=self._live_files())
        for fid in cands:
            moved = 0
            # the LSM view is the truth: rewrite every live pointer into fid
            for key, _ in db.scan(b"", 1 << 30):
                rec = self._pointer_for(key)
                if rec is None or rec.file_id != fid:
                    continue
                value = db.bvalue.get(rec)
                db.put(key, value)  # re-separates → fresh ValueOffset
                moved += 1
            db.flush()
            path = db.bvalue.file_path(fid)
            try:
                size = os.path.getsize(path)
                os.unlink(path)
            except OSError:
                size = 0
            db.bvalue.drop_reader(fid)
            db.dead_tracker.forget(fid)
            self.collected_files += 1
            self.reclaimed_bytes += size
            self.rewritten_values += moved
        return {
            "collected_files": self.collected_files,
            "reclaimed_bytes": self.reclaimed_bytes,
            "rewritten_values": self.rewritten_values,
        }

    def _pointer_for(self, key: bytes) -> ValueOffset | None:
        """Fetch the authoritative ValueOffset for `key` (or None)."""
        db = self.db
        with db.mutex:
            tables = [db.mem, *reversed(db.immutables)]
            version = db.versions.current
        for t in tables:
            found, type_, value = t.get(key)
            if found:
                return ValueOffset.decode(value) if type_ == kTypeValuePtr else None
        for _lvl, fmeta in version.candidates_for_get(key):
            found, _seq, type_, value = db.versions.reader(fmeta.file_no).get(key)
            if found:
                return ValueOffset.decode(value) if type_ == kTypeValuePtr else None
        return None
