"""BValue garbage collection — a beyond-paper extension.

The paper describes no reclamation story for BValue files: overwritten or
deleted keys leave dead values behind forever (WiscKey/Titan both need GC;
BVLSM §III-C is silent). This module adds the standard vLog GC, adapted to
the multi-queue layout:

* ``DeadValueTracker`` — the write/compaction paths report superseded
  ValueOffsets (overwrite in MemTable, drop during compaction, delete);
  dead bytes are accumulated per BValue file.
* ``collect()`` — for every sealed file whose dead ratio ≥ threshold, scan
  the LIVE key space (the LSM tree is the source of truth), rewrite each
  live value through the normal multi-queue write path (getting a fresh
  ValueOffset), re-insert the Key-ValueOffset record, and delete the file.
  Crash-safe by construction: the old file is unlinked only after the
  re-pointed records are durable (same WAL-ordering argument as checkpoint
  commit), and a crash mid-GC leaves only duplicate live values, never
  missing ones.

Scheduling: GC normally runs as a low-priority job on the background
scheduler, triggered when a compaction pushes a sealed file past
``DBConfig.gc_dead_ratio_trigger`` (``gc_auto``). ``DB.gc_collect`` is the
synchronous wrapper over the same pass. Either way the rewrites draw from
the shared I/O token bucket at low priority (under the unified budget the
BValue dispatch itself inherits PRI_LOW from the GC context), and the pass
bails out between files when the DB is closing.

Pacing: an auto-scheduled pass is **sliced** — it rewrites at most
``DBConfig.gc_slice_bytes`` of live values, then returns its LOW thread to
the scheduler; the completion edge re-examines the dead ratios and queues
the next slice. A slice that stops mid-file simply leaves the file for the
next slice: already-moved keys no longer point into it, so resuming is a
plain re-scan (idempotent), and the file is only unlinked by the slice
that proves every live pointer has moved out. Candidates are served
deadest-first so each slice reclaims the most bytes per rewrite.
"""
from __future__ import annotations

import threading
from collections import defaultdict

from .errors import SnapshotUnstableError
from .ratelimiter import PRI_LOW
from .record import ValueOffset, kTypeValue, kTypeValuePtr


class DeadValueTracker:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.dead_bytes: dict[int, int] = defaultdict(int)
        self.total_bytes: dict[int, int] = defaultdict(int)

    def on_write(self, voff: ValueOffset) -> None:
        with self._lock:
            self.total_bytes[voff.file_id] += voff.size

    def on_dead(self, voff: ValueOffset) -> None:
        with self._lock:
            self.dead_bytes[voff.file_id] += voff.size

    def dead_ratio(self, file_id: int) -> float:
        with self._lock:
            total = self.total_bytes.get(file_id, 0)
            return self.dead_bytes.get(file_id, 0) / total if total else 0.0

    def candidates(self, threshold: float, exclude: set[int]) -> list[int]:
        with self._lock:
            out = []
            for fid, total in self.total_bytes.items():
                if fid in exclude or not total:
                    continue
                if self.dead_bytes.get(fid, 0) / total >= threshold:
                    out.append(fid)
            return out

    def forget(self, file_id: int) -> None:
        with self._lock:
            self.dead_bytes.pop(file_id, None)
            self.total_bytes.pop(file_id, None)

    def signature(self, fids) -> frozenset:
        """(fid, dead_bytes) fingerprint of a candidate set — dead bytes
        only grow, so ANY new death in these files changes the signature.
        The scheduler parks this after a zero-progress GC pass and re-arms
        as soon as it differs (new candidate OR more deaths in an old
        one), so a transiently uncollectable set is retried on the next
        real edge instead of being ignored forever."""
        with self._lock:
            return frozenset((fid, self.dead_bytes.get(fid, 0)) for fid in fids)


class BValueGC:
    def __init__(
        self, db, threshold: float = 0.5, max_rewrite_bytes: int = 0, resume=None
    ):
        self.db = db
        self.threshold = threshold
        # slice budget: stop the pass (without unlinking the current file)
        # once this many live bytes have been rewritten; 0 = unsliced
        self.max_rewrite_bytes = max_rewrite_bytes
        # work list carried over from a previous sliced pass — the live-key
        # scan is the dominant cost, so slices share ONE scan. Safe to
        # reuse because fresh ValueOffsets always land in active files (ids
        # never reused), so no NEW pointer can appear inside a sealed
        # candidate after the scan: a carried list is complete-modulo-
        # deaths, and every key is re-checked against the live pointer
        # before rewriting anyway.
        self.resume = resume
        self.resume_state = None  # (remaining candidate fids, live_ptrs)
        self.collected_files = 0
        self.reclaimed_bytes = 0
        self.rewritten_values = 0
        self.rewritten_bytes = 0
        self.sliced = False  # budget exhausted with work remaining
        self.snapshot_deferred = 0  # files kept alive for a live snapshot

    def _live_files(self) -> set[int]:
        """Files GC must not touch: the active append tails, plus any file
        quarantined for corruption (rewriting through it would read the bad
        bytes; the file stays on disk so its intact values keep serving)."""
        db = self.db
        return {q.file_id for q in db.bvalue.queues} | set(
            db.versions.quarantined_bvalues
        )

    def _stopping(self) -> bool:
        db = self.db
        return db._closed or db.bg._stopping

    def collect(self) -> dict:
        """One GC pass. Returns stats. Runs from a scheduler thread
        (``gc_auto``) or synchronously via ``DB.gc_collect``."""
        db = self.db
        cur = set(db.dead_tracker.candidates(self.threshold, exclude=self._live_files()))
        if self._stopping():
            return self._stats()
        cands: list[int] = []
        live_ptrs: dict[int, list[bytes]] = {}
        if self.resume is not None:
            # continue the previous slice's work list (files collected or
            # cleaned since then drop out of the current candidate set)
            r_cands, live_ptrs = self.resume
            cands = [fid for fid in r_cands if fid in cur]
        if not cands:
            if not cur:
                return self._stats()
            # deadest-first: a sliced pass spends its budget where each
            # rewritten byte reclaims the most dead ones
            cands = sorted(cur, key=db.dead_tracker.dead_ratio, reverse=True)
            # ONE scan over the live key space serves every candidate file
            # (and, via resume, every later slice): the LSM view is the
            # truth, so collect (key -> pointer) per candidate.
            live_ptrs = {fid: [] for fid in cands}
            for n, (key, _) in enumerate(db.range()):
                if (n & 1023) == 0 and self._stopping():
                    return self._stats()  # closing: don't finish an O(DB) walk
                rec = self._pointer_for(key)
                if rec is not None and rec.file_id in live_ptrs:
                    live_ptrs[rec.file_id].append(key)
        # GC rewrites re-enter the foreground put path from a background
        # thread: exempt them from the writer stall (the token bucket below
        # is their throttle) so they can't deadlock the low-priority pool.
        db._bg_local.exempt = True
        try:
            for ci, fid in enumerate(cands):
                if self._stopping():
                    break
                moved = 0
                file_clean = True  # every live pointer provably moved out
                for j, key in enumerate(live_ptrs[fid]):
                    if (j & 255) == 0 and self._stopping():
                        return self._stats()  # closing mid-file: the file
                        # is NOT unlinked, so bailing here loses nothing
                    # re-check: the pointer may have been superseded (or the
                    # key deleted) since the scan — only rewrite live ones
                    rec = self._pointer_for(key)
                    if rec is None or rec.file_id != fid:
                        continue
                    value = db.bvalue.get(rec)
                    # priority inheritance: when the commit below will
                    # itself dispatch this value through BValue (unified
                    # budget, WAL-time separation, value still over the
                    # threshold), that dispatch charges PRI_LOW on this
                    # thread — charging here too would pace the rewrite
                    # twice. Every other shape (budget not unified, flush
                    # separation where the dispatch happens later on the
                    # flush thread at FG priority, or a value now under
                    # the threshold) still pays the LOW toll here.
                    commit_charges_low = (
                        db.cfg.unified_io_budget
                        and db.cfg.separation_mode == "wal"
                        and len(value) >= db.cfg.value_threshold
                    )
                    if not commit_charges_low:
                        db.rate_limiter.request(len(key) + len(value), PRI_LOW)

                    # conditional re-insert (fresh ValueOffset via the
                    # normal separation path): the commit leader re-checks
                    # the pointer at seq-assignment time, so a concurrent
                    # foreground overwrite of `key` can never be shadowed
                    # by this resurrected old value
                    def _still_current(k=key, want=rec):
                        cur = self._pointer_for(k)
                        return (
                            cur is not None
                            and cur.file_id == want.file_id
                            and cur.offset == want.offset
                        )

                    if db._commit(
                        [(kTypeValue, key, value)], precondition=_still_current
                    ):
                        moved += 1
                        self.rewritten_bytes += len(value)
                        if (
                            self.max_rewrite_bytes
                            and self.rewritten_bytes >= self.max_rewrite_bytes
                        ):
                            # slice budget spent: yield the LOW thread and
                            # hand the remaining work list (this file
                            # included — moved keys skip on re-check) to
                            # the next slice, which resumes WITHOUT
                            # repeating the keyspace scan. The file is NOT
                            # unlinked: only a slice that walks its full
                            # key list may prove it clean.
                            self.sliced = True
                            self.rewritten_values += moved
                            self.resume_state = (cands[ci:], live_ptrs)
                            return self._stats()
                        continue
                    # skipped: a supersede is fine (the key's value lives
                    # elsewhere now), but a precondition that merely ERRORED
                    # leaves the pointer live in fid — unlinking then would
                    # orphan it. Fresh offsets are never reused, so "still
                    # points into fid" can only mean the error path.
                    try:
                        cur = self._pointer_for(key)
                    except RuntimeError:
                        cur = rec  # can't prove it moved: keep the file
                    if cur is not None and cur.file_id == fid:
                        file_clean = False
                if self._stopping():
                    break
                if not file_clean:
                    continue  # leave fid for a later, calmer pass
                # snapshot guard: a live snapshot older than this file's
                # rewrites can still resolve a key to a PRE-rewrite pointer
                # (the re-inserted record has a newer seq, invisible to it),
                # and compaction retains those older versions for exactly
                # that snapshot. Unlinking now would break its reads — defer
                # to a later pass. A snapshot taken after ``hwm`` sees only
                # the fresh pointers, so it never blocks reclamation.
                with db.mutex:
                    hwm = db._seq
                snaps = db.snapshot_seqs()
                if snaps and min(snaps) < hwm:
                    self.snapshot_deferred += 1
                    continue
                db.flush()
                path = db.bvalue.file_path(fid)
                try:
                    size = db.env.getsize(path)
                    db.env.unlink(path)
                except OSError:
                    size = 0
                db.bvalue.drop_reader(fid)
                db.dead_tracker.forget(fid)
                self.collected_files += 1
                self.reclaimed_bytes += size
                self.rewritten_values += moved
        finally:
            db._bg_local.exempt = False
        return self._stats()

    def _stats(self) -> dict:
        return {
            "collected_files": self.collected_files,
            "reclaimed_bytes": self.reclaimed_bytes,
            "rewritten_values": self.rewritten_values,
            "rewritten_bytes": self.rewritten_bytes,
            "sliced": self.sliced,
            "snapshot_deferred": self.snapshot_deferred,
        }

    def _pointer_for(self, key: bytes) -> ValueOffset | None:
        """Fetch the authoritative ValueOffset for `key` (or None). Like
        ``DB.get``, the version-snapshot walk races concurrent compaction
        (an input table can be unlinked mid-walk) — retry on a superseded
        snapshot instead of surfacing the torn read."""
        db = self.db
        for _attempt in range(8):
            with db.mutex:
                tables = [db.mem, *reversed(db.immutables)]
                version = db.versions.current
            for t in tables:
                found, type_, value = t.get(key)
                if found:
                    return ValueOffset.decode(value) if type_ == kTypeValuePtr else None
            try:
                for _lvl, fmeta in version.candidates_for_get(key):
                    found, _seq, type_, value = db.versions.reader(fmeta.file_no).get(key)
                    if found:
                        return ValueOffset.decode(value) if type_ == kTypeValuePtr else None
            except (OSError, ValueError):
                if db.versions.current is version:
                    raise  # stable snapshot: real I/O or corruption error
                continue  # snapshot superseded mid-walk — take a fresh one
            if db.versions.current is version or _attempt == 7:
                return None
        # every attempt died on a torn snapshot: treating that as "no live
        # pointer" would let collect() unlink a file without rewriting this
        # key — surface the instability instead (the pass retries later)
        raise SnapshotUnstableError("GC could not obtain a stable version snapshot")
