"""Bloom filter for SSTable key lookups (Kirsch–Mitzenmacher double hashing),
matching LevelDB's ~10 bits/key default. Serialized form:
``[k u8][nbits u32][bitmap bytes]``.

New filters round ``nbits`` up to a power of two so every probe reduces
with a bitmask instead of a ``%`` division (the probe loop is the hottest
pure-Python code on a bloom-negative get). The serialized form is
self-describing — ``nbits`` rides in the header — so filters encoded by
older builds (arbitrary ``nbits``) still decode; probes fall back to ``%``
only for those legacy non-power-of-two sizes.

Batched probes: :meth:`may_contain_many` answers N keys with ONE numpy
masked gather instead of N Python probe loops. The bitmap is lazily viewed
as a ``uint8`` ndarray (zero-copy over the same buffer scalar probes use),
the per-key (h1, h2) pairs expand into an (N, k) bit-index matrix, and a
single vectorized ``bits[idx >> 3] >> (idx & 7)`` gather reduces with
``.all(axis=1)``. This is the multi-get hot path: per level, every
still-unresolved key is probed against a candidate table in one call.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np


def _hash2(key: bytes) -> tuple[int, int]:
    h1 = zlib.crc32(key) & 0xFFFFFFFF
    h2 = zlib.adler32(key) & 0xFFFFFFFF
    # adler32 is weak for short keys; mix.
    h2 = (h2 * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF
    return h1, h2 | 1


class BloomFilter:
    __slots__ = ("k", "nbits", "bits", "_mask", "_np_bits")

    def __init__(self, k: int, nbits: int, bits: bytearray):
        self.k = k
        self.nbits = nbits
        self.bits = bits
        # power-of-two sizes (every filter built by this code) probe with a
        # mask; legacy arbitrary sizes keep the modulo path
        self._mask = nbits - 1 if nbits & (nbits - 1) == 0 else None
        self._np_bits: np.ndarray | None = None  # lazy batch-probe view

    @classmethod
    def build(cls, keys: list[bytes], bits_per_key: int = 10) -> "BloomFilter":
        n = max(1, len(keys))
        nbits = 1 << (max(64, n * bits_per_key) - 1).bit_length()  # next pow2
        mask = nbits - 1
        k = max(1, min(30, int(bits_per_key * 0.69)))  # ln2 * bits/key
        bits = bytearray(nbits // 8)
        for key in keys:
            h1, h2 = _hash2(key)
            for i in range(k):
                b = (h1 + i * h2) & mask
                bits[b >> 3] |= 1 << (b & 7)
        return cls(k, nbits, bits)

    def may_contain(self, key: bytes) -> bool:
        h1, h2 = _hash2(key)
        bits = self.bits
        mask = self._mask
        if mask is not None:
            for i in range(self.k):
                b = (h1 + i * h2) & mask
                if not bits[b >> 3] & (1 << (b & 7)):
                    return False
            return True
        nbits = self.nbits
        for i in range(self.k):
            b = (h1 + i * h2) % nbits
            if not bits[b >> 3] & (1 << (b & 7)):
                return False
        return True

    def may_contain_many(self, keys) -> np.ndarray:
        """Vectorized probe: one masked numpy gather for the whole batch.

        Returns a ``bool`` ndarray aligned with ``keys`` where
        ``out[i] == self.may_contain(keys[i])`` exactly — including legacy
        non-power-of-two encodings, which vectorize the ``%`` reduction the
        scalar fallback uses. An empty batch returns an empty array.
        """
        n = len(keys)
        if n == 0:
            return np.zeros(0, dtype=bool)
        if n == 1:  # ndarray setup costs more than one scalar probe loop
            return np.array([self.may_contain(keys[0])], dtype=bool)
        bits = self._np_bits
        if bits is None:
            # zero-copy view when the backing store allows it (bytearray /
            # bytes); shares the buffer so there is no stale-copy hazard —
            # filters are immutable once built/decoded
            bits = np.frombuffer(memoryview(self.bits), dtype=np.uint8)
            self._np_bits = bits
        h = np.empty((2, n), dtype=np.uint64)
        crc32, adler32 = zlib.crc32, zlib.adler32  # per-key C calls
        for i, key in enumerate(keys):
            h[0, i] = crc32(key) & 0xFFFFFFFF
            h[1, i] = ((adler32(key) & 0xFFFFFFFF) * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF
        h1 = h[0][:, None]
        h2 = (h[1] | np.uint64(1))[:, None]
        probes = np.arange(self.k, dtype=np.uint64)[None, :]
        idx = h1 + probes * h2  # (n, k) — max ~2^32 * 30, fits uint64
        if self._mask is not None:
            idx &= np.uint64(self._mask)
        else:
            idx %= np.uint64(self.nbits)
        got = bits[(idx >> np.uint64(3)).astype(np.int64)]
        want = (np.uint8(1) << (idx & np.uint64(7)).astype(np.uint8)).astype(np.uint8)
        return ((got & want) == want).all(axis=1)

    def encode(self) -> bytes:
        return struct.pack("<BI", self.k, self.nbits) + bytes(self.bits)

    @staticmethod
    def decode(buf: bytes) -> "BloomFilter":
        k, nbits = struct.unpack_from("<BI", buf, 0)
        return BloomFilter(k, nbits, bytearray(buf[5:]))
