"""Bloom filter for SSTable key lookups (Kirsch–Mitzenmacher double hashing),
matching LevelDB's ~10 bits/key default. Serialized form:
``[k u8][nbits u32][bitmap bytes]``.

New filters round ``nbits`` up to a power of two so every probe reduces
with a bitmask instead of a ``%`` division (the probe loop is the hottest
pure-Python code on a bloom-negative get). The serialized form is
self-describing — ``nbits`` rides in the header — so filters encoded by
older builds (arbitrary ``nbits``) still decode; ``may_contain`` falls back
to ``%`` only for those legacy non-power-of-two sizes.
"""
from __future__ import annotations

import struct
import zlib


def _hash2(key: bytes) -> tuple[int, int]:
    h1 = zlib.crc32(key) & 0xFFFFFFFF
    h2 = zlib.adler32(key) & 0xFFFFFFFF
    # adler32 is weak for short keys; mix.
    h2 = (h2 * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF
    return h1, h2 | 1


class BloomFilter:
    __slots__ = ("k", "nbits", "bits", "_mask")

    def __init__(self, k: int, nbits: int, bits: bytearray):
        self.k = k
        self.nbits = nbits
        self.bits = bits
        # power-of-two sizes (every filter built by this code) probe with a
        # mask; legacy arbitrary sizes keep the modulo path
        self._mask = nbits - 1 if nbits & (nbits - 1) == 0 else None

    @classmethod
    def build(cls, keys: list[bytes], bits_per_key: int = 10) -> "BloomFilter":
        n = max(1, len(keys))
        nbits = 1 << (max(64, n * bits_per_key) - 1).bit_length()  # next pow2
        mask = nbits - 1
        k = max(1, min(30, int(bits_per_key * 0.69)))  # ln2 * bits/key
        bits = bytearray(nbits // 8)
        for key in keys:
            h1, h2 = _hash2(key)
            for i in range(k):
                b = (h1 + i * h2) & mask
                bits[b >> 3] |= 1 << (b & 7)
        return cls(k, nbits, bits)

    def may_contain(self, key: bytes) -> bool:
        h1, h2 = _hash2(key)
        bits = self.bits
        mask = self._mask
        if mask is not None:
            for i in range(self.k):
                b = (h1 + i * h2) & mask
                if not bits[b >> 3] & (1 << (b & 7)):
                    return False
            return True
        nbits = self.nbits
        for i in range(self.k):
            b = (h1 + i * h2) % nbits
            if not bits[b >> 3] & (1 << (b & 7)):
                return False
        return True

    def encode(self) -> bytes:
        return struct.pack("<BI", self.k, self.nbits) + bytes(self.bits)

    @staticmethod
    def decode(buf: bytes) -> "BloomFilter":
        k, nbits = struct.unpack_from("<BI", buf, 0)
        return BloomFilter(k, nbits, bytearray(buf[5:]))
