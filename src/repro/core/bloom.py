"""Bloom filter for SSTable key lookups (Kirsch–Mitzenmacher double hashing),
matching LevelDB's ~10 bits/key default. Serialized form:
``[k u8][nbits u32][bitmap bytes]``.
"""
from __future__ import annotations

import struct
import zlib


def _hash2(key: bytes) -> tuple[int, int]:
    h1 = zlib.crc32(key) & 0xFFFFFFFF
    h2 = zlib.adler32(key) & 0xFFFFFFFF
    # adler32 is weak for short keys; mix.
    h2 = (h2 * 0x9E3779B1 + 0x7F4A7C15) & 0xFFFFFFFF
    return h1, h2 | 1


class BloomFilter:
    __slots__ = ("k", "nbits", "bits")

    def __init__(self, k: int, nbits: int, bits: bytearray):
        self.k = k
        self.nbits = nbits
        self.bits = bits

    @classmethod
    def build(cls, keys: list[bytes], bits_per_key: int = 10) -> "BloomFilter":
        n = max(1, len(keys))
        nbits = max(64, n * bits_per_key)
        k = max(1, min(30, int(bits_per_key * 0.69)))  # ln2 * bits/key
        bits = bytearray((nbits + 7) // 8)
        for key in keys:
            h1, h2 = _hash2(key)
            for i in range(k):
                b = (h1 + i * h2) % nbits
                bits[b >> 3] |= 1 << (b & 7)
        return cls(k, nbits, bits)

    def may_contain(self, key: bytes) -> bool:
        h1, h2 = _hash2(key)
        nbits = self.nbits
        bits = self.bits
        for i in range(self.k):
            b = (h1 + i * h2) % nbits
            if not bits[b >> 3] & (1 << (b & 7)):
                return False
        return True

    def encode(self) -> bytes:
        return struct.pack("<BI", self.k, self.nbits) + bytes(self.bits)

    @staticmethod
    def decode(buf: bytes) -> "BloomFilter":
        k, nbits = struct.unpack_from("<BI", buf, 0)
        return BloomFilter(k, nbits, bytearray(buf[5:]))
