"""BVLSM DB facade — put/get/delete/scan + recovery.

One engine, three systems (see :mod:`.config`): ``separation_mode`` selects
where key–value separation happens. The BVLSM path (§III-B of the paper):

WAL-enabled::

    value --fsync--> BValue file            (multi-queue, parallel)
    Key-ValueOffset --append/fsync--> WAL   (tiny record)
    Key-ValueOffset --> MemTable --> SSTable

WAL-disabled / async::

    value --> BVCache (pinned) --> background batch write --> BValue file
    Key-ValueOffset --> MemTable (--> buffered WAL in async mode)
"""
from __future__ import annotations

import os
import threading
import time

from .bvalue import BValueManager
from .bvcache import BVCache
from .gc import BValueGC, DeadValueTracker
from .compaction import BackgroundWorker, _merge_iters
from .config import DBConfig
from .manifest import VersionSet
from .memtable import MemTable
from .record import (
    ValueOffset,
    decode_entries,
    encode_entries,
    kTypeDeletion,
    kTypeValue,
    kTypeValuePtr,
)
from .stats import EngineStats
from .wal import WALWriter, replay_wal


class DB:
    def __init__(self, path: str, cfg: DBConfig | None = None):
        self.path = path
        self.cfg = cfg or DBConfig()
        os.makedirs(path, exist_ok=True)
        self.stats = EngineStats()
        self.mutex = threading.RLock()
        self.writer_cv = threading.Condition(self.mutex)

        self.versions = VersionSet(path, self.cfg.num_levels)
        self.versions.open()
        self._seq = self.versions.last_seq

        self.bvcache = BVCache(self.cfg.bvcache_bytes, self.cfg.bvcache_policy)
        self.dead_tracker = DeadValueTracker()
        self.bvalue = BValueManager(
            os.path.join(path, "bvalue"),
            num_queues=self.cfg.num_bvalue_queues,
            async_writes=True,
            dispatch=self.cfg.bvalue_dispatch,
            page_size=self.cfg.bvalue_page_size,
            batch_bytes=self.cfg.bvalue_batch_bytes,
            max_file_bytes=self.cfg.bvalue_max_file_bytes,
            gather_window_s=self.cfg.bvalue_gather_window_s,
            stats=self.stats,
            on_persisted=self.bvcache.unpin,
            on_persisted_many=self.bvcache.unpin_many,
            next_file_id=self.versions.bvalue_next_file_id,
        )

        self.mem = MemTable()
        self.immutables: list[MemTable] = []
        self._wal_no = 0
        self.wal: WALWriter | None = None
        self._recover()
        self._open_wal()

        self.worker = BackgroundWorker(self)
        self.worker.start()
        self._closed = False

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _wal_path(self, no: int) -> str:
        return os.path.join(self.path, f"wal_{no:06d}.log")

    def _recover(self) -> None:
        logs = sorted(
            f for f in os.listdir(self.path) if f.startswith("wal_") and f.endswith(".log")
        )
        for name in logs:
            no = int(name[4:-4])
            self._wal_no = max(self._wal_no, no + 1)
            for payload in replay_wal(os.path.join(self.path, name)):
                seq, entries = decode_entries(payload)
                for type_, key, val in entries:
                    self.mem.add(seq, type_, key, val)
                    self._seq = max(self._seq, seq)
            os.unlink(os.path.join(self.path, name))

    def _open_wal(self) -> None:
        if self.cfg.wal_mode == "off":
            self.wal = None
            return
        self.wal = WALWriter(
            self._wal_path(self._wal_no),
            mode=self.cfg.wal_mode,
            flush_interval_s=self.cfg.wal_flush_interval_s,
            flush_bytes=self.cfg.wal_flush_bytes,
            stats=self.stats,
        )
        self.mem.wal_no = self._wal_no  # type: ignore[attr-defined]
        self._wal_no += 1

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        self._write(kTypeValue, key, value)

    def delete(self, key: bytes) -> None:
        self._write(kTypeDeletion, key, b"")

    def _write(self, type_: int, key: bytes, value: bytes) -> None:
        cfg = self.cfg
        separate = (
            type_ == kTypeValue
            and cfg.separation_mode == "wal"
            and len(value) >= cfg.value_threshold
        )
        # --- WAL-time separation happens OUTSIDE the DB mutex: parallel
        # callers stream values onto different queues concurrently. ---
        if separate:
            sync_value = cfg.wal_mode == "sync"
            voff = self.bvalue.put(key, value, sync=sync_value)
            self.bvcache.insert(key, voff, value, pinned=not sync_value)
            self.dead_tracker.on_write(voff)
            mem_type, mem_val = kTypeValuePtr, voff.encode()
        else:
            mem_type, mem_val = type_, value

        with self.mutex:
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            self._maybe_stall_locked()
            self._seq += 1
            seq = self._seq
            if self.wal is not None:
                self.wal.append(encode_entries(seq, [(mem_type, key, mem_val)]))
            prev = self.mem.add(seq, mem_type, key, mem_val)
            if prev is not None and prev[1] == kTypeValuePtr:
                self.dead_tracker.on_dead(ValueOffset.decode(prev[2]))
            self.stats.mark_user_write(len(key) + len(value))
            if self.mem.approximate_size >= cfg.memtable_size:
                self._rotate_memtable_locked()

    def _maybe_stall_locked(self) -> None:
        cfg = self.cfg
        t0 = None
        while (
            len(self.immutables) >= cfg.max_immutables
            or len(self.versions.current.levels[0]) >= cfg.l0_stop_trigger
        ):
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            if t0 is None:
                t0 = time.monotonic()
            self.worker.signal()
            self.writer_cv.wait(timeout=0.05)
        if t0 is not None:
            self.stats.add_stall(time.monotonic() - t0)
        l0 = len(self.versions.current.levels[0])
        if l0 >= cfg.l0_slowdown_trigger:
            # RocksDB delayed-write: back off proportionally to L0 excess.
            delay = min(0.001 * (l0 - cfg.l0_slowdown_trigger + 1), 0.01)
            self.stats.add_stall(delay)
            time.sleep(delay)

    def _rotate_memtable_locked(self) -> None:
        if self.wal is not None:
            self.wal.flush()
            self.wal.close()
        self.immutables.append(self.mem)
        self.mem = MemTable()
        self._open_wal()
        self.worker.signal()

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: bytes) -> bytes | None:
        with self.mutex:
            tables = [self.mem, *reversed(self.immutables)]
            version = self.versions.current
        for t in tables:
            found, type_, value = t.get(key)
            if found:
                return self._resolve(key, type_, value)
        for _level, fmeta in version.candidates_for_get(key):
            reader = self.versions.reader(fmeta.file_no)
            found, _seq, type_, value = reader.get(key)
            if found:
                return self._resolve(key, type_, value)
        return None

    def _resolve(self, key: bytes, type_: int, value: bytes) -> bytes | None:
        if type_ == kTypeDeletion:
            return None
        if type_ == kTypeValue:
            return value
        voff = ValueOffset.decode(value)
        cached = self.bvcache.get_if_unpersisted(
            key, voff, pinned_only=not self.cfg.bvcache_enabled
        )
        if cached is not None:
            self.bvcache.hits += 1
            return cached
        self.bvcache.misses += 1
        return self.bvalue.get(voff, verify=self.cfg.paranoid_checks)

    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Range scan: merged view across memtables + all levels."""
        with self.mutex:
            mems = [self.mem, *reversed(self.immutables)]
            version = self.versions.current
        iters = [m.range_items(start, None) for m in mems]
        for f in version.levels[0]:
            if f.largest >= start:
                iters.append(self.versions.reader(f.file_no).iter_from(start))
        for level in range(1, len(version.levels)):
            for f in version.levels[level]:
                if f.largest >= start:
                    iters.append(self.versions.reader(f.file_no).iter_from(start))
        out: list[tuple[bytes, bytes]] = []
        last = None
        for key, _seq, type_, value in _merge_iters(iters):
            if key == last:
                continue
            last = key
            resolved = self._resolve(key, type_, value)
            if resolved is None:
                continue
            out.append((key, resolved))
            if len(out) >= count:
                break
        return out

    # ------------------------------------------------------------------
    # maintenance / lifecycle
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Rotate + flush all memtables; barrier on value/WAL persistence."""
        with self.mutex:
            if len(self.mem):
                self._rotate_memtable_locked()
        self.wait_idle(compactions=False)
        self.bvalue.flush()
        if self.wal is not None:
            self.wal.flush()

    def wait_idle(self, compactions: bool = True, timeout: float = 120.0) -> None:
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            with self.mutex:
                busy = bool(self.immutables)
            if not busy and compactions:
                busy = self.worker.compactor.pick() is not None
            if not busy:
                return
            self.worker.signal()
            time.sleep(0.005)
        raise TimeoutError("wait_idle timed out")

    def gc_collect(self, threshold: float = 0.5) -> dict:
        """Reclaim BValue files whose dead ratio ≥ threshold (beyond-paper
        extension — see core/gc.py)."""
        return BValueGC(self, threshold).collect()

    def compact_all(self) -> None:
        """Drive compaction to quiescence (test/benchmark helper)."""
        self.wait_idle(compactions=True)

    def close(self, crash: bool = False) -> None:
        if self._closed:
            return
        self._closed = True
        if not crash:
            self.bvalue.flush()
        self.worker.stop() if not crash else self._crash_stop_worker()
        if self.wal is not None:
            self.wal.close(drop_buffered=crash)
        self.bvalue.close()
        self.versions.close()

    def _crash_stop_worker(self) -> None:
        # crash simulation: stop the worker without flushing memtables
        with self.worker.cv:
            self.worker._stop = True
            self.worker.cv.notify()
        # prevent the "stop" path from seeing pending work
        with self.mutex:
            self.immutables.clear()
        self.worker.join(timeout=30)

    # convenience --------------------------------------------------------
    def __enter__(self) -> "DB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
