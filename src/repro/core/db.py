"""BVLSM DB facade — put/get/delete/scan/write(WriteBatch) + recovery.

One engine, three systems (see :mod:`.config`): ``separation_mode`` selects
where key–value separation happens. The BVLSM path (§III-B of the paper):

WAL-enabled::

    value --fsync--> BValue file            (multi-queue, parallel)
    Key-ValueOffset --append/fsync--> WAL   (tiny record)
    Key-ValueOffset --> MemTable --> SSTable

WAL-disabled / async::

    value --> BVCache (pinned) --> background batch write --> BValue file
    Key-ValueOffset --> MemTable (--> buffered WAL in async mode)

Write pipeline (group commit)
-----------------------------

Commits run through a RocksDB-style leader/follower writer group
(JoinBatchGroup). Every commit — a :class:`~.writebatch.WriteBatch` or the
single-entry batches behind ``put``/``delete`` — performs WAL-time value
separation *outside* the DB mutex (big values fan out across the BValue
queues via ``put_many``, one fsync per queue per batch), then enqueues on
the writer queue:

* the writer at the head becomes the **leader**: it drains the queue up to
  ``wal_group_max_{batches,entries,bytes}``, assigns each batch a sequence
  number, and releases the DB mutex while it persists the whole group with
  ONE ``WALWriter.append_many`` call — a single write + (sync mode) a
  single fsync for every writer in the group;
* **followers** block until the leader marks them done; their ack carries
  full durability in sync mode because their record was in the leader's
  fsynced blob;
* the leader then re-acquires the mutex, applies every batch to the
  MemTable in bulk (``add_batch``), wakes the group, and hands leadership
  to the next queued writer.

``wal_group_commit=False`` restores the pre-pipeline one-record-one-fsync
path (the benchmark baseline); ``EngineStats`` exposes the group-size
histogram and ``fsyncs_per_write`` so the amortization is observable.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque

from .bvalue import BValueManager
from .bvcache import BVCache
from .gc import BValueGC, DeadValueTracker
from .compaction import BackgroundWorker, _merge_iters
from .config import DBConfig
from .manifest import VersionSet
from .memtable import MemTable
from .record import (
    ValueOffset,
    decode_entries,
    encode_entries,
    kTypeDeletion,
    kTypeValue,
    kTypeValuePtr,
)
from .stats import EngineStats
from .wal import WALWriter, replay_wal
from .writebatch import WriteBatch


class _Writer:
    """One queued commit: a batch's memtable-ready entries + ack state.

    ``user_bytes`` is the pre-separation payload (stats); ``entry_bytes`` is
    the post-separation size — what actually lands in the WAL record — and
    is what group formation charges against ``wal_group_max_bytes``, so a
    batch of separated big values (tiny ValueOffset entries) doesn't
    spuriously cap the group."""

    __slots__ = ("entries", "count", "user_bytes", "entry_bytes", "seq", "done", "error")

    def __init__(self, entries: list[tuple[int, bytes, bytes]], user_bytes: int):
        self.entries = entries
        self.count = len(entries)
        self.user_bytes = user_bytes
        self.entry_bytes = sum(len(k) + len(v) for _, k, v in entries)
        self.seq = 0
        self.done = False
        self.error: BaseException | None = None


class DB:
    def __init__(self, path: str, cfg: DBConfig | None = None):
        self.path = path
        self.cfg = cfg or DBConfig()
        os.makedirs(path, exist_ok=True)
        self.stats = EngineStats()
        self.mutex = threading.RLock()
        self.writer_cv = threading.Condition(self.mutex)
        # group-commit writer queue: head = leader, rest = followers
        self._writers: deque[_Writer] = deque()
        self._group_cv = threading.Condition(self.mutex)
        self._commit_in_flight = False  # leader is writing WAL outside mutex

        self.versions = VersionSet(path, self.cfg.num_levels)
        self.versions.open()
        self._seq = self.versions.last_seq

        self.bvcache = BVCache(self.cfg.bvcache_bytes, self.cfg.bvcache_policy)
        self.dead_tracker = DeadValueTracker()
        self.bvalue = BValueManager(
            os.path.join(path, "bvalue"),
            num_queues=self.cfg.num_bvalue_queues,
            async_writes=True,
            dispatch=self.cfg.bvalue_dispatch,
            page_size=self.cfg.bvalue_page_size,
            batch_bytes=self.cfg.bvalue_batch_bytes,
            max_file_bytes=self.cfg.bvalue_max_file_bytes,
            gather_window_s=self.cfg.bvalue_gather_window_s,
            stats=self.stats,
            on_persisted=self.bvcache.unpin,
            on_persisted_many=self.bvcache.unpin_many,
            next_file_id=self.versions.bvalue_next_file_id,
        )

        self.mem = MemTable()
        self.immutables: list[MemTable] = []
        self._wal_no = 0
        self.wal: WALWriter | None = None
        self._recover()
        self._open_wal()

        self.worker = BackgroundWorker(self)
        self.worker.start()
        self._closed = False

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _wal_path(self, no: int) -> str:
        return os.path.join(self.path, f"wal_{no:06d}.log")

    def _recover(self) -> None:
        logs = sorted(
            f for f in os.listdir(self.path) if f.startswith("wal_") and f.endswith(".log")
        )
        for name in logs:
            no = int(name[4:-4])
            self._wal_no = max(self._wal_no, no + 1)
            for payload in replay_wal(os.path.join(self.path, name)):
                seq, entries = decode_entries(payload)
                self.mem.add_batch(seq, entries)
                self._seq = max(self._seq, seq)
            os.unlink(os.path.join(self.path, name))

    def _open_wal(self) -> None:
        if self.cfg.wal_mode == "off":
            self.wal = None
            return
        self.wal = WALWriter(
            self._wal_path(self._wal_no),
            mode=self.cfg.wal_mode,
            flush_interval_s=self.cfg.wal_flush_interval_s,
            flush_bytes=self.cfg.wal_flush_bytes,
            stats=self.stats,
        )
        self.mem.wal_no = self._wal_no
        self._wal_no += 1

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        self._commit([(kTypeValue, key, value)])

    def delete(self, key: bytes) -> None:
        self._commit([(kTypeDeletion, key, b"")])

    def write(self, batch: WriteBatch) -> None:
        """Commit a WriteBatch atomically (one WAL record, one seq)."""
        if len(batch):
            self._commit(list(batch._ops))

    def _commit(self, ops: list[tuple[int, bytes, bytes]]) -> None:
        cfg = self.cfg
        # --- Phase 1: WAL-time separation happens OUTSIDE the DB mutex and
        # outside the writer group: parallel callers stream values onto
        # different queues concurrently; a batch's big values fan out across
        # ALL queues in one put_many call before the leader commits. ---
        user_bytes = 0
        big_idx: list[int] = []
        for i, (type_, key, value) in enumerate(ops):
            user_bytes += len(key) + len(value)
            if (
                type_ == kTypeValue
                and cfg.separation_mode == "wal"
                and len(value) >= cfg.value_threshold
            ):
                big_idx.append(i)
        if big_idx:
            sync_value = cfg.wal_mode == "sync"
            on_reserved = None
            if not sync_value:
                # async path: the pinned insert must land BEFORE the value is
                # handed to a writer thread, or the persist-completion unpin
                # could fire first and the entry would stay pinned forever.
                def on_reserved(key, voff, value):
                    self.bvcache.insert(key, voff, value, pinned=True)

            voffs = self.bvalue.put_many(
                [(ops[i][1], ops[i][2]) for i in big_idx],
                sync=sync_value,
                on_reserved=on_reserved,
            )
            for i, voff in zip(big_idx, voffs):
                _, key, value = ops[i]
                if sync_value:
                    self.bvcache.insert(key, voff, value, pinned=False)
                self.dead_tracker.on_write(voff)
                ops[i] = (kTypeValuePtr, key, voff.encode())

        # --- Phase 2: join the write group. ---
        w = _Writer(ops, user_bytes)
        with self.mutex:
            self._writers.append(w)
            # check done FIRST: once the leader pops + acks the group, w is
            # no longer in the deque (which may even be empty).
            while not w.done and self._writers[0] is not w:
                self._group_cv.wait()
            if not w.done:
                self._lead_group_locked(w)
        if w.error is not None:
            raise w.error

    def _lead_group_locked(self, leader: _Writer) -> None:
        """Called with the mutex held by the writer at the queue head: commit
        the head run of the queue as one group, then wake everyone."""
        cfg = self.cfg
        group = [leader]
        err: BaseException | None = None
        try:
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            self._maybe_stall_locked()
        except BaseException as e:  # fail fast: only the leader is charged
            err = e
        if err is None:
            # form the group AFTER the stall so late arrivals ride along
            if cfg.wal_group_commit:
                n_entries, n_bytes = leader.count, leader.entry_bytes
                for w in list(self._writers)[1:]:
                    if (
                        len(group) >= cfg.wal_group_max_batches
                        or n_entries + w.count > cfg.wal_group_max_entries
                        or n_bytes + w.entry_bytes > cfg.wal_group_max_bytes
                    ):
                        break
                    group.append(w)
                    n_entries += w.count
                    n_bytes += w.entry_bytes
            for w in group:
                self._seq += 1
                w.seq = self._seq
            wal = self.wal
            if wal is not None:
                # WAL encode + I/O without the mutex: entries are immutable
                # once queued, so new writers keep enqueueing and the BValue
                # queues keep streaming while we serialize and fsync. Group
                # members stay at the queue head, so no second leader can
                # emerge; _commit_in_flight keeps flush() from rotating the
                # memtable out from under this commit.
                self._commit_in_flight = True
                self.mutex.release()
                try:
                    wal.append_many([encode_entries(w.seq, w.entries) for w in group])
                except BaseException as e:
                    err = e
                finally:
                    self.mutex.acquire()
                    self._commit_in_flight = False
        if err is None:
            try:
                total_entries = 0
                total_bytes = 0
                for w in group:
                    prevs = self.mem.add_batch(w.seq, w.entries)
                    for prev in prevs:
                        if prev[1] == kTypeValuePtr:
                            self.dead_tracker.on_dead(ValueOffset.decode(prev[2]))
                    total_entries += w.count
                    total_bytes += w.user_bytes
                self.stats.mark_user_writes(total_entries, total_bytes)
                self.stats.record_group(len(group), total_entries)
            except BaseException as e:  # must still ack the group below, or
                err = e  # every current and future writer deadlocks
        for w in group:
            popped = self._writers.popleft()
            assert popped is w, "writer queue out of order"
            w.error = err
            w.done = True
        self._group_cv.notify_all()
        if err is None and self.mem.approximate_size >= self.cfg.memtable_size:
            self._rotate_memtable_locked()

    def _maybe_stall_locked(self) -> None:
        cfg = self.cfg
        t0 = None
        while (
            len(self.immutables) >= cfg.max_immutables
            or len(self.versions.current.levels[0]) >= cfg.l0_stop_trigger
        ):
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            if t0 is None:
                t0 = time.monotonic()
            self.worker.signal()
            self.writer_cv.wait(timeout=0.05)
        if t0 is not None:
            self.stats.add_stall(time.monotonic() - t0)
        l0 = len(self.versions.current.levels[0])
        if l0 >= cfg.l0_slowdown_trigger:
            # RocksDB delayed-write: back off proportionally to L0 excess.
            delay = min(0.001 * (l0 - cfg.l0_slowdown_trigger + 1), 0.01)
            self.stats.add_stall(delay)
            time.sleep(delay)

    def _rotate_memtable_locked(self) -> None:
        if self.wal is not None:
            self.wal.flush()
            self.wal.close()
        self.immutables.append(self.mem)
        self.mem = MemTable()
        self._open_wal()
        self.worker.signal()

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: bytes) -> bytes | None:
        with self.mutex:
            tables = [self.mem, *reversed(self.immutables)]
            version = self.versions.current
        for t in tables:
            found, type_, value = t.get(key)
            if found:
                return self._resolve(key, type_, value)
        for _level, fmeta in version.candidates_for_get(key):
            reader = self.versions.reader(fmeta.file_no)
            found, _seq, type_, value = reader.get(key)
            if found:
                return self._resolve(key, type_, value)
        return None

    def _resolve(self, key: bytes, type_: int, value: bytes) -> bytes | None:
        if type_ == kTypeDeletion:
            return None
        if type_ == kTypeValue:
            return value
        voff = ValueOffset.decode(value)
        cached = self.bvcache.get_if_unpersisted(
            key, voff, pinned_only=not self.cfg.bvcache_enabled
        )
        if cached is not None:
            self.bvcache.hits += 1
            return cached
        self.bvcache.misses += 1
        return self.bvalue.get(voff, verify=self.cfg.paranoid_checks)

    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Range scan: merged view across memtables + all levels."""
        with self.mutex:
            mems = [self.mem, *reversed(self.immutables)]
            version = self.versions.current
        iters = [m.range_items(start, None) for m in mems]
        for f in version.levels[0]:
            if f.largest >= start:
                iters.append(self.versions.reader(f.file_no).iter_from(start))
        for level in range(1, len(version.levels)):
            for f in version.levels[level]:
                if f.largest >= start:
                    iters.append(self.versions.reader(f.file_no).iter_from(start))
        out: list[tuple[bytes, bytes]] = []
        last = None
        for key, _seq, type_, value in _merge_iters(iters):
            if key == last:
                continue
            last = key
            resolved = self._resolve(key, type_, value)
            if resolved is None:
                continue
            out.append((key, resolved))
            if len(out) >= count:
                break
        return out

    # ------------------------------------------------------------------
    # maintenance / lifecycle
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Rotate + flush all memtables; barrier on value/WAL persistence."""
        with self.mutex:
            # a leader mid-commit has unapplied entries targeting the current
            # WAL/memtable pair — rotating now would strand them.
            while self._commit_in_flight:
                self._group_cv.wait()
            if len(self.mem):
                self._rotate_memtable_locked()
        self.wait_idle(compactions=False)
        self.bvalue.flush()
        if self.wal is not None:
            self.wal.flush()

    def wait_idle(self, compactions: bool = True, timeout: float = 120.0) -> None:
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            if self.worker.error is not None:
                raise RuntimeError("background worker failed") from self.worker.error
            with self.mutex:
                busy = bool(self.immutables)
            if not busy and compactions:
                busy = self.worker.compactor.pick() is not None
            if not busy:
                return
            self.worker.signal()
            time.sleep(0.005)
        raise TimeoutError("wait_idle timed out")

    def gc_collect(self, threshold: float = 0.5) -> dict:
        """Reclaim BValue files whose dead ratio ≥ threshold (beyond-paper
        extension — see core/gc.py)."""
        return BValueGC(self, threshold).collect()

    def compact_all(self) -> None:
        """Drive compaction to quiescence (test/benchmark helper)."""
        self.wait_idle(compactions=True)

    def close(self, crash: bool = False) -> None:
        if self._closed:
            return
        self._closed = True
        if not crash:
            self.bvalue.flush()
        self.worker.stop() if not crash else self._crash_stop_worker()
        if self.wal is not None:
            self.wal.close(drop_buffered=crash)
        self.bvalue.close()
        self.versions.close()

    def _crash_stop_worker(self) -> None:
        # crash simulation: stop the worker without flushing memtables
        with self.worker.cv:
            self.worker._stop_requested = True
            self.worker.cv.notify()
        # prevent the "stop" path from seeing pending work
        with self.mutex:
            self.immutables.clear()
        self.worker.join(timeout=30)

    # convenience --------------------------------------------------------
    def __enter__(self) -> "DB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
