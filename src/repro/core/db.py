"""BVLSM DB facade — put/get/delete/scan/write(WriteBatch) + recovery.

One engine, three systems (see :mod:`.config`): ``separation_mode`` selects
where key–value separation happens. The BVLSM path (§III-B of the paper):

WAL-enabled::

    value --fsync--> BValue file            (multi-queue, parallel)
    Key-ValueOffset --append/fsync--> WAL   (tiny record)
    Key-ValueOffset --> MemTable --> SSTable

WAL-disabled / async::

    value --> BVCache (pinned) --> background batch write --> BValue file
    Key-ValueOffset --> MemTable (--> buffered WAL in async mode)

Write pipeline (pipelined group commit)
---------------------------------------

Commits run through a RocksDB-style leader/follower writer group
(JoinBatchGroup) with a two-stage pipelined handoff. Every commit — a
:class:`~.writebatch.WriteBatch` or the single-entry batches behind
``put``/``delete`` — performs WAL-time value separation *outside* the DB
mutex (big values fan out across the BValue queues via ``put_many``, one
fsync per queue per batch), then enqueues on the writer queue. Commit runs
in three stages:

1. **drain** (mutex held): the queue head becomes the leader, waits for a
   pipeline slot (bounded by ``wal_pipeline_depth``, and gated by
   ``wal_pipeline_min_fill`` so overlapped groups are worth their
   overhead), merges the head run of the queue into one group up to the
   adaptive byte cap / hard entry caps, assigns sequence numbers, and
   reserves a WAL write-order ticket.
2. **persist** (no mutex): frame encoding is lock-free; the file write
   happens strictly in ticket (= sequence) order while the group still
   heads the queue; then the group POPS itself — the **handoff** — and
   fsyncs outside the ordering barrier, so the next leader drains the
   now-refilled queue and encodes + writes its group while this fsync is
   in flight. A group whose ticket a later-started fsync already covered
   skips its own (at most one fsync runs at a time; piled-up groups ride
   the next one).
3. **publish** (mutex held, sequence order): groups apply to the MemTable
   oldest-first — in bulk (``add_batch``), or hash-sharded across a worker
   pool when the group is huge — then wake their followers. A group is
   never visible unless every earlier-sequence group is durable.

**Adaptive group sizing** replaces the fixed byte cap: a latency-target
controller grows/shrinks the effective cap from the persist-latency EWMA
(see ``DBConfig.wal_group_target_latency_s``).

``wal_pipelined_commit=False`` restores PR 1's single-outstanding-group
commit (pipeline depth 1); ``wal_group_commit=False`` restores the
pre-pipeline one-record-one-fsync path (the benchmark baseline).
``EngineStats`` exposes the group-size and pipeline-depth histograms,
``fsyncs_per_write``, and the adaptive-cap gauges so all three
optimizations are observable.

Background work (flush, compaction, GC) runs on the prioritized job
scheduler (:mod:`.scheduler`), with writer throttling handled by the
continuous delayed-write controller in :meth:`DB._maybe_stall_locked` and
background output bytes paced by the shared token bucket
(:mod:`.ratelimiter`). See ``docs/ARCHITECTURE.md`` §"Background jobs".
"""
from __future__ import annotations

import bisect
import os
import threading
import time
import warnings
from collections import deque

import msgpack

from .blockcache import BlockCache
from .bvalue import BValueManager
from .bvcache import BVCache
from .gc import DeadValueTracker
from .compaction import _merge_iters
from .config import DBConfig
from .env import DEFAULT_ENV
from .errors import CorruptionError, ErrorHandler, SnapshotUnstableError
from .manifest import MANIFEST_NAME, VersionSet
from .memtable import MemTable
from .ratelimiter import PRI_FG, PRI_LOW, RateLimiter
from .scheduler import BackgroundCoordinator, WriteController
from .record import (
    MAX_SEQ,
    ValueOffset,
    decode_entries,
    encode_entries,
    frame_record,
    iter_framed_records_ex,
    kTypeDeletion,
    kTypeRangeDeletion,
    kTypeValue,
    kTypeValuePtr,
)
from .sstable import table_path
from .stats import EngineStats
from .wal import WALWriter
from .writebatch import WriteBatch


class _Writer:
    """One queued commit: a batch's memtable-ready entries + ack state.

    ``user_bytes`` is the pre-separation payload (stats); ``entry_bytes`` is
    the post-separation size — what actually lands in the WAL record — and
    is what group formation charges against ``wal_group_max_bytes``, so a
    batch of separated big values (tiny ValueOffset entries) doesn't
    spuriously cap the group.

    ``precondition`` (RocksDB WriteCallback analogue) makes the commit
    conditional: the group leader evaluates it under the DB mutex at
    seq-assignment time and, if it fails — or an earlier batch in the same
    group writes one of this batch's keys — the batch is emptied and acked
    with ``skipped=True`` instead of being written. GC value rewrites use
    this so a concurrent foreground overwrite can never be shadowed by a
    resurrected stale value."""

    __slots__ = (
        "entries", "count", "user_bytes", "entry_bytes", "seq", "done", "error",
        "precondition", "skipped",
    )

    def __init__(
        self,
        entries: list[tuple[int, bytes, bytes]],
        user_bytes: int,
        precondition=None,
    ):
        self.entries = entries
        self.count = len(entries)
        self.user_bytes = user_bytes
        self.entry_bytes = sum(len(k) + len(v) for _, k, v in entries)
        self.seq = 0
        self.done = False
        self.error: BaseException | None = None
        self.precondition = precondition
        self.skipped = False


class _Group:
    """One in-flight commit group: the writers drained by a leader, plus
    the WAL write-order ticket that pins its position in the pipeline."""

    __slots__ = ("writers", "ticket")

    def __init__(self, writers: list[_Writer]):
        self.writers = writers
        self.ticket: int | None = None


class Snapshot:
    """A pinned read point (RocksDB ``GetSnapshot`` analogue).

    Reads through it (``db.get(key, snapshot=snap)``, ``db.iterator(snap)``)
    see exactly the state visible at creation: writes published later are
    invisible and deletes published later do not hide anything. While a
    snapshot is live the engine retains what it can still see — memtables
    keep superseded versions, compaction keeps shadowed versions and range
    tombstones alive (stripe dedup in :mod:`.compaction`), and BValue GC
    defers file unlinks. Always :meth:`release` (or use as a context
    manager): a leaked snapshot widens retention forever, and
    ``DBConfig.max_snapshots`` hard-caps the live count for that reason."""

    __slots__ = ("seq", "_db", "_released")

    def __init__(self, db: "DB", seq: int):
        self._db = db
        self.seq = seq
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._db._release_snapshot_seq(self.seq)

    def __enter__(self) -> "Snapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "released" if self._released else "live"
        return f"<Snapshot seq={self.seq} {state}>"


class Cursor:
    """Stable bidirectional iterator over one MVCC read point.

    The constructor captures (memtables, version, read_seq) under the DB
    mutex, registers the read point as a snapshot, and pins the version
    (``VersionSet.pin``), so concurrent flushes/compactions/GC cannot close
    or unlink anything the walk needs: dropped readers are parked and input
    unlinks are deferred until the cursor closes. Forward iteration is the
    same lazy heap merge as ``scan`` — a sorted level opens a file only
    when the merge reaches it — plus MVCC filtering: versions newer than
    the read point are skipped, the first visible version per user key
    decides it, and point-/range-deleted keys are elided.

    Range tombstones vs laziness: a sorted-level file's tombstone can span
    keys *below* its first point key — keys served by other sources before
    the lazy concat would ever open that file. Each sorted level therefore
    keeps a discovery pointer that advances whenever the merge cursor
    reaches a file's (tombstone-extended) smallest key, registering that
    file's tombstones before any key they could cover is emitted. A short
    scan still opens O(levels) files: the pointer only opens files whose
    range the cursor actually enters.

    ``prev()`` steps backward without materialized reverse iterators: take
    the max over all sources of ``largest_key_below(bound)``, resolve that
    candidate with a point lookup on the pinned state, and keep walking
    down while candidates turn out deleted at the read point."""

    __slots__ = (
        "_db", "_snap", "_own_snap", "read_seq", "_mems", "_version",
        "_pinned", "_tombs", "_tomb_files", "_lvl_files", "_lvl_ptr",
        "_merged", "_skip_key", "key", "value", "valid", "_closed",
    )

    def __init__(self, db: "DB", snapshot: Snapshot | None = None):
        self._db = db
        self._own_snap = snapshot is None
        self._snap = db.snapshot() if snapshot is None else snapshot
        self.read_seq = self._snap.seq
        with db.mutex:
            self._mems = [db.mem, *reversed(db.immutables)]
            # atomic capture+pin: a plain ``current`` read here could race
            # a compaction's edit + input unlink (versions have their own
            # lock — the DB mutex does not exclude background edits)
            self._version = db.versions.pin_current()
        self._pinned = True
        # range tombstones discovered from table files so far (pre-filtered
        # to seq <= read_seq); memtable tombstones are consulted live.
        self._tombs: list[tuple[int, bytes, bytes]] = []
        self._tomb_files: set[int] = set()
        # per-sorted-level discovery pointers (see class docstring)
        self._lvl_files = [
            self._version.levels[lvl]
            for lvl in range(1, len(self._version.levels))
        ]
        self._lvl_ptr = [0] * len(self._lvl_files)
        self._merged = None
        self._skip_key: bytes | None = None
        self.key: bytes | None = None
        self.value: bytes | None = None
        self.valid = False
        self._closed = False

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._merged = None
        self.valid = False
        if self._pinned:
            self._pinned = False
            self._db.versions.unpin()
        if self._own_snap:
            self._snap.release()

    def __enter__(self) -> "Cursor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- range-tombstone discovery --------------------------------------
    def _register_file_tombs(self, fmeta) -> None:
        if fmeta.file_no in self._tomb_files:
            return
        self._tomb_files.add(fmeta.file_no)
        for t in self._db.versions.reader(fmeta.file_no).range_tombstones:
            if t[0] <= self.read_seq:
                self._tombs.append(t)

    def _advance_tomb_ptrs(self, key: bytes) -> None:
        # register every sorted-level file whose (tombstone-extended) range
        # has started by ``key`` — before the merge can emit a covered key
        for li, files in enumerate(self._lvl_files):
            p = self._lvl_ptr[li]
            while p < len(files) and files[p].smallest <= key:
                self._register_file_tombs(files[p])
                p += 1
            self._lvl_ptr[li] = p

    def _tomb_seq(self, key: bytes) -> int:
        best = 0
        for m in self._mems:
            ts = m.covering_tombstone_seq(key, self.read_seq)
            if ts > best:
                best = ts
        for seq, start, end in self._tombs:
            if start <= key < end and seq > best:
                best = seq
        return best

    # -- forward iteration ----------------------------------------------
    def seek(self, target: bytes) -> bool:
        """Position on the first visible key >= ``target``; returns
        ``valid``."""
        self._build_merged(target)
        self._skip_key = None
        return self._advance()

    def seek_to_first(self) -> bool:
        return self.seek(b"")

    def _build_merged(self, start: bytes) -> None:
        db = self._db
        iters = [m.iter_versions_from(start) for m in self._mems]
        for f in self._version.levels[0]:
            if f.largest >= start:
                self._register_file_tombs(f)
                iters.append(db.versions.reader(f.file_no).iter_from(start))
        for li, files in enumerate(self._lvl_files):
            # reset the discovery pointer: files entirely below ``start``
            # are irrelevant (a tombstone's end bounds the file's largest)
            lo, hi = 0, len(files)
            while lo < hi:
                mid = (lo + hi) // 2
                if files[mid].largest < start:
                    lo = mid + 1
                else:
                    hi = mid
            self._lvl_ptr[li] = lo
            if lo < len(files):
                iters.append(self._concat(files[lo:], start))
        self._merged = _merge_iters(iters)

    def _concat(self, files, start: bytes):
        first = True
        for f in files:
            self._register_file_tombs(f)
            it = self._db.versions.reader(f.file_no).iter_from(
                start if first else f.smallest
            )
            first = False
            yield from it

    def next(self) -> bool:
        """Advance to the next visible key; returns ``valid``."""
        if self._merged is None:
            # forward state was invalidated by a prev() — rebuild past key
            if not self.valid:
                return False
            self._build_merged(self.key)
            self._skip_key = self.key
        return self._advance()

    def _advance(self) -> bool:
        db = self._db
        for key, seq, type_, value in self._merged:
            if seq > self.read_seq:
                continue  # newer than the read point
            if key == self._skip_key:
                continue  # this user key is already decided
            self._skip_key = key  # first visible version decides the key
            self._advance_tomb_ptrs(key)
            if type_ == kTypeDeletion or seq < self._tomb_seq(key):
                continue  # point- or range-deleted at the read point
            resolved = db._resolve(key, type_, value)
            if resolved is None:
                continue
            self.key = key
            self.value = resolved
            self.valid = True
            return True
        self.key = None
        self.value = None
        self.valid = False
        return False

    # -- reverse iteration ----------------------------------------------
    def prev(self) -> bool:
        """Step to the largest visible key strictly below the current one
        (below infinity when invalid: an invalid cursor's ``prev`` is a
        seek-to-last). Returns ``valid``."""
        bound = self.key if self.valid else None
        self._merged = None  # forward state is stale after a reverse step
        while True:
            cand = self._largest_below(bound)
            if cand is None:
                self.key = None
                self.value = None
                self.valid = False
                return False
            resolved = self._db._lookup_at(
                cand, self.read_seq, self._mems, self._version
            )
            if resolved is not None:
                self.key = cand
                self.value = resolved
                self.valid = True
                return True
            bound = cand  # deleted at the read point — keep walking down

    def _largest_below(self, bound: bytes | None) -> bytes | None:
        db = self._db
        best = None
        for m in self._mems:
            k = m.largest_key_below(bound)
            if k is not None and (best is None or k > best):
                best = k
        for f in self._version.levels[0]:
            k = db.versions.reader(f.file_no).largest_key_below(bound)
            if k is not None and (best is None or k > best):
                best = k
        for files in self._lvl_files:
            # rightmost file that could hold point keys < bound; walk left
            # past tombstone-only tails (extended bounds may hold no point
            # key below the bound at all)
            i = len(files) - 1
            if bound is not None:
                lo, hi = 0, len(files)
                while lo < hi:
                    mid = (lo + hi) // 2
                    if files[mid].smallest < bound:
                        lo = mid + 1
                    else:
                        hi = mid
                i = lo - 1
            while i >= 0:
                k = db.versions.reader(files[i].file_no).largest_key_below(bound)
                if k is not None:
                    if best is None or k > best:
                        best = k
                    break
                i -= 1
        return best


class DB:
    def __init__(self, path: str, cfg: DBConfig | None = None, role: str = "primary"):
        self.path = path
        self.cfg = cfg or DBConfig()
        if self.cfg.replica_of is not None:
            role = "replica"
        if role not in ("primary", "replica"):
            raise ValueError(f"DB role must be 'primary' or 'replica', got {role!r}")
        # replicas reject user writes (check_writable) and disable GC; the
        # replication stream applies through _follower until promote()
        self._role = role
        self._repl = None  # primary-side Replicator once a follower attaches
        self._follower = None  # replica-side Follower once attached
        # pluggable filesystem: every open/read/write/fsync/rename/unlink in
        # the engine routes through this (tests inject FaultInjectionEnv)
        self.env = self.cfg.env or DEFAULT_ENV
        self.env.makedirs(path)
        self.stats = EngineStats()
        self.errors = ErrorHandler(self)
        self.mutex = threading.RLock()
        self.writer_cv = threading.Condition(self.mutex)
        # group-commit writer queue: head = leader, rest = followers
        self._writers: deque[_Writer] = deque()
        self._group_cv = threading.Condition(self.mutex)
        # pipelined commit: groups in flight, oldest first. Publication is
        # strictly in this order (no commit-order hole).
        self._pending: deque[_Group] = deque()
        # live snapshot registry: read-point seq -> refcount (several
        # snapshots can share one seq). Guarded by the mutex; compaction,
        # GC and the memtable retain path all consult it.
        self._snapshots: dict[int, int] = {}
        self._publish_cv = threading.Condition(self.mutex)  # publish-order barrier
        self._pipeline_cv = threading.Condition(self.mutex)  # slot/rotation waits
        self._rotation_pending = False  # rotate once the pipeline drains
        # adaptive group sizing (latency-target controller)
        self._group_cap_bytes = min(
            max(self.cfg.wal_group_init_bytes, self.cfg.wal_group_min_bytes),
            self.cfg.wal_group_max_bytes,
        )
        self._persist_ewma: float | None = None
        self._mt_pool = None  # lazy ThreadPoolExecutor for sharded apply

        # shared decoded-block cache (read path): one 2Q/LRU for every
        # SSTable reader — foreground gets, scans, and (read-through only,
        # by default) compaction. None when disabled so readers skip lookups.
        self.block_cache = (
            BlockCache(
                self.cfg.block_cache_bytes,
                self.cfg.block_cache_shards,
                policy=self.cfg.block_cache_policy,
                a1_fraction=self.cfg.block_cache_a1_fraction,
            )
            if self.cfg.block_cache_bytes > 0
            else None
        )
        self.stats.register_block_cache(self.block_cache)
        self.versions = VersionSet(
            path,
            self.cfg.num_levels,
            self.block_cache,
            env=self.env,
            paranoid=self.cfg.paranoid_checks,
        )
        self.versions.open()
        self._seq = self.versions.last_seq

        # shared token bucket for every accounted byte: background writes
        # (compaction output, flush tables, GC rewrites) block or defer on
        # it, and — under the unified budget — foreground BValue dispatches
        # charge it at PRI_FG, shrinking the background refill. rate 0 =
        # unlimited, zero overhead.
        self.rate_limiter = RateLimiter(
            self.cfg.bg_io_bytes_per_sec,
            self.cfg.bg_io_refill_period_s,
            stats=self.stats,
            bg_min_fraction=self.cfg.bg_io_min_fraction,
        )
        # continuous delayed-write controller state (leader-only, under mutex).
        # _delay_debt accumulates every published group's post-separation
        # bytes; the next leader entering the delay region pays for ALL of
        # it, so the aggregate ingest tracks the controller rate even though
        # followers never lead (charging only the leader's own batch would
        # let a group commit ~group-size times the target rate).
        self._write_controller = WriteController(self.cfg)
        self._delay_debt = 0
        # GC rewrites re-enter the foreground write path from a background
        # thread; this marker exempts them from the hard stall (they would
        # otherwise deadlock a single-thread low pool waiting on themselves)
        self._bg_local = threading.local()

        self.bvcache = BVCache(self.cfg.bvcache_bytes, self.cfg.bvcache_policy)
        self.dead_tracker = DeadValueTracker()
        self.bvalue = BValueManager(
            os.path.join(path, "bvalue"),
            num_queues=self.cfg.num_bvalue_queues,
            async_writes=True,
            dispatch=self.cfg.bvalue_dispatch,
            page_size=self.cfg.bvalue_page_size,
            batch_bytes=self.cfg.bvalue_batch_bytes,
            max_file_bytes=self.cfg.bvalue_max_file_bytes,
            gather_window_s=self.cfg.bvalue_gather_window_s,
            stats=self.stats,
            on_persisted=self.bvcache.unpin,
            on_persisted_many=self.bvcache.unpin_many,
            next_file_id=self.versions.bvalue_next_file_id,
            # unified device model: value-log dispatches charge the shared
            # bucket — foreground puts at PRI_FG (never blocked), GC
            # rewrites inherit PRI_LOW from their background initiator
            limiter=self.rate_limiter if self.cfg.unified_io_budget else None,
            io_priority=lambda: (
                PRI_LOW if getattr(self._bg_local, "exempt", False) else PRI_FG
            ),
            env=self.env,
        )

        self.mem = MemTable()
        self.immutables: list[MemTable] = []
        self._wal_no = 0
        self.wal: WALWriter | None = None
        self._recover()
        self._open_wal()

        self._closed = False
        self.bg = BackgroundCoordinator(self)
        self.bg.maybe_schedule()  # recovery may have left flushable state

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _wal_path(self, no: int) -> str:
        return os.path.join(self.path, f"wal_{no:06d}.log")

    def _release_wal(self, path: str, last_seq: int) -> None:
        """A flushed memtable's log is redundant for recovery — but a
        lagging follower may still need it for catch-up, so with followers
        attached the segment is retained until every ack passes its last
        sequence (the Replicator unlinks it then)."""
        repl = self._repl
        if repl is not None and repl.active and repl.should_retain(last_seq):
            repl.retain_wal(path, last_seq)
            return
        try:
            self.env.unlink(path)
        except OSError:
            pass

    def _recover(self) -> None:
        logs = sorted(
            f
            for f in self.env.listdir(self.path)
            if f.startswith("wal_") and f.endswith(".log")
        )
        replayed: list[str] = []
        for name in logs:
            no = int(name[4:-4])
            self._wal_no = max(self._wal_no, no + 1)
            path = os.path.join(self.path, name)
            with self.env.open(path, "rb") as f:
                buf = f.read()
            end = 0
            for payload, end in iter_framed_records_ex(buf):
                seq, entries = decode_entries(payload)
                self.mem.add_batch(seq, entries)
                self._seq = max(self._seq, seq)
            if end < len(buf):
                # torn tail (partial frame or CRC mismatch from a crash
                # mid-append): truncate to the last whole record so nothing
                # can ever parse past the damage
                self.stats.add("wal_truncated_bytes", len(buf) - end)
                with self.env.open(path, "r+b") as f:
                    f.truncate(end)
            if end == 0:
                try:
                    self.env.unlink(path)  # nothing recoverable in it
                except OSError:
                    pass
            else:
                replayed.append(path)
        self._drop_dangling_pointers()
        if len(self.mem) or self.mem.range_tombstones:
            # The recovered entries exist ONLY in memory + these logs, so
            # the logs must outlive them: seal the memtable as an immutable
            # that CARRIES its source logs, and let flush_memtable delete
            # them after the L0 manifest commit. (The old code unlinked the
            # logs right here — a crash before the first flush then lost
            # every previously-acked write.)
            self.mem.recovery_logs = replayed
            self.immutables.append(self.mem)
            self.mem = MemTable()
        else:
            for p in replayed:
                try:
                    self.env.unlink(p)
                except OSError:
                    pass

    def _drop_dangling_pointers(self) -> None:
        """Close the async-WAL separation hole at recovery time.

        Under a buffered WAL there is no ordering barrier between a
        separated value's fsync and its Key-ValueOffset record reaching the
        disk, so a crash can leave a durable pointer to value bytes that
        never made it. Probe every replayed pointer and drop the records
        whose bytes are gone: the key falls back to its previous durable
        version — legal, since an async ack never promised durability —
        instead of every future ``get`` failing on a short read forever.
        (Sync WAL fsyncs the value before appending the pointer, so there
        every probe succeeds by construction.)"""
        dangling = set()
        for key, (_seq, type_, value) in self.mem._table.items():
            if type_ != kTypeValuePtr:
                continue
            try:
                # verify=True: existence is not enough — a dropped write
                # batch can leave a zero-filled hole inside a file a LATER
                # batch extended and fsynced, so the probe must prove the
                # bytes themselves (CRC), not just that the read succeeds
                self.bvalue.get(ValueOffset.decode(value), verify=True)
            except Exception:
                dangling.add(key)
        if not dangling:
            return
        self.stats.add("recovery_dangling_ptrs", len(dangling))
        mem = MemTable()
        for key, (seq, type_, value) in self.mem._table.items():
            if key not in dangling:
                mem.add(seq, type_, key, value)
        # range tombstones ride the same replayed WAL records and must
        # survive the rebuild, or a crash after an acked delete_range
        # silently resurrects every covered key
        for seq, start, end in self.mem.range_tombstones:
            mem._add_range_tombstone(seq, start, end)
        self.mem = mem

    def _open_wal(self) -> None:
        if self.cfg.wal_mode == "off":
            self.wal = None
            return
        self.wal = WALWriter(
            self._wal_path(self._wal_no),
            mode=self.cfg.wal_mode,
            flush_interval_s=self.cfg.wal_flush_interval_s,
            flush_bytes=self.cfg.wal_flush_bytes,
            stats=self.stats,
            env=self.env,
        )
        self.mem.wal_no = self._wal_no
        self._wal_no += 1

    @classmethod
    def open(cls, path: str, config: DBConfig | None = None, **kw) -> "DB":
        """Canonical constructor: open (creating if absent) the store at
        ``path``. Equivalent to ``DB(path, config)`` — the bare constructor
        keeps working — but ``open()`` is the one documented spelling,
        mirrored by ``ShardedDB.open(path, shards=N, config=None)``."""
        return cls(path, config, **kw)

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        """Store ``key -> value``. Values >= ``value_threshold`` (in ``wal``
        separation mode) are streamed to the BValue store first; only a
        ValueOffset rides the WAL/MemTable. Durable on return under sync
        WAL. Thread-safe: concurrent puts merge into commit groups."""
        self._commit([(kTypeValue, key, value)])

    def delete(self, key: bytes) -> None:
        """Write a tombstone for ``key`` (the value, if separated, is
        reclaimed later by ``gc_collect``). Same durability as ``put``."""
        self._commit([(kTypeDeletion, key, b"")])

    def delete_range(self, start: bytes, end: bytes) -> None:
        """Delete every key in ``[start, end)`` with ONE range tombstone —
        one WAL record, one memtable entry: O(1) in the number of covered
        keys. Covered versions become invisible to reads above the
        tombstone's sequence (older snapshots still see them); compaction
        physically drops them — and reports their separated values dead —
        as it encounters them. Same durability as ``put``. Requires
        SSTable format v3 (the tombstone side block)."""
        self._commit([(kTypeRangeDeletion, start, end)])

    def write(self, batch: WriteBatch) -> None:
        """Commit a WriteBatch atomically: all ops share one sequence
        number and one CRC-framed WAL record, so crash replay applies the
        whole batch or none of it. An empty batch is a no-op."""
        if len(batch):
            self._commit(list(batch._ops))

    def _commit(
        self, ops: list[tuple[int, bytes, bytes]], precondition=None
    ) -> bool:
        """Commit one batch; returns False iff a ``precondition`` made the
        leader skip it (see :class:`_Writer`)."""
        cfg = self.cfg
        # fail fast while read-only: don't separate values (phase 1 would
        # write them to the BValue log) for a commit that cannot proceed
        self.errors.check_writable()
        # --- Phase 1: WAL-time separation happens OUTSIDE the DB mutex and
        # outside the writer group: parallel callers stream values onto
        # different queues concurrently; a batch's big values fan out across
        # ALL queues in one put_many call before the leader commits. ---
        user_bytes = 0
        big_idx: list[int] = []
        for i, (type_, key, value) in enumerate(ops):
            if type_ == kTypeRangeDeletion:
                # gate at write time, not flush time: a v<3 table cannot
                # carry the tombstone side block, and failing the flush
                # later would lose an already-acked write
                if cfg.sstable_format_version < 3:
                    raise ValueError(
                        "delete_range requires sstable_format_version >= 3"
                    )
                if not key < value:  # key=start, value=end (exclusive)
                    raise ValueError("delete_range: start must sort before end")
            user_bytes += len(key) + len(value)
            if (
                type_ == kTypeValue
                and cfg.separation_mode == "wal"
                and len(value) >= cfg.value_threshold
            ):
                big_idx.append(i)
        if big_idx:
            sync_value = cfg.wal_mode == "sync"
            on_reserved = None
            if not sync_value:
                # async path: the pinned insert must land BEFORE the value is
                # handed to a writer thread, or the persist-completion unpin
                # could fire first and the entry would stay pinned forever.
                def on_reserved(key, voff, value):
                    self.bvcache.insert(key, voff, value, pinned=True)

            voffs = self.bvalue.put_many(
                [(ops[i][1], ops[i][2]) for i in big_idx],
                sync=sync_value,
                on_reserved=on_reserved,
            )
            for i, voff in zip(big_idx, voffs):
                _, key, value = ops[i]
                if sync_value:
                    self.bvcache.insert(key, voff, value, pinned=False)
                self.dead_tracker.on_write(voff)
                ops[i] = (kTypeValuePtr, key, voff.encode())

        # --- Phase 2: join the write group. ---
        w = _Writer(ops, user_bytes, precondition)
        with self.mutex:
            self._writers.append(w)
            if self._pending:
                self._pipeline_cv.notify()  # a waiting leader may fill up now
            # check done FIRST, and guard the head peek: once a leader
            # drains its group off the queue, w may be in a pending group
            # (not done yet, no longer queued) and the deque may be empty.
            while not w.done and not (self._writers and self._writers[0] is w):
                self._group_cv.wait()
            if not w.done:
                self._lead_group_locked(w)
        if w.error is not None:
            raise w.error
        return not w.skipped

    def _lead_group_locked(self, leader: _Writer) -> None:
        """Called with the mutex held by the writer at the queue head: run
        the three commit stages (drain / persist / publish) for one group.

        The mutex is released during persist; by then the group has been
        popped off the writer queue and parked in ``self._pending``, so the
        next queue head immediately becomes a leader and overlaps its
        encode+write with this group's fsync.
        """
        cfg = self.cfg
        try:
            self.errors.check_writable()
            self._maybe_stall_locked()
        except BaseException as e:  # fail fast: only the leader is charged
            popped = self._writers.popleft()
            assert popped is leader, "writer queue out of order"
            leader.error = e
            leader.done = True
            self._group_cv.notify_all()
            return

        # --- stage 1: drain. Wait for a pipeline slot (we are still the
        # queue head, so nobody else can form a group while we wait), then
        # merge the head run of the queue — late arrivals during the stall
        # and the slot wait ride along.
        depth_cap = (
            cfg.wal_pipeline_depth
            if (cfg.wal_pipelined_commit and cfg.wal_group_commit)
            else 1
        )
        while (
            self._rotation_pending
            or len(self._pending) >= depth_cap
            # min-fill gate: overlapping an in-flight group only pays once
            # enough writers are queued to form a real group; otherwise
            # wait — for more arrivals (enqueues notify) or the drain.
            or (self._pending and len(self._writers) < cfg.wal_pipeline_min_fill)
        ):
            self._pipeline_cv.wait()
        group = [leader]
        if cfg.wal_group_commit:
            cap_bytes = (
                self._group_cap_bytes if cfg.wal_group_adaptive else cfg.wal_group_max_bytes
            )
            n_entries, n_bytes = leader.count, leader.entry_bytes
            for w in list(self._writers)[1:]:
                if (
                    len(group) >= cfg.wal_group_max_batches
                    or n_entries + w.count > cfg.wal_group_max_entries
                    or n_bytes + w.entry_bytes > cap_bytes
                ):
                    break
                group.append(w)
                n_entries += w.count
                n_bytes += w.entry_bytes
        if any(w.precondition is not None for w in group):
            self._check_preconditions_locked(group)
        for w in group:
            self._seq += 1
            w.seq = self._seq
        grp = _Group(group)
        wal = self.wal
        if wal is not None:
            # ticket taken under the mutex right after seq assignment, so
            # WAL file order always equals sequence order
            grp.ticket = wal.reserve()
        self._pending.append(grp)
        self.stats.record_pipeline_depth(len(self._pending))

        # --- stage 2: persist. The group STAYS at the queue head through
        # the (fast) file write — late writers keep piling up behind it —
        # and hands the queue off right before the (slow) fsync: the next
        # leader then drains a well-filled queue and encodes + writes its
        # group while our fsync is in flight. Both halves run OUTSIDE the
        # mutex (entries are immutable once queued; the BValue queues keep
        # streaming).
        err: BaseException | None = None
        persist_s = 0.0
        payloads: list | None = None  # kept for the replication ship below
        t0 = time.monotonic()
        if wal is not None:
            self.mutex.release()
            try:
                try:
                    payloads = [encode_entries(w.seq, w.entries) for w in group]
                except BaseException:
                    # the reserved ticket MUST be consumed or every later
                    # group deadlocks at the write barrier
                    wal.abort_ticket(grp.ticket)
                    raise
                wal.write_many(payloads, grp.ticket)
            except BaseException as e:
                err = e
            finally:
                self.mutex.acquire()
        # handoff point: pop the group; the next queue head becomes leader
        for w in group:
            popped = self._writers.popleft()
            assert popped is w, "writer queue out of order"
        self._group_cv.notify_all()
        if wal is not None and err is None:
            self.mutex.release()
            try:
                wal.sync_ticket(grp.ticket)
                persist_s = time.monotonic() - t0
            except BaseException as e:
                err = e
            finally:
                self.mutex.acquire()
            if err is None and cfg.wal_group_adaptive and cfg.wal_group_commit:
                self._adapt_group_cap_locked(persist_s)

        # --- stage 3: publish in sequence order. Earlier groups are
        # durable AND visible before we are; our followers wake only after
        # both hold for us too.
        while self._pending[0] is not grp:
            self._publish_cv.wait()
        if err is None:
            try:
                total_entries = sum(w.count for w in group)
                total_bytes = sum(w.user_bytes for w in group)
                # post-separation bytes: what actually lands in the LSM and
                # drives compaction debt — the delayed-write controller's
                # currency (paid by the next leader entering the region)
                self._delay_debt += sum(w.entry_bytes for w in group)
                prevs = self._apply_group_locked(group, total_entries)
                had_ptr_dead = False
                for prev in prevs:
                    if prev[1] == kTypeValuePtr:
                        self.dead_tracker.on_dead(ValueOffset.decode(prev[2]))
                        had_ptr_dead = True
                if had_ptr_dead:
                    # memtable overwrites can push a sealed BValue file past
                    # the GC trigger with no flush/compaction edge in sight
                    # — this is the one dead-ratio edge those hooks miss
                    self.bg._maybe_schedule_gc()
                self.stats.mark_user_writes(total_entries, total_bytes)
                self.stats.record_group(len(group), total_entries)
            except BaseException as e:  # must still ack the group below, or
                err = e  # every current and future writer deadlocks
        if err is None and self._repl is not None:
            # ship the committed group, publish-ordered (we hold the mutex;
            # earlier groups shipped before us). Durable-first in sync mode
            # (sync_ticket completed above), post-ack in async. Skipped
            # writers ship as empty payloads so follower seqs stay
            # contiguous. Never fails the client write: a dead transport
            # just leaves the follower to catch up from the WAL.
            try:
                self._repl.on_group(
                    [
                        (
                            w.seq,
                            payloads[i]
                            if payloads is not None
                            else encode_entries(w.seq, w.entries),
                        )
                        for i, w in enumerate(group)
                    ]
                )
            except Exception:
                self.stats.add("repl_ship_errors")
        popped_grp = self._pending.popleft()
        assert popped_grp is grp, "pipeline out of order"
        for w in group:
            w.error = err
            w.done = True
        self._group_cv.notify_all()
        self._publish_cv.notify_all()
        self._pipeline_cv.notify_all()
        # rotation waits for the pipeline to drain: every pending group's
        # WAL record lives in the CURRENT file, and rotating under them
        # would let their entries land in a memtable whose WAL is gone
        # after the old file is dropped at flush.
        if err is None and self.mem.approximate_size >= cfg.memtable_size:
            self._rotation_pending = True
        if self._rotation_pending and not self._pending:
            self._rotation_pending = False
            self._rotate_memtable_locked()
            self._pipeline_cv.notify_all()

    def _check_preconditions_locked(self, group: list[_Writer]) -> None:
        """Evaluate conditional batches (RocksDB WriteCallback analogue)
        under the mutex, at seq-assignment time: any published state is
        visible to the check, any later write gets a higher sequence and
        legitimately supersedes. Two windows the state check can't see are
        closed by key-collision scans: earlier batches in this very group,
        and earlier *pipelined groups* that hold lower sequence numbers
        but have not published to the memtable yet (``self._pending`` is
        stable under the mutex; a group is either pending — caught here —
        or applied — caught by the state check — never neither). A failed
        batch is emptied and acked as skipped; any value it already
        separated is reported dead."""
        seen_keys: set[bytes] = {
            k
            for grp in self._pending
            for w_ in grp.writers
            for _t, k, _v in w_.entries
        }
        for w in group:
            if w.precondition is not None:
                try:
                    ok = w.precondition() and not any(
                        k in seen_keys for _t, k, _v in w.entries
                    )
                except BaseException:
                    ok = False  # fail safe: skip, never resurrect
                if not ok:
                    for type_, _k, v in w.entries:
                        if type_ == kTypeValuePtr:
                            # the separated copy phase 1 wrote is now
                            # unreferenced — let GC reclaim it
                            self.dead_tracker.on_dead(ValueOffset.decode(v))
                    w.entries = []
                    w.count = 0
                    w.entry_bytes = 0
                    w.skipped = True
                    continue
            for _t, k, _v in w.entries:
                seen_keys.add(k)

    def _apply_group_locked(self, group: list[_Writer], total_entries: int) -> list:
        """MemTable apply for one group: bulk per-batch, or hash-sharded
        across the worker pool when the group is huge. While snapshots are
        live, superseded versions a snapshot can still see are retained in
        the memtable's history instead of being discarded (and are NOT in
        the returned prev list — their values are not dead yet)."""
        cfg = self.cfg
        retain = max(self._snapshots) if self._snapshots else None
        if (
            cfg.memtable_shard_apply_entries
            and cfg.memtable_apply_shards > 1
            and total_entries >= cfg.memtable_shard_apply_entries
        ):
            if self._mt_pool is None:
                from concurrent.futures import ThreadPoolExecutor

                self._mt_pool = ThreadPoolExecutor(
                    max_workers=cfg.memtable_apply_shards, thread_name_prefix="mt-apply"
                )
            self.stats.add("memtable_shard_applies")
            return self.mem.add_group_sharded(
                [(w.seq, w.entries) for w in group],
                self._mt_pool,
                cfg.memtable_apply_shards,
                retain_from=retain,
            )
        prevs: list = []
        for w in group:
            prevs.extend(self.mem.add_batch(w.seq, w.entries, retain_from=retain))
        return prevs

    # ------------------------------------------------------------------
    # snapshots
    # ------------------------------------------------------------------
    def snapshot(self) -> Snapshot:
        """Pin the current read point; see :class:`Snapshot`. Raises
        ``RuntimeError`` past ``DBConfig.max_snapshots`` live snapshots."""
        with self.mutex:
            if sum(self._snapshots.values()) >= self.cfg.max_snapshots:
                raise RuntimeError(
                    f"snapshot(): {self.cfg.max_snapshots} snapshots already "
                    "live (DBConfig.max_snapshots) — release some first"
                )
            # The read point is the last PUBLISHED sequence. Pipelined
            # groups hold assigned-but-unpublished seqs; including them
            # would let the "snapshot" grow entries after creation.
            if self._pending:
                seq = min(w.seq for w in self._pending[0].writers) - 1
            else:
                seq = self._seq
            self._snapshots[seq] = self._snapshots.get(seq, 0) + 1
            return Snapshot(self, seq)

    def _release_snapshot_seq(self, seq: int) -> None:
        with self.mutex:
            n = self._snapshots.get(seq, 0)
            if n <= 1:
                self._snapshots.pop(seq, None)
            else:
                self._snapshots[seq] = n - 1

    def snapshot_seqs(self) -> list[int]:
        """Sorted live snapshot read points (compaction stripe boundaries,
        GC unlink guard)."""
        with self.mutex:
            return sorted(self._snapshots)

    def iterator(self, snapshot: Snapshot | None = None) -> Cursor:
        """A bidirectional :class:`Cursor` over a stable read point —
        ``snapshot``, or one taken now and released when the cursor
        closes. The cursor survives concurrent flush/compaction/GC (it
        pins the version); always close it (or use ``with``)."""
        return Cursor(self, snapshot)

    def _adapt_group_cap_locked(self, persist_s: float) -> None:
        """Latency-target controller: EWMA the group persist latency and
        steer the effective byte cap toward ``wal_group_target_latency_s``
        — grow while persists are comfortably fast (more amortization for
        free), shrink when the EWMA overshoots (followers waiting too
        long), clamped to [min_bytes, max_bytes]."""
        cfg = self.cfg
        self._persist_ewma = (
            persist_s
            if self._persist_ewma is None
            else 0.7 * self._persist_ewma + 0.3 * persist_s
        )
        cap = self._group_cap_bytes
        if self._persist_ewma > cfg.wal_group_target_latency_s:
            cap = int(cap * 0.7)
        elif self._persist_ewma < 0.5 * cfg.wal_group_target_latency_s:
            cap = int(cap * 1.5)
        self._group_cap_bytes = min(
            max(cap, cfg.wal_group_min_bytes), cfg.wal_group_max_bytes
        )
        self.stats.set_gauge("wal_group_effective_bytes", self._group_cap_bytes)
        self.stats.set_gauge("wal_persist_ewma_s", self._persist_ewma)

    def _pending_compaction_bytes(self) -> int:
        """Estimate of the compaction debt (RocksDB's
        ``estimated_pending_compaction_bytes``).

        Legacy (``pending_debt_overlap_aware=False``): every byte above a
        level's target plus all of L0 once it crosses the compaction
        trigger — the *displaced* bytes, not the work to clear them.

        Overlap-aware: each level's excess is multiplied by the write
        amplification of pushing it one level down (1 + the target level's
        overlap ratio, clamped at ``level_size_multiplier``), and the
        rewritten bytes cascade: what lands on the next level may push
        *it* over target, so the grandparent overlap those bytes will drag
        along is counted too. The delayed-write controller therefore sees
        the real device-write debt — and starts delaying — before the
        fullness-only estimate would."""
        cfg = self.cfg
        v = self.versions.current
        if not cfg.pending_debt_overlap_aware:
            total = 0
            if len(v.levels[0]) >= cfg.l0_compaction_trigger:
                total += v.level_bytes(0)
            for level in range(1, cfg.num_levels - 1):
                total += max(0, v.level_bytes(level) - cfg.level_max_bytes(level))
            return total
        debt = 0.0
        carry = 0.0  # rewritten bytes arriving from the level above
        for level in range(cfg.num_levels - 1):
            size = v.level_bytes(level) + carry
            if level == 0:
                excess = size if len(v.levels[0]) >= cfg.l0_compaction_trigger else 0.0
            else:
                excess = max(0.0, size - cfg.level_max_bytes(level))
            if excess <= 0.0:
                carry = 0.0
                continue
            ratio = min(
                float(cfg.level_size_multiplier),
                v.level_bytes(level + 1) / max(size, 1.0),
            )
            written = excess * (1.0 + ratio)
            debt += written
            carry = written  # lands one level down: grandparent debt
        return int(debt)

    def _maybe_stall_locked(self) -> None:
        """Writer throttling, two regimes (called by the group leader):

        * **stop** — immutables full, L0 at ``l0_stop_trigger``, or
          compaction debt past the hard limit: block on ``writer_cv`` until
          a background job completion clears the trigger (CV-signalled by
          the scheduler; the timeout is only a lost-wakeup safety net).
        * **delay** — above the soft thresholds the
          :class:`~.scheduler.WriteController` converts the bytes committed
          since the last controller charge (``_delay_debt`` — every
          published group's bytes, so followers' bytes are paid for even
          though only leaders sleep) into a sleep at the current
          delayed-write rate, which decays while the backlog grows and
          recovers as compaction catches up — a smooth throughput ramp
          instead of on/off oscillation. The sleep releases the DB mutex
          (the leader still heads the writer queue, so no second leader
          can form), keeping reads and job-completion hooks unblocked.

        Background-originated writes (GC rewrites) skip both regimes: they
        are already rate-limited at the token bucket, and stalling them
        could deadlock the low-priority pool against itself."""
        cfg = self.cfg
        if getattr(self._bg_local, "exempt", False):
            return
        t0 = None
        # the estimate walks every level's file list — compute it once per
        # wakeup and reuse for both the stop condition and the controller,
        # instead of twice per commit on the hot path
        pending = self._pending_compaction_bytes()
        while (
            len(self.immutables) >= cfg.max_immutables
            or len(self.versions.current.levels[0]) >= cfg.l0_stop_trigger
            or pending >= cfg.hard_pending_compaction_bytes
        ):
            self.errors.check_writable()
            if t0 is None:
                t0 = time.monotonic()
                self.bg.maybe_schedule()
            self.writer_cv.wait(timeout=0.05)
            pending = self._pending_compaction_bytes()
        if t0 is not None:
            self.stats.add_stall(time.monotonic() - t0, kind="stop")
        delay = self._write_controller.delay_for(
            len(self.versions.current.levels[0]), pending, max(self._delay_debt, 1)
        )
        self._delay_debt = 0  # charged (or the region is inactive: stale
        # debt must not snowball into one giant first delay on entry)
        if delay > 0:
            self.stats.add_stall(delay, kind="delay")
            self.mutex.release()
            try:
                time.sleep(delay)
            finally:
                self.mutex.acquire()

    def _rotate_memtable_locked(self) -> None:
        if self.wal is not None:
            self.wal.flush()
            self.wal.close()
        self.immutables.append(self.mem)
        self.mem = MemTable()
        self._open_wal()
        self.bg.maybe_schedule()  # turn the new immutable into a flush job

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: bytes, snapshot: Snapshot | None = None) -> bytes | None:
        """Point lookup: newest version visible at the read point wins
        (MemTables, then L0 newest-first, then deeper levels). With
        ``snapshot`` the read point is the snapshot's sequence; otherwise
        latest. SSTable blocks are fetched through the shared block cache
        before any pread; separated values then resolve through the
        BVCache / BValue store. Returns None for absent, deleted, or
        range-deleted keys."""
        read_seq = MAX_SEQ if snapshot is None else snapshot.seq
        # lock-free against background work: the (memtables, version) pair
        # is snapshotted under the mutex, but a compaction may finish and
        # unlink this snapshot's input files while we walk it. The reader
        # cache keeps dropped files open (close-deferred), so that window
        # only bites on a cache miss — retry against a fresh snapshot.
        for _attempt in range(8):
            with self.mutex:
                tables = [self.mem, *reversed(self.immutables)]
                version = self.versions.current
            try:
                result = self._lookup_at(key, read_seq, tables, version)
            except (OSError, ValueError) as e:
                if self.versions.current is version:
                    if isinstance(e, CorruptionError):
                        # quarantine before surfacing: the next read (and
                        # the compaction picker) skips the bad file
                        self.errors.on_corruption(e)
                    raise  # stable snapshot: real I/O or corruption error
                continue  # snapshot superseded mid-walk — take a fresh one
            # a miss is only trustworthy if the version didn't move under
            # us (a file may have been replaced between candidates); under
            # sustained churn accept the last miss rather than spinning.
            if (
                result is not None
                or self.versions.current is version
                or _attempt == 7
            ):
                return result
        return None

    def _lookup_at(self, key: bytes, read_seq: int, tables, version):
        """One MVCC point lookup over a fixed (memtables, version) pair:
        the resolved value, or None for absent / point-deleted /
        range-deleted at ``read_seq``. Raises OSError/ValueError when the
        walk races a compaction (``get`` retries on a fresh pair; pinned
        callers — cursors — can never see that).

        Tombstone accounting relies on the LSM freshness invariants:
        memtable data is strictly newer than table data, and shallower
        overlapping table data is strictly newer than deeper — so the max
        covering-tombstone seq only needs the sources up to AND INCLUDING
        the hit's level (a snapshot-retained version can coexist with a
        newer tombstone in the *adjacent touching file* of the same sorted
        level, hence "including")."""
        tomb = 0
        hit = None
        for t in tables:
            ts = t.covering_tombstone_seq(key, read_seq)
            if ts > tomb:
                tomb = ts
            found, seq, type_, value = t.get_at(key, read_seq)
            if found:
                hit = (seq, type_, value)
                break
        if hit is None:
            hit_level = None
            for level, fmeta in version.candidates_for_get(key):
                if hit is not None and level != hit_level:
                    break  # deeper data is strictly older — done
                reader = self.versions.reader(fmeta.file_no)
                if reader.range_tombstones:
                    ts = reader.max_tombstone_seq(key, read_seq)
                    if ts > tomb:
                        tomb = ts
                if hit is None:
                    if read_seq == MAX_SEQ:
                        found, seq, type_, value = reader.get(key)
                    else:
                        found, seq, type_, value = reader.get_at(key, read_seq)
                    if found:
                        hit = (seq, type_, value)
                        hit_level = level
        if hit is None or hit[0] < tomb or hit[1] == kTypeDeletion:
            return None
        return self._resolve(key, hit[1], hit[2])

    def _resolve(self, key: bytes, type_: int, value: bytes) -> bytes | None:
        if type_ == kTypeDeletion:
            return None
        if type_ == kTypeValue:
            return value
        voff = ValueOffset.decode(value)
        cached = self.bvcache.get_if_unpersisted(
            key, voff, pinned_only=not self.cfg.bvcache_enabled
        )
        if cached is not None:
            self.bvcache.hits += 1
            return cached
        self.bvcache.misses += 1
        try:
            return self.bvalue.get(voff, verify=self.cfg.paranoid_checks)
        except CorruptionError as e:
            self.errors.on_corruption(e)  # quarantine the value-log file
            raise

    def multi_get(
        self, keys, snapshot: Snapshot | None = None
    ) -> list[bytes | None]:
        """Batched point lookup: resolve many keys in one pass, returning a
        list of values (``None`` for absent/deleted) aligned with ``keys``.

        Semantically identical to ``[self.get(k, snapshot) for k in keys]``
        but structured for batch efficiency: the (memtables, version) pair
        is snapshotted ONCE per chunk; per level, every still-unresolved
        key is probed against a candidate table's bloom filter in a single
        vectorized call (:meth:`BloomFilter.may_contain_many`); and keys
        landing in the same data block decode it once
        (:meth:`SSTableReader.get_many`). Chunks are capped at
        ``DBConfig.multi_get_max_batch`` so one huge batch can't pin a
        version for an unbounded stretch."""
        keys = [bytes(k) for k in keys]
        if not keys:
            return []
        read_seq = MAX_SEQ if snapshot is None else snapshot.seq
        self.stats.add("multi_gets")
        self.stats.add("multi_get_keys", len(keys))
        out: dict[bytes, bytes | None] = {}
        cap = max(1, self.cfg.multi_get_max_batch)
        for i in range(0, len(keys), cap):
            # dedup (order-preserving): each distinct key resolves once
            chunk = list(dict.fromkeys(keys[i : i + cap]))
            # same lock-free retry protocol as ``get`` (see there): a walk
            # torn by a concurrent compaction retries the whole chunk on a
            # fresh (memtables, version) pair.
            for _attempt in range(8):
                with self.mutex:
                    tables = [self.mem, *reversed(self.immutables)]
                    version = self.versions.current
                try:
                    resolved = self._multi_lookup_at(
                        chunk, read_seq, tables, version
                    )
                except (OSError, ValueError) as e:
                    if self.versions.current is version:
                        if isinstance(e, CorruptionError):
                            self.errors.on_corruption(e)
                        raise  # stable snapshot: real I/O or corruption
                    continue  # snapshot superseded mid-walk — retry
                # misses are only trustworthy on an unmoved version; under
                # sustained churn accept the last answer rather than spin
                if self.versions.current is version or _attempt == 7:
                    out.update(resolved)
                    break
        return [out.get(k) for k in keys]

    def _multi_lookup_at(self, keys, read_seq: int, tables, version) -> dict:
        """One batched MVCC lookup over a fixed (memtables, version) pair.
        Returns ``{key: value-or-None}`` for every key. Level by level:
        keys already resolved at a shallower level drop out (deeper data is
        strictly older), in-level files still contribute range-tombstone
        seqs for keys they cover (same invariant as ``_lookup_at``: the
        max covering tombstone must include the hit's own level)."""
        tomb = dict.fromkeys(keys, 0)
        hit: dict[bytes, tuple | None] = dict.fromkeys(keys)
        # memtables stay scalar — pure in-memory probes, strictly newer
        # than any table data
        pending = []
        for key in keys:
            for t in tables:
                ts = t.covering_tombstone_seq(key, read_seq)
                if ts > tomb[key]:
                    tomb[key] = ts
                found, seq, type_, value = t.get_at(key, read_seq)
                if found:
                    hit[key] = (seq, type_, value)
                    break
            if hit[key] is None:
                pending.append(key)
        snap_seq = None if read_seq == MAX_SEQ else read_seq
        for level, files in enumerate(version.levels):
            pending = [k for k in pending if hit[k] is None]
            if not pending or not files:
                continue
            if level == 0:
                # L0 files overlap; probe in list order (newest first)
                groups = [
                    (i, [k for k in pending if f.smallest <= k <= f.largest])
                    for i, f in enumerate(files)
                ]
            else:
                # sorted level: bisect each key to its file; bounds extended
                # by range tombstones can make two files TOUCH on one key —
                # keep walking while smallest <= key (at most one extra),
                # earlier file first (it holds the newer versions)
                largests = [f.largest for f in files]
                gm: dict[int, list[bytes]] = {}
                for k in pending:
                    fi = bisect.bisect_left(largests, k)
                    while fi < len(files) and files[fi].smallest <= k:
                        gm.setdefault(fi, []).append(k)
                        fi += 1
                groups = sorted(gm.items())
            for fi, ks in groups:
                if not ks:
                    continue
                reader = self.versions.reader(files[fi].file_no)
                if reader.range_tombstones:
                    for k in ks:
                        ts = reader.max_tombstone_seq(k, read_seq)
                        if ts > tomb[k]:
                            tomb[k] = ts
                probe = [k for k in ks if hit[k] is None]
                if probe:
                    for k, ent in reader.get_many(probe, read_seq=snap_seq).items():
                        hit[k] = ent
        out = {}
        for k in keys:
            h = hit[k]
            if h is None or h[0] < tomb[k] or h[1] == kTypeDeletion:
                out[k] = None
            else:
                out[k] = self._resolve(k, h[1], h[2])
        return out

    def range(
        self,
        start: bytes = b"",
        end: bytes | None = None,
        limit: int | None = None,
        snapshot: Snapshot | None = None,
    ):
        """Stream live ``(key, value)`` pairs with ``start <= key``
        (``< end`` when given), ascending, up to ``limit`` — the canonical
        range-read surface (``scan(start, count)`` is a deprecated shim
        over it).

        A generator over a pinned :class:`Cursor`: the walk cannot be torn
        by concurrent flush/compaction/GC (the cursor pins the version and
        a read-point snapshot for its whole lifetime), memory stays O(1)
        in the result size, and abandoning the generator early closes the
        cursor (``GeneratorExit`` unwinds the ``with``). The cursor — and
        with it the read point, when no ``snapshot`` is passed — is only
        taken when iteration actually starts, standard generator
        semantics.

        Iterator fan-out is lazy: L0 files overlap so each contributes its
        own iterator, but every sorted level (L1+) feeds the heap merge ONE
        concatenating iterator that binary-searches the file list and opens
        a file only when the merge cursor actually reaches it — a short
        range read touches O(levels) files, not O(all files).
        """
        if limit is not None and limit <= 0:
            return
        n = 0
        with Cursor(self, snapshot) as cur:
            ok = cur.seek(start)
            while ok:
                key = cur.key
                if end is not None and key >= end:
                    return
                yield key, cur.value
                n += 1
                if limit is not None and n >= limit:
                    return
                ok = cur.next()

    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Deprecated: use ``range(start, limit=count)``.

        Kept as a shim (materializes the same result list) so old callers
        keep working; the historical bounded-retry scaffold (and the typed
        :class:`SnapshotUnstableError`) stays with it for alternate
        ``_scan_attempts`` implementations that can still report a torn
        snapshot by returning None.
        """
        warnings.warn(
            "DB.scan(start, count) is deprecated; use "
            "DB.range(start, limit=count)",
            DeprecationWarning,
            stacklevel=2,
        )
        for _round in range(2):
            if _round:
                time.sleep(0.005)  # one backoff round, then give up typed
            result = self._scan_attempts(start, count)
            if result is not None:
                return result
        raise SnapshotUnstableError(
            "scan() could not obtain a stable version snapshot"
        )

    def _scan_attempts(
        self, start: bytes, count: int
    ) -> list[tuple[bytes, bytes]] | None:
        with Cursor(self) as cur:
            out: list[tuple[bytes, bytes]] = []
            ok = cur.seek(start)
            while ok and len(out) < count:
                out.append((cur.key, cur.value))
                ok = cur.next()
            return out

    def _level_concat_iter(self, files, start: bytes):
        """Lazily chain one sorted level's tables: a reader is opened only
        when the previous file is exhausted (or, for the first file, when
        the heap merge first pulls from this level)."""
        first = True
        for f in files:
            it = self.versions.reader(f.file_no).iter_from(start if first else f.smallest)
            first = False
            yield from it

    # ------------------------------------------------------------------
    # maintenance / lifecycle
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Synchronous barrier: drain the commit pipeline, rotate the
        memtable, flush every immutable to L0, and force BValue/WAL
        persistence. On return all previously-acked writes are in SSTables
        or durable logs."""
        with self.mutex:
            # in-flight groups have unapplied entries targeting the current
            # WAL/memtable pair — rotating now would strand them.
            while self._pending:
                self._publish_cv.wait()
            # a tombstone-only memtable has len() == 0 but must still reach
            # an SSTable (its range block), so it counts as flushable
            if len(self.mem) or self.mem.range_tombstones:
                self._rotate_memtable_locked()
        self.wait_idle(compactions=False)
        self.bvalue.flush()
        if self.wal is not None:
            self.wal.flush()

    def wait_idle(self, compactions: bool = True, timeout: float = 120.0) -> None:
        """Block until background work is quiescent. Signalled by the job
        scheduler's completion CV — no sleep-polling, and no ``pick()``
        probes while idle (the coordinator schedules exhaustively at every
        completion edge, so idleness is a pure counter condition)."""
        self.bg.wait_idle(compactions=compactions, timeout=timeout)

    def gc_collect(self, threshold: float = 0.5) -> dict:
        """Reclaim BValue files whose dead ratio ≥ threshold (beyond-paper
        extension — see core/gc.py). Synchronous wrapper over the same
        pass the scheduler runs when ``gc_auto`` is on; a shared lock keeps
        manual and auto GC from ever running concurrently."""
        return self.bg.run_gc(threshold)

    def compact_all(self) -> None:
        """Drive compaction to quiescence (test/benchmark helper)."""
        self.wait_idle(compactions=True)

    def checkpoint(
        self, directory: str, base: str | None = None, hardlink: bool = True
    ) -> None:
        """Online checkpoint: materialize a consistent, openable copy of
        the DB in ``directory`` without stopping writes.

        ``base`` names a previous checkpoint image: any file already
        present there is hard-linked from the base instead of from the
        live DB (incremental checkpoint — repeated replica re-bootstraps
        only materialize what changed). SSTables and sealed BValue files
        are immutable, so same-name ⇒ same-content; the MANIFEST is always
        written fresh.

        ``hardlink=False`` forces byte copies from the *live* DB (links
        from ``base`` still happen — the base belongs to the image's own
        machine). A replica bootstrap needs this: the image will be
        written to (value mirroring) by a different failure domain, and a
        shared inode would let the replica's faults reach the primary's
        files.

        Sequence: flush (so everything acked is in SSTables — a checkpoint
        carries no WAL), seal the active BValue files (an append tail must
        never be hard-linked: the link shares the inode, so later appends
        would bleed into the checkpoint), then under the mutex pin the
        current version + register a snapshot and capture the counters.
        Live tables and value files are hard-linked (``checkpoint_hardlink``;
        copy fallback on False or a cross-device error) into the target,
        and finally a fresh single-edit MANIFEST is written via tmp-file +
        fsync + atomic rename — its presence is the commit marker, so a
        crash mid-checkpoint leaves a directory that is recognizably
        incomplete (no MANIFEST) rather than a subtly wrong DB.

        The pin keeps every captured SSTable on disk (compaction defers
        input unlinks); the snapshot keeps BValue GC from unlinking a
        value file whose pre-rewrite pointers the captured tables still
        hold. The retry probe on value files covers the one benign race
        left (GC passed its guard before our snapshot registered — then
        the captured tables only reference the rewritten copies)."""
        if self.env.exists(os.path.join(directory, MANIFEST_NAME)):
            raise ValueError(f"checkpoint: {directory!r} already holds a DB")
        self.flush()
        self.bvalue.seal_active()
        with self.mutex:
            snap = self.snapshot()
            version = self.versions.pin_current()
            last_seq = self.versions.last_seq
            next_file_no = self.versions.next_file_no
            bv_next = self.bvalue.next_file_id
        try:
            self.env.makedirs(directory)
            bv_dir = os.path.join(directory, "bvalue")
            self.env.makedirs(bv_dir)
            add = []
            for level, lv in enumerate(version.levels):
                # L0 is ordered newest-first in memory, but manifest replay
                # INSERTS each L0 add at the front — a single batched edit
                # must list L0 oldest-first or the opened image reads L0 in
                # reversed (oldest-wins) order.
                files = list(reversed(lv)) if level == 0 else lv
                for f in files:
                    self._checkpoint_file(
                        table_path(self.path, f.file_no),
                        table_path(directory, f.file_no),
                        base_src=table_path(base, f.file_no) if base else None,
                        hardlink=hardlink,
                    )
                    add.append((level, f.to_wire()))
            src_bv = os.path.join(self.path, "bvalue")
            base_bv = os.path.join(base, "bvalue") if base else None
            for name in sorted(self.env.listdir(src_bv)):
                if not name.endswith(".val"):
                    continue
                for _ in range(3):
                    try:
                        self._checkpoint_file(
                            os.path.join(src_bv, name),
                            os.path.join(bv_dir, name),
                            base_src=os.path.join(base_bv, name) if base_bv else None,
                            hardlink=hardlink,
                        )
                        break
                    except OSError:
                        if not self.env.exists(os.path.join(src_bv, name)):
                            break  # GC'd mid-walk: nothing live points here
            edit = {
                "add": add,
                "last_seq": last_seq,
                "next_file_no": next_file_no,
                "bvalue_next_file_id": bv_next,
            }
            tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
            f = self.env.open(tmp, "wb")
            try:
                f.write(frame_record(msgpack.packb(edit, use_bin_type=True)))
                f.flush()
                self.env.fsync(f)
            finally:
                f.close()
            self.env.rename(tmp, os.path.join(directory, MANIFEST_NAME))
            self.stats.add("checkpoints")
            # the committed image now belongs to its consumer (a replica, a
            # backup target): this env's crash simulation must no longer
            # rewind files another failure domain may start writing. An
            # uncommitted image (crash before the rename) stays tracked —
            # its unsynced files SHOULD vanish with this machine.
            self.env.release_tracking(directory)
        finally:
            self.versions.unpin()
            snap.release()

    def _checkpoint_file(
        self,
        src: str,
        dst: str,
        base_src: str | None = None,
        hardlink: bool = True,
    ) -> None:
        if base_src is not None and self.env.exists(base_src):
            # incremental: the previous image already holds this (immutable)
            # file — link from there, never touching the live copy. The
            # size check guards bases that are NOT pristine images (a
            # re-bootstrap reuses the old replica store, where a mirrored
            # value file can be a short prefix of the primary's): same
            # name + same size is required before trusting same content.
            try:
                if self.env.getsize(base_src) == self.env.getsize(src):
                    self.env.link(base_src, dst)
                    self.stats.add("checkpoint_base_links")
                    return
            except OSError:
                pass  # base unusable for this file: fall through to live
        if hardlink and self.cfg.checkpoint_hardlink:
            try:
                self.env.link(src, dst)
                return
            except FileNotFoundError:
                raise
            except OSError:
                pass  # EXDEV / EEXIST / unsupported — fall back to a copy
        with self.env.open(src, "rb") as fi:
            data = fi.read()
        f = self.env.open(dst, "wb")
        try:
            f.write(data)
            f.flush()
            self.env.fsync(f)
        finally:
            f.close()

    def resume(self) -> None:
        """Leave read-only mode after a hard background error.

        Probes the Env (write + fsync + readback of a scratch file — if the
        cause, say ENOSPC, still holds, the probe raises and the latch
        stays), clears the error latch, replaces a poisoned WAL by sealing
        the current memtable (its log tail may be torn; replay stops at the
        damage anyway, and the sealed memtable holds everything acked), and
        re-kicks the scheduler so deferred flush/compaction/GC work drains.
        """
        if self.errors.error is None:
            return  # not latched: nothing to do
        probe = os.path.join(self.path, "RESUME_PROBE")
        f = self.env.open(probe, "wb")
        try:
            f.write(b"probe")
            f.flush()
            self.env.fsync(f)
        finally:
            f.close()
        try:
            with self.env.open(probe, "rb") as f:
                if f.read() != b"probe":
                    raise IOError("resume(): Env probe readback mismatch")
        finally:
            try:
                self.env.unlink(probe)
            except OSError:
                pass
        self.errors.clear()
        with self.mutex:
            wal = self.wal
            if wal is not None and wal._poisoned:
                # a WAL append failed mid-file: never append past the torn
                # tail. The failed group was never applied (publish skips on
                # error), so the memtable holds exactly the durable prefix —
                # seal it behind a fresh WAL file.
                while self._pending:
                    self._publish_cv.wait()
                self._rotate_memtable_locked()
        self.stats.add("resumes")
        self.bg.maybe_schedule()

    # ------------------------------------------------------------------
    # replication
    # ------------------------------------------------------------------
    def promote(self) -> None:
        """Failover: turn this replica into a primary.

        The PR 6 resume machinery in reverse — instead of clearing a latch
        on the same instance, the write latch moves here: seal the stream
        (no further frames apply), replay whatever suffix survives in the
        old primary's durable WAL (final catch-up — in sync mode that is
        every acknowledged write, because values fsync before their pointer
        record and retention kept the segments), discard buffered
        non-contiguous frames (the unacked suffix), move the BValue id
        allocator past the mirrored id space and force-roll every queue so
        new writes can never append into a mirrored file, then flip the
        role. Idempotent: promoting a primary — or promoting twice, or
        during an in-flight apply — is a no-op beyond the first call."""
        with self.mutex:
            if self._role != "replica":
                return
        follower = self._follower
        if follower is not None:
            follower.seal(final_catch_up=True)
            # async primaries can die with durable pointers to value bytes
            # that never hit their disk; the final catch-up then mirrors
            # nothing for them. Same hole async recovery has, same cure:
            # probe and drop, each key falls back to its previous version.
            self._drop_dangling_pointers()
        with self.mutex:
            if self._role != "replica":  # lost a promote race
                return
            if follower is not None:
                self.bvalue.ensure_next_file_id(follower.max_mirrored_file + 1)
            self.bvalue.seal_active(force=True)
            self._role = "primary"
            self._follower = None
            # start the new reign on a fresh WAL segment if the memtable
            # holds applied-but-unflushed state (mirrors resume())
            if len(self.mem) or self.mem.range_tombstones:
                self._rotate_memtable_locked()
        self.stats.add("promotions")
        self.bg.maybe_schedule()

    def replication_status(self) -> dict:
        """Role + stream position for observability and the benchmark."""
        out: dict = {"role": self._role}
        repl = self._repl
        if repl is not None and repl.active:
            out["shipped_seq"] = repl.shipped_seq
            out["min_acked_seq"] = repl.min_acked()
            out["retained_wals"] = len(repl._retained)
        follower = self._follower
        if follower is not None:
            out["applied_seq"] = follower.applied_seq
            out["last_shipped_seen"] = follower.last_shipped_seen
            out["lag"] = follower.lag
            out["diverged"] = follower.diverged
            out["needs_rebootstrap"] = follower.needs_rebootstrap
        return out

    def verify_integrity(
        self, background: bool = False, fail_fast: bool = False
    ) -> dict | None:
        """Scrub the DB: CRC-verify every live SSTable block and every
        separated value reachable from a live table entry. Corrupt files
        are quarantined (manifest-marked, skipped by compaction and GC)
        via the normal :class:`CorruptionError` path, and the scan keeps
        going — the report's ``findings`` list carries every damage site
        (file, block, error class), so a replica bootstrap can
        quarantine-and-continue instead of giving up at the first hit.
        Reads are paced at low priority through the shared I/O token
        bucket, so a scrub cannot starve foreground traffic.

        ``fail_fast=True`` restores raise-on-first-corruption semantics
        (the first :class:`CorruptionError` propagates after quarantining
        its file). ``background=True`` submits the scrub to the
        low-priority job pool and returns None; otherwise runs inline and
        returns the report dict."""
        if background:
            self.bg.submit_scrub()
            return None
        return self._scrub(fail_fast=fail_fast)

    def _scrub(self, fail_fast: bool = False) -> dict:
        report = {
            "sst_files": 0,
            "blocks_verified": 0,
            "values_verified": 0,
            "corruptions": [],
            "findings": [],
        }

        def record(kind: str, file_id, block, exc: BaseException) -> None:
            report["corruptions"].append(str(exc))
            report["findings"].append(
                {
                    "kind": kind,
                    "file": file_id,
                    "block": block,
                    "error": type(exc).__name__,
                    "detail": str(exc),
                }
            )
            if fail_fast:
                raise exc

        version = self.versions.current
        quarantined = self.versions.quarantined_files()
        seen_vals: set[tuple[int, int]] = set()
        for level in range(len(version.levels)):
            for fmeta in version.levels[level]:
                if self._closed or fmeta.file_no in quarantined:
                    continue
                try:
                    reader = self.versions.reader(fmeta.file_no)
                except OSError:
                    continue  # compacted away under the scrub — fine
                report["sst_files"] += 1
                unreadable = False
                file_quarantined = False
                for idx in range(len(reader.index)):
                    if self._closed:
                        break
                    _key, _off, length = reader.index[idx]
                    self.rate_limiter.request(length, PRI_LOW)
                    try:
                        reader.verify_block(idx)
                    except CorruptionError as e:
                        # quarantine once, but keep scanning: the report
                        # must name EVERY damaged block, not just the first
                        if not file_quarantined:
                            self.errors.on_corruption(e)
                            file_quarantined = True
                        record("sst_block", fmeta.file_no, idx, e)
                        continue
                    except OSError:
                        unreadable = True
                        break  # truncated/unlinked mid-scrub: not corruption
                    report["blocks_verified"] += 1
                if unreadable or file_quarantined:
                    continue
                # follow the table's value pointers into the BValue log
                try:
                    for _k, _seq, type_, value in reader.iter_all(fill_cache=False):
                        if self._closed:
                            break
                        if type_ != kTypeValuePtr:
                            continue
                        voff = ValueOffset.decode(value)
                        if (
                            voff.file_id in self.versions.quarantined_bvalues
                            or (voff.file_id, voff.offset) in seen_vals
                        ):
                            continue
                        seen_vals.add((voff.file_id, voff.offset))
                        self.rate_limiter.request(voff.size, PRI_LOW)
                        try:
                            self.bvalue.get(voff, verify=True)
                            report["values_verified"] += 1
                        except CorruptionError as e:
                            self.errors.on_corruption(e)
                            record("bvalue", voff.file_id, voff.offset, e)
                        except OSError:
                            continue  # GC'd / short read: retryable, not rot
                except OSError:
                    continue
        return report

    def close(self, crash: bool = False) -> None:
        """Shut down the engine. ``crash=True`` simulates a hard crash for
        recovery tests: async WAL buffers are dropped, memtables are NOT
        flushed, and background work is abandoned — reopening the path
        exercises the real recovery code."""
        if self._closed:
            return
        self._closed = True
        if self._follower is not None:
            if crash:
                self._follower.sealed = True  # abandon in-flight apply
            else:
                self._follower.seal(final_catch_up=False)
        if self._repl is not None:
            self._repl.close()
        if not crash:
            self.bvalue.flush()
        else:
            # crash simulation: queued flush jobs are discarded and the
            # immutables stay unflushed — reopening recovers from the WAL
            with self.mutex:
                self.immutables.clear()
        self.bg.stop(crash=crash)
        if self.wal is not None:
            self.wal.close(drop_buffered=crash)
        self.bvalue.close()
        self.versions.close()
        if self._mt_pool is not None:
            self._mt_pool.shutdown(wait=True)

    # convenience --------------------------------------------------------
    def __enter__(self) -> "DB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
