"""Write-Ahead Log with the three durability modes the paper benchmarks.

* ``sync``  — every append is followed by ``fsync`` (strict durability;
  workloads R-WS / S-WS).
* ``async`` — appends buffer in memory and a background flusher writes +
  fsyncs in batches (R-WA / S-WA). Acknowledged writes may be lost on crash
  up to the flush interval, exactly like RocksDB's WAL-async mode.
* ``off``   — handled at the DB layer (no WAL object at all; R-WO / S-WO).

Group commit rides on :meth:`WALWriter.append_many`: the DB's write-group
leader hands over every queued batch's payload at once, and the whole group
costs a single ``write`` + (sync mode) a single ``fsync``. Each payload keeps
its own CRC frame (:mod:`.record`), so replay-atomicity remains per-batch:
a torn tail drops whole batches, never partial ones.

Records are CRC-framed (:mod:`.record`); replay stops at the first torn or
corrupt record.
"""
from __future__ import annotations

import os
import threading

from .record import frame_record, frame_records, iter_framed_records


class WALWriter:
    def __init__(
        self,
        path: str,
        mode: str = "sync",
        flush_interval_s: float = 0.05,
        flush_bytes: int = 1 << 20,
        stats=None,
    ):
        assert mode in ("sync", "async")
        self.path = path
        self.mode = mode
        self._f = open(path, "ab", buffering=0)
        self._stats = stats
        self._closed = False
        if mode == "async":
            self._buf: list[bytes] = []
            self._buf_bytes = 0
            self._flush_bytes = flush_bytes
            self._interval = flush_interval_s
            self._lock = threading.Lock()
            self._wake = threading.Event()
            self._thread = threading.Thread(target=self._flusher, name="wal-flusher", daemon=True)
            self._thread.start()

    # -- public api -------------------------------------------------------
    def append(self, payload: bytes) -> None:
        self._append_blob(frame_record(payload), nrecords=1)

    def append_many(self, payloads) -> None:
        """Group commit: persist many framed records with ONE write (and in
        sync mode one fsync) — the durability barrier is paid per group."""
        if not payloads:
            return
        self._append_blob(frame_records(payloads), nrecords=len(payloads))

    def _append_blob(self, blob: bytes, nrecords: int) -> None:
        if self.mode == "sync":
            self._f.write(blob)
            os.fsync(self._f.fileno())
            if self._stats:
                self._stats.add("wal_bytes", len(blob))
                self._stats.add("wal_fsyncs")
                self._stats.add("wal_records", nrecords)
        else:
            with self._lock:
                self._buf.append(blob)
                self._buf_bytes += len(blob)
                if self._stats:
                    self._stats.add("wal_records", nrecords)
                if self._buf_bytes >= self._flush_bytes:
                    self._wake.set()

    def flush(self) -> None:
        """Force buffered records to disk (async mode barrier)."""
        if self.mode == "async":
            self._drain()
        else:
            os.fsync(self._f.fileno())

    def close(self, drop_buffered: bool = False) -> None:
        """drop_buffered=True simulates a crash with unflushed async buffer."""
        if self._closed:
            return
        self._closed = True
        if self.mode == "async":
            if drop_buffered:
                with self._lock:
                    self._buf.clear()
                    self._buf_bytes = 0
            self._wake.set()
            self._thread.join(timeout=5)
            if not drop_buffered:
                self._drain()
        self._f.close()

    # -- internals ----------------------------------------------------------
    def _drain(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
            self._buf_bytes = 0
        if buf:
            blob = b"".join(buf)
            self._f.write(blob)
            os.fsync(self._f.fileno())
            if self._stats:
                self._stats.add("wal_bytes", len(blob))
                self._stats.add("wal_fsyncs")

    def _flusher(self) -> None:
        while not self._closed:
            self._wake.wait(timeout=self._interval)
            self._wake.clear()
            if self._closed:
                return
            self._drain()


def replay_wal(path: str):
    """Yield payloads of intact records from a WAL file."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        buf = f.read()
    yield from iter_framed_records(buf)
