"""Write-Ahead Log with the three durability modes the paper benchmarks.

* ``sync``  — every append is followed by ``fsync`` (strict durability;
  workloads R-WS / S-WS).
* ``async`` — appends buffer in memory and a background flusher writes +
  fsyncs in batches (R-WA / S-WA). Acknowledged writes may be lost on crash
  up to the flush interval, exactly like RocksDB's WAL-async mode.
* ``off``   — handled at the DB layer (no WAL object at all; R-WO / S-WO).

Group commit rides on :meth:`WALWriter.append_many`: the DB's write-group
leader hands over every queued batch's payload at once, and the whole group
costs a single ``write`` + (sync mode) a single ``fsync``. Each payload keeps
its own CRC frame (:mod:`.record`), so replay-atomicity remains per-batch:
a torn tail drops whole batches, never partial ones.

Pipelined commit (write pipeline v2)
------------------------------------

Concurrent group leaders overlap their commits through a **ticket
barrier**: the DB reserves a ticket per group *in sequence order* (under
its mutex, via :meth:`reserve`), then each leader calls ``append_many``
concurrently. Frame encoding runs with no lock at all; the file ``write``
runs under the barrier strictly in ticket order, so the WAL byte stream is
always a seq-ordered prefix — recovery can never observe group N+1 without
group N (no commit-order hole). The ``fsync`` runs *outside* the barrier:
while leader N's fsync is in flight, leader N+1 is already encoding and
writing. Because appends are file-ordered, any fsync issued after ticket
T's write also covers every ticket ≤ T — at most one fsync runs at a time,
and leaders that pile up behind it ride the next one instead of issuing
their own (``wal_fsync_skips``).

Records are CRC-framed (:mod:`.record`); replay stops at the first torn or
corrupt record.
"""
from __future__ import annotations

import threading

import os

from .env import DEFAULT_ENV
from .record import (
    decode_varint,
    frame_records,
    iter_framed_records,
    iter_framed_records_ex,
)


class WALWriter:
    def __init__(
        self,
        path: str,
        mode: str = "sync",
        flush_interval_s: float = 0.05,
        flush_bytes: int = 1 << 20,
        stats=None,
        env=None,
    ):
        assert mode in ("sync", "async")
        self.path = path
        self.mode = mode
        self._env = env or DEFAULT_ENV
        self._f = self._env.open(path, "ab", buffering=0)
        self._stats = stats
        self._closed = False
        # ticket barrier state (sync + async: file/buffer order must match
        # sequence order for hole-free replay)
        self._order_lock = threading.Lock()
        self._order_cv = threading.Condition(self._order_lock)
        self._next_ticket = 0  # next ticket to hand out
        self._next_write = 0  # ticket whose write may proceed
        self._synced = -1  # highest ticket covered by a completed fsync
        self._sync_in_flight = False  # one fsync at a time; laters piggyback
        self._poisoned = False  # a write failed: the tail may be torn
        if mode == "async":
            self._buf: list[bytes] = []
            self._buf_bytes = 0
            self._flush_bytes = flush_bytes
            self._interval = flush_interval_s
            self._lock = threading.Lock()
            self._wake = threading.Event()
            self._thread = threading.Thread(target=self._flusher, name="wal-flusher", daemon=True)
            self._thread.start()

    # -- public api -------------------------------------------------------
    def reserve(self) -> int:
        """Hand out the next write-order ticket.

        The caller (the DB's group-commit leader) MUST call this in commit
        sequence order — i.e. while holding the lock under which it assigned
        the group's sequence numbers — or the file order would diverge from
        the sequence order.
        """
        with self._order_lock:
            t = self._next_ticket
            self._next_ticket += 1
            return t

    def append(self, payload: bytes) -> None:
        """Persist one record (self-ordered: reserves its own ticket)."""
        self.append_many([payload])

    def append_many(self, payloads, ticket: int | None = None) -> None:
        """Group commit: persist many framed records with ONE write (and in
        sync mode at most one fsync) — the durability barrier is paid per
        group, and skipped entirely when a later-started fsync already
        covers this ticket.

        With ``ticket`` (from :meth:`reserve`) the write waits its turn at
        the ticket barrier; without one the call is self-ordered (reserve +
        append under the same breath — the non-pipelined path).
        """
        if not payloads:
            return
        if ticket is None:
            ticket = self.reserve()
        self.write_many(payloads, ticket)
        self.sync_ticket(ticket)

    def write_many(self, payloads, ticket: int) -> None:
        """Stage 1 of a pipelined append: frame (lock-free) + ordered file
        write. NOT durable yet in sync mode — follow with
        :meth:`sync_ticket`. The split lets the DB's commit leader hand the
        writer queue off between the write and the fsync, so the next
        group forms and encodes while this one's fsync is in flight."""
        try:
            blob = frame_records(payloads)  # encode OUTSIDE any lock
        except BaseException:
            self.abort_ticket(ticket)  # or every later ticket deadlocks
            raise
        if self.mode == "sync":
            self._write_ordered(ticket, blob, len(payloads))
        else:
            self._buffer_ordered(ticket, blob, len(payloads))

    def abort_ticket(self, ticket: int) -> None:
        """Consume a reserved ticket without writing (the caller failed
        before reaching the barrier). MUST be called for any reserved
        ticket that will never be written, or the barrier deadlocks."""
        with self._order_cv:
            while self._next_write != ticket:
                self._order_cv.wait()
            self._next_write = ticket + 1
            self._order_cv.notify_all()

    def sync_ticket(self, ticket: int) -> None:
        """Stage 2: make ``ticket`` durable (sync mode; async buffers are
        flushed by the background flusher on its own clock)."""
        if self.mode == "sync":
            self._sync_cover(ticket)

    def flush(self) -> None:
        """Force buffered records to disk (async mode barrier)."""
        if self.mode == "async":
            self._drain()
        else:
            self._env.fsync(self._f)

    def close(self, drop_buffered: bool = False) -> None:
        """drop_buffered=True simulates a crash with unflushed async buffer."""
        if self._closed:
            return
        self._closed = True
        if self.mode == "async":
            if drop_buffered:
                with self._lock:
                    self._buf.clear()
                    self._buf_bytes = 0
            self._wake.set()
            self._thread.join(timeout=5)
            if not drop_buffered:
                self._drain()
        self._f.close()

    # -- internals ----------------------------------------------------------
    def _write_ordered(self, ticket: int, blob: bytes, nrecords: int) -> None:
        """File write strictly in ticket order (the sequence barrier)."""
        with self._order_cv:
            while self._next_write != ticket:
                self._order_cv.wait()
            try:
                if self._poisoned:
                    # an earlier write failed: the file may end in a torn
                    # record, and replay stops there — appending past it
                    # would ack writes that can never be recovered.
                    raise IOError(f"WAL {self.path} poisoned by an earlier failed write")
                self._f.write(blob)
            except BaseException:
                self._poisoned = True
                raise
            finally:
                # advance even on a failed write: later tickets must not
                # deadlock (they fail fast on the poison flag instead)
                self._next_write = ticket + 1
                self._order_cv.notify_all()
            if self._stats:
                self._stats.add("wal_bytes", len(blob))
                self._stats.add("wal_records", nrecords)

    def _sync_cover(self, ticket: int) -> None:
        """fsync OUTSIDE the write barrier — overlaps the next leader's
        encode+write. At most one fsync is in flight; a group that arrives
        while one is running waits for it, then re-checks: because appends
        are file-ordered, an fsync started after ticket T's write durably
        covers every ticket ≤ T, so piled-up groups ride the next fsync
        instead of issuing their own (``wal_fsync_skips``)."""
        with self._order_cv:
            while True:
                if self._synced >= ticket:
                    if self._stats:
                        self._stats.add("wal_fsync_skips")
                    return
                if not self._sync_in_flight:
                    self._sync_in_flight = True
                    covered = self._next_write - 1  # everything written so far
                    break
                self._order_cv.wait()
        try:
            self._env.fsync(self._f)
        finally:
            with self._order_cv:
                self._sync_in_flight = False
                if covered > self._synced:
                    self._synced = covered
                self._order_cv.notify_all()
        if self._stats:
            self._stats.add("wal_fsyncs")

    def _buffer_ordered(self, ticket: int, blob: bytes, nrecords: int) -> None:
        # async mode: the buffer append takes the ticket barrier too, so the
        # flusher writes groups in sequence order (hole-free replay).
        with self._order_cv:
            while self._next_write != ticket:
                self._order_cv.wait()
            try:
                with self._lock:
                    self._buf.append(blob)
                    self._buf_bytes += len(blob)
                    if self._stats:
                        self._stats.add("wal_records", nrecords)
                    if self._buf_bytes >= self._flush_bytes:
                        self._wake.set()
            finally:
                self._next_write = ticket + 1
                self._order_cv.notify_all()

    def _drain(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
            self._buf_bytes = 0
        if buf:
            blob = b"".join(buf)
            self._f.write(blob)
            self._env.fsync(self._f)
            if self._stats:
                self._stats.add("wal_bytes", len(blob))
                self._stats.add("wal_fsyncs")

    def _flusher(self) -> None:
        while not self._closed:
            self._wake.wait(timeout=self._interval)
            self._wake.clear()
            if self._closed:
                return
            self._drain()


def replay_wal(path: str, env=None):
    """Yield payloads of intact records from a WAL file."""
    env = env or DEFAULT_ENV
    if not env.exists(path):
        return
    with env.open(path, "rb") as f:
        buf = f.read()
    yield from iter_framed_records(buf)


class WALSegmentReader:
    """Tail-following reader over a DB directory's WAL segments
    (``wal_NNNNNN.log``), used by replication catch-up: a lagging follower
    reads the primary's durable log directly and applies every committed
    group it missed over the wire.

    Segments are visited in wal-number order (= append order; each
    segment's sequence numbers are a contiguous continuation of the
    previous one's thanks to the ticket barrier). The reader is stateful:
    it remembers a byte offset per segment, so repeated :meth:`read_new`
    calls only parse bytes appended since the last call — including bytes
    appended to a segment that was previously read to its (then) end.
    Torn or corrupt frames stop the scan of that segment at that point;
    the caller's seq-contiguity check decides whether what follows is a
    real gap."""

    def __init__(self, directory: str, env=None):
        self.dir = directory
        self._env = env or DEFAULT_ENV
        self._offsets: dict[str, int] = {}

    def reset(self) -> None:
        self._offsets.clear()

    def _segments(self) -> list[str]:
        try:
            names = self._env.listdir(self.dir)
        except OSError:
            return []
        segs = [n for n in names if n.startswith("wal_") and n.endswith(".log")]
        segs.sort()  # zero-padded wal numbers: lexical == numeric order
        return segs

    def read_new(self):
        """Yield ``(seq, payload)`` for every intact record appended since
        the last call, across all segments in order. Deleted segments are
        forgotten; new ones are picked up automatically."""
        segs = self._segments()
        live = set(segs)
        for tracked in list(self._offsets):
            if tracked not in live:
                del self._offsets[tracked]
        for name in segs:
            start = self._offsets.get(name, 0)
            path = os.path.join(self.dir, name)
            try:
                with self._env.open(path, "rb") as f:
                    if start:
                        f.seek(start)
                    buf = f.read()
            except OSError:
                continue
            if not buf:
                continue
            consumed = 0
            for payload, end in iter_framed_records_ex(buf):
                consumed = end
                try:
                    seq, _ = decode_varint(payload, 0)
                except (IndexError, ValueError):
                    break
                yield seq, payload
            self._offsets[name] = start + consumed
