"""BVCache — the paper's big-value read cache (§III-D).

A fixed-capacity in-memory structure with a hash index for O(1) key lookup.
New writes are admitted at the MRU end (Most-Recent-Write-First), so values
not yet persisted by the asynchronous BValue writers remain readable.
Eviction removes from the LRU end using a recency (LRU) or frequency (LFU)
policy, per the paper's "depending on system load conditions".

Un-persisted entries are *pinned* (dropping one would lose the only copy in
WAL-disabled mode). Pinned entries live in a separate ordered map so the
eviction path never scans them — O(1) eviction even when the cache is
pin-saturated; the BValue writer unpins (in batch) on flush completion.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

from .record import ValueOffset


@dataclass(slots=True)
class _Entry:
    voff: ValueOffset
    value: bytes
    freq: int
    ts: float


class BVCache:
    def __init__(self, capacity_bytes: int, policy: str = "lru"):
        assert policy in ("lru", "lfu")
        self.capacity = capacity_bytes
        self.policy = policy
        self._map: OrderedDict[bytes, _Entry] = OrderedDict()  # evictable
        self._pinned: OrderedDict[bytes, _Entry] = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._map) + len(self._pinned)

    @property
    def size_bytes(self) -> int:
        return self._bytes

    # -- write path -----------------------------------------------------
    def insert(self, key: bytes, voff: ValueOffset, value: bytes, pinned: bool = False) -> None:
        with self._lock:
            old = self._map.pop(key, None) or self._pinned.pop(key, None)
            if old is not None:
                self._bytes -= len(key) + len(old.value)
            ent = _Entry(voff, value, (old.freq + 1 if old else 1), time.monotonic())
            (self._pinned if pinned else self._map)[key] = ent  # MRU end
            self._bytes += len(key) + len(value)
            self._evict_locked()

    def unpin(self, key: bytes, voff: ValueOffset) -> None:
        """BValue writer completed persisting `key`'s value at `voff`."""
        self.unpin_many(((key, voff),))

    def unpin_many(self, items) -> None:
        """Batch unpin — one lock acquisition per BValue flush batch. Matches
        on location (file/offset/size) only: the BValue writer does not carry
        the value CRC, so full ValueOffset equality would never unpin."""
        with self._lock:
            for key, voff in items:
                ent = self._pinned.get(key)
                if ent is not None and (
                    ent.voff.file_id == voff.file_id
                    and ent.voff.offset == voff.offset
                    and ent.voff.size == voff.size
                ):
                    del self._pinned[key]
                    self._map[key] = ent  # joins the evictable order at MRU
            self._evict_locked()

    # -- read path ------------------------------------------------------
    def get(self, key: bytes) -> bytes | None:
        with self._lock:
            ent = self._map.get(key)
            if ent is not None:
                ent.freq += 1
                ent.ts = time.monotonic()
                self._map.move_to_end(key, last=True)
                self.hits += 1
                return ent.value
            ent = self._pinned.get(key)
            if ent is not None:
                ent.freq += 1
                self.hits += 1
                return ent.value
            self.misses += 1
            return None

    def get_if_unpersisted(self, key: bytes, voff: ValueOffset, pinned_only: bool = False) -> bytes | None:
        with self._lock:
            ent = self._pinned.get(key)
            if ent is None and not pinned_only:
                ent = self._map.get(key)
            if ent is not None and ent.voff == voff:
                return ent.value
            return None

    # -- eviction ---------------------------------------------------------
    def _evict_locked(self) -> None:
        if self.policy == "lfu":
            while self._bytes > self.capacity and self._map:
                # sampled-LFU: least-frequent among the 16 LRU-most entries
                candidates = []
                for i, (k, e) in enumerate(self._map.items()):
                    candidates.append((e.freq, e.ts, k))
                    if i >= 15:
                        break
                _, _, victim = min(candidates)
                ent = self._map.pop(victim)
                self._bytes -= len(victim) + len(ent.value)
        else:  # lru — pop from the LRU end; pinned entries are elsewhere
            while self._bytes > self.capacity and self._map:
                k, ent = self._map.popitem(last=False)
                self._bytes -= len(k) + len(ent.value)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self),
            "pinned": len(self._pinned),
            "bytes": self._bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
        }
