"""Pluggable filesystem Env + FaultInjectionEnv (RocksDB FaultInjectionTestFS-style).

Every file operation the engine performs — WAL appends, value-queue pwrites,
SSTable writes, manifest appends, recovery listdir/unlink — goes through
``DBConfig.env`` instead of calling ``open``/``os.*`` directly. The default
:class:`Env` is a zero-overhead passthrough; :class:`FaultInjectionEnv`
layers three test capabilities on top without the engine knowing:

* **rule-based faults** — inject an errno (or arbitrary exception) by
  operation kind, path substring, Nth-occurrence countdown, or probability
  (:meth:`add_fault`). An ``errno.ENOSPC`` rule on ``write``/``sync`` is a
  faithful disk-full simulation.
* **simulated crashes** — :meth:`set_crash_after` arms a countdown; once it
  fires, every mutating op raises :class:`SimulatedCrashError` ("the machine
  died"), and :meth:`drop_unsynced` then rewinds every tracked file to its
  last-fsynced state: appends past the synced size are truncated, overwrites
  of previously-synced bytes are undone from a per-write undo log. This is
  what lets the crash harness kill the engine at *any* write edge and check
  that reopen honors exactly the acknowledged-sync prefix.
* **corruption** — :meth:`corrupt` flips bytes at a file offset to exercise
  CRC verification and quarantine paths.

Metadata ops (``rename``/``unlink``) are applied immediately and treated as
durable — the engine always fsyncs outputs before unlinking inputs, so
dropping unsynced *data* is the interesting failure mode, matching RocksDB's
FaultInjectionTestFS default.
"""
from __future__ import annotations

import errno as _errno
import os
import random
import threading

from .errors import SimulatedCrashError

#: operation kinds a fault rule can match. "write" covers append/pwrite,
#: "sync" covers fsync/fdatasync on any handle, "ship" covers replication
#: frame sends (the transport routes through the env).
OPS = ("open", "read", "write", "sync", "rename", "unlink", "listdir",
       "truncate", "link", "ship")

#: ops that mutate the (simulated) device — these all fail once a simulated
#: crash has fired. "ship" is here because a dead primary cannot send.
_MUTATING_OPS = frozenset(
    ("open", "write", "sync", "rename", "unlink", "truncate", "link", "ship")
)


class Env:
    """Default environment: a thin passthrough to the real filesystem.

    The engine only ever calls these methods, so a subclass can interpose on
    the complete I/O surface. File handles returned by :meth:`open` are
    ordinary file objects (or wrappers with the same interface); raw-fd
    paths use :meth:`open_fd`/:meth:`pread`/:meth:`pwrite`/:meth:`close_fd`.
    """

    # -- buffered file objects ------------------------------------------
    def open(self, path, mode="rb", buffering=-1):
        return open(path, mode, buffering=buffering)

    def fsync(self, f) -> None:
        """fsync a file object or a raw fd. File objects are flushed first —
        fsyncing a buffered handle without draining the userspace buffer
        would silently make nothing durable."""
        if isinstance(f, int):
            os.fsync(f)
        else:
            f.flush()
            os.fsync(f.fileno())

    # -- raw fd API (value queues) --------------------------------------
    def open_fd(self, path, flags, mode=0o644) -> int:
        return os.open(path, flags, mode)

    def close_fd(self, fd: int) -> None:
        os.close(fd)

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        return os.pread(fd, size, offset)

    def pread_f(self, f, size: int, offset: int) -> bytes:
        """Positional read on a file object (race-free: no shared cursor)."""
        return os.pread(f.fileno(), size, offset)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        return os.pwrite(fd, data, offset)

    def truncate_fd(self, fd: int, size: int) -> None:
        os.ftruncate(fd, size)

    # -- metadata --------------------------------------------------------
    def rename(self, src, dst) -> None:
        os.rename(src, dst)

    def unlink(self, path) -> None:
        os.unlink(path)

    def listdir(self, path):
        return os.listdir(path)

    def exists(self, path) -> bool:
        return os.path.exists(path)

    def getsize(self, path) -> int:
        return os.path.getsize(path)

    def makedirs(self, path) -> None:
        os.makedirs(path, exist_ok=True)

    def link(self, src, dst) -> None:
        """Hard-link ``src`` to ``dst`` (checkpoint file sharing). Callers
        that must work across devices catch OSError and fall back to a
        byte copy."""
        os.link(src, dst)

    def release_tracking(self, prefix: str) -> None:
        """Disown every tracked path under ``prefix`` (no-op here; see
        ``FaultInjectionEnv``). Called when a completed checkpoint image is
        handed to another failure domain — e.g. a replica bootstrap — so
        this env's simulated crash can no longer rewind files that a
        different machine now owns and writes."""

    # -- replication transport -------------------------------------------
    def ship(self, stream: str, blob: bytes) -> list:
        """Deliver one replication frame on ``stream``. Returns the frames
        that actually arrive at the far end — a fault-injecting env may
        drop, duplicate, reorder, or corrupt them in flight."""
        return [blob]


#: module-level default shared by every DB that doesn't set ``cfg.env``.
DEFAULT_ENV = Env()


class FaultRule:
    """One injection rule. Matches ``op`` (or any op if None) against a path
    substring, then fires according to ``count`` (first N matches) and/or
    ``probability``. ``count=None`` means unlimited."""

    __slots__ = ("op", "path_substr", "count", "probability", "exc_factory")

    def __init__(self, op, path_substr, count, probability, exc_factory):
        self.op = op
        self.path_substr = path_substr
        self.count = count
        self.probability = probability
        self.exc_factory = exc_factory

    def matches(self, op: str, path: str) -> bool:
        if self.op is not None and self.op != op:
            return False
        if self.path_substr is not None and self.path_substr not in path:
            return False
        return True


class _FaultFile:
    """File-object wrapper that routes write/flush/read traffic back through
    the owning FaultInjectionEnv for rule checks and unsynced tracking."""

    def __init__(self, env, f, path, writable):
        self._env = env
        self._f = f
        self.path = path
        self._writable = writable

    def write(self, data):
        if self._writable:
            self._env._check("write", self.path)
        n = self._f.write(data)
        if self._writable:
            self._env._note_append(self.path, len(data))
        return n

    def read(self, *a):
        self._env._check("read", self.path)
        return self._f.read(*a)

    def seek(self, *a):
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def flush(self):
        return self._f.flush()

    def fileno(self):
        return self._f.fileno()

    def truncate(self, size=None):
        self._env._check("truncate", self.path)
        r = self._f.truncate(size)
        self._env._note_truncate(self.path, size if size is not None else self._f.tell())
        return r

    def close(self):
        return self._f.close()

    @property
    def closed(self):
        return self._f.closed

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _FileState:
    """Unsynced-write tracking for one path: bytes beyond ``synced_size`` and
    overwrites recorded in ``undo`` vanish on :meth:`drop_unsynced`.

    The undo log is bounded: only the *first* overwrite of each synced byte
    range is recorded (``covered`` tracks ranges already logged — their
    pre-overwrite originals are what a rollback restores, so later rewrites
    of the same bytes need no new entries). Total undo bytes per file can
    therefore never exceed ``synced_size``, no matter how many times a
    long-running workload rewrites the same region."""

    __slots__ = ("synced_size", "undo", "covered", "undo_bytes")

    def __init__(self, synced_size: int):
        self.synced_size = synced_size
        self.undo = []  # list[(offset, original_bytes)] for overwrites below synced_size
        self.covered = []  # sorted disjoint (start, end) ranges already in undo
        self.undo_bytes = 0

    def uncovered(self, start: int, end: int):
        """Subranges of [start, end) not yet present in the undo log."""
        out = []
        pos = start
        for s, e in self.covered:
            if e <= pos:
                continue
            if s >= end:
                break
            if s > pos:
                out.append((pos, s))
            pos = max(pos, e)
            if pos >= end:
                break
        if pos < end:
            out.append((pos, end))
        return out

    def cover(self, start: int, end: int) -> None:
        if start >= end:
            return
        ivs = self.covered + [(start, end)]
        ivs.sort()
        merged = [ivs[0]]
        for s, e in ivs[1:]:
            ls, le = merged[-1]
            if s <= le:
                merged[-1] = (ls, max(le, e))
            else:
                merged.append((s, e))
        self.covered = merged

    def clear_undo(self) -> None:
        self.undo.clear()
        self.covered.clear()
        self.undo_bytes = 0


class FaultInjectionEnv(Env):
    """Env that can fail operations on command, simulate whole-process
    crashes with loss of unsynced data, and corrupt bytes on disk."""

    def __init__(self, seed: int = 0):
        self._lock = threading.RLock()
        self._rules: list[FaultRule] = []
        self._rng = random.Random(seed)
        self._files: dict[str, _FileState] = {}
        self._fd_paths: dict[int, str] = {}
        # crash point: countdown over matching mutating ops; <0 = disarmed
        self._crash_countdown = -1
        self._crash_ops: frozenset = frozenset()
        self._crash_path_substr: str | None = None
        self._crashed = False
        self.op_counts: dict[str, int] = {}
        # replication-transport faults: (drop, duplicate, reorder, corrupt)
        # probabilities applied per shipped frame
        self._transport_faults = (0.0, 0.0, 0.0, 0.0)
        self._held_frame: bytes | None = None  # frame delayed by a reorder
        self.transport_stats = {
            "dropped": 0, "duplicated": 0, "reordered": 0, "corrupted": 0,
        }

    # ------------------------------------------------------------------
    # test-facing controls
    # ------------------------------------------------------------------
    def add_fault(
        self,
        op: str | None = None,
        path_substr: str | None = None,
        count: int | None = 1,
        probability: float = 1.0,
        error: int | BaseException | type = _errno.EIO,
    ) -> FaultRule:
        """Arm an injection rule. ``error`` may be an errno int, an exception
        instance/class, or a zero-arg callable returning an exception."""
        if isinstance(error, int):
            eno = error
            factory = lambda path: OSError(eno, os.strerror(eno), path)  # noqa: E731
        elif isinstance(error, BaseException):
            factory = lambda path, e=error: e  # noqa: E731
        elif isinstance(error, type) and issubclass(error, BaseException):
            factory = lambda path, cls=error: cls(f"injected fault at {path}")  # noqa: E731
        else:
            factory = lambda path, fn=error: fn()  # noqa: E731
        rule = FaultRule(op, path_substr, count, probability, factory)
        with self._lock:
            self._rules.append(rule)
        return rule

    def clear_faults(self) -> None:
        with self._lock:
            self._rules.clear()

    def set_crash_after(
        self,
        n: int,
        ops=("write", "sync", "rename", "unlink"),
        path_substr: str | None = None,
    ) -> None:
        """After ``n`` more matching mutating ops succeed, the simulated
        machine dies: every further mutating op raises SimulatedCrashError."""
        with self._lock:
            self._crash_countdown = max(0, n)
            self._crash_ops = frozenset(ops)
            self._crash_path_substr = path_substr
            self._crashed = n == 0

    def disarm_crash(self) -> None:
        with self._lock:
            self._crash_countdown = -1
            self._crashed = False

    def set_transport_faults(
        self,
        drop: float = 0.0,
        duplicate: float = 0.0,
        reorder: float = 0.0,
        corrupt: float = 0.0,
    ) -> None:
        """Per-frame fault probabilities for :meth:`ship`. ``reorder`` holds
        a frame back and delivers it after the next one (an adjacent swap);
        ``corrupt`` flips one byte, which the frame CRC must catch."""
        with self._lock:
            self._transport_faults = (drop, duplicate, reorder, corrupt)

    @property
    def undo_bytes(self) -> int:
        """Total bytes held in per-file overwrite undo logs (bounded: at most
        one entry per synced byte — see :class:`_FileState`)."""
        with self._lock:
            seen, total = set(), 0
            for st in self._files.values():
                if id(st) in seen:  # hard links share one state object
                    continue
                seen.add(id(st))
                total += st.undo_bytes
            return total

    def reset(self) -> None:
        """Return the env to a pristine state: clear fault rules, disarm any
        crash point, forget unsynced-write tracking, clear transport faults
        and the held reorder frame, and zero all counters. Long-lived
        harness loops call this between iterations so no state (including
        the undo log) accumulates across runs."""
        with self._lock:
            self._rules.clear()
            self._crash_countdown = -1
            self._crash_ops = frozenset()
            self._crash_path_substr = None
            self._crashed = False
            self._files.clear()
            self._fd_paths.clear()
            self._transport_faults = (0.0, 0.0, 0.0, 0.0)
            self._held_frame = None
            self.op_counts.clear()
            for k in self.transport_stats:
                self.transport_stats[k] = 0

    @property
    def crashed(self) -> bool:
        return self._crashed

    def release_tracking(self, prefix: str) -> None:
        """Disown tracked paths under ``prefix``: a completed checkpoint
        image belongs to whoever it was made for (a replica, an operator's
        backup target), so this env's ``drop_unsynced`` must not rewind
        those files once another failure domain starts writing them.
        Hard-link-shared state stays alive under the source path."""
        sep = prefix if prefix.endswith(os.sep) else prefix + os.sep
        with self._lock:
            for path in [p for p in self._files if p.startswith(sep)]:
                del self._files[path]

    def drop_unsynced(self) -> None:
        """Rewind every tracked file to its last-fsynced state (the on-disk
        image a real power-cut would leave, under a no-reorder disk model)."""
        with self._lock:
            for path, st in list(self._files.items()):
                try:
                    fd = os.open(path, os.O_RDWR)
                except FileNotFoundError:
                    continue
                try:
                    for off, original in reversed(st.undo):
                        os.pwrite(fd, original, off)
                    os.ftruncate(fd, st.synced_size)
                finally:
                    os.close(fd)
                st.clear_undo()
            # state survives: synced sizes are still the truth for these paths

    def reset_tracking(self) -> None:
        """Forget unsynced-write state (fresh boot of the simulated machine)."""
        with self._lock:
            self._files.clear()
            self._fd_paths.clear()

    def corrupt(self, path: str, offset: int, nbytes: int = 1) -> None:
        """Flip bits in ``nbytes`` bytes at ``offset`` (XOR 0xFF)."""
        with open(path, "r+b") as f:
            f.seek(offset)
            original = f.read(nbytes)
            f.seek(offset)
            f.write(bytes(b ^ 0xFF for b in original))

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _check(self, op: str, path: str, mutating: bool | None = None) -> None:
        """Rule + crash-point gate, called before the real operation.
        ``mutating`` overrides the op-kind default — a read-only ``open``
        must keep working after a simulated crash (the dead machine's disk
        is still readable), while a writable one must not."""
        if mutating is None:
            mutating = op in _MUTATING_OPS
        with self._lock:
            self.op_counts[op] = self.op_counts.get(op, 0) + 1
            if self._crashed and mutating:
                raise SimulatedCrashError(
                    _errno.EIO, f"simulated crash: {op} on {path}"
                )
            if (
                self._crash_countdown >= 0
                and op in self._crash_ops
                and (
                    self._crash_path_substr is None
                    or self._crash_path_substr in path
                )
            ):
                if self._crash_countdown == 0:
                    self._crashed = True
                    raise SimulatedCrashError(
                        _errno.EIO, f"simulated crash: {op} on {path}"
                    )
                self._crash_countdown -= 1
            for rule in self._rules:
                if not rule.matches(op, path):
                    continue
                if rule.count is not None and rule.count <= 0:
                    continue
                if rule.probability < 1.0 and self._rng.random() >= rule.probability:
                    continue
                if rule.count is not None:
                    rule.count -= 1
                raise rule.exc_factory(path)

    def _state(self, path: str, synced_size: int) -> _FileState:
        st = self._files.get(path)
        if st is None:
            st = self._files[path] = _FileState(synced_size)
        return st

    def _note_append(self, path: str, nbytes: int) -> None:
        # appends land past synced_size; nothing to record — drop_unsynced's
        # truncate handles them. Ensure the path is tracked.
        with self._lock:
            if path not in self._files:
                # opened before tracking started (shouldn't happen via open())
                self._files[path] = _FileState(0)

    def _note_truncate(self, path: str, size: int) -> None:
        with self._lock:
            st = self._files.get(path)
            if st is not None and size < st.synced_size:
                st.synced_size = size
                st.undo = [(o, b[: max(0, size - o)]) for o, b in st.undo if o < size]
                st.undo_bytes = sum(len(b) for _, b in st.undo)
                st.covered = [(s, min(e, size)) for s, e in st.covered if s < size]

    def _note_sync(self, path: str) -> None:
        with self._lock:
            st = self._files.get(path)
            if st is not None:
                try:
                    st.synced_size = os.path.getsize(path)
                except OSError:
                    pass
                st.clear_undo()

    # ------------------------------------------------------------------
    # Env surface
    # ------------------------------------------------------------------
    def open(self, path, mode="rb", buffering=-1):
        writable = any(c in mode for c in "wax+")
        self._check("open", path, mutating=writable)
        f = open(path, mode, buffering=buffering)
        if writable:
            with self._lock:
                if "w" in mode:
                    # truncating open: previously-synced content is gone
                    self._files[path] = _FileState(0)
                elif path not in self._files:
                    # append/update open of an existing file: whatever is on
                    # disk now was (conservatively) already durable
                    try:
                        size = os.path.getsize(path)
                    except OSError:
                        size = 0
                    self._files[path] = _FileState(size)
        return _FaultFile(self, f, path, writable)

    def fsync(self, f) -> None:
        if isinstance(f, int):
            path = self._fd_paths.get(f, "")
            self._check("sync", path)
            os.fsync(f)
            if path:
                self._note_sync(path)
        else:
            path = getattr(f, "path", getattr(f, "name", ""))
            self._check("sync", path)
            f.flush()
            os.fsync(f.fileno())
            self._note_sync(path)

    def open_fd(self, path, flags, mode=0o644) -> int:
        self._check(
            "open", path, mutating=bool(flags & (os.O_WRONLY | os.O_RDWR))
        )
        fd = os.open(path, flags, mode)
        with self._lock:
            self._fd_paths[fd] = path
            if flags & (os.O_WRONLY | os.O_RDWR):
                if path not in self._files:
                    try:
                        size = os.path.getsize(path)
                    except OSError:
                        size = 0
                    self._files[path] = _FileState(size)
        return fd

    def close_fd(self, fd: int) -> None:
        with self._lock:
            self._fd_paths.pop(fd, None)
        os.close(fd)

    def pread(self, fd: int, size: int, offset: int) -> bytes:
        self._check("read", self._fd_paths.get(fd, ""))
        return os.pread(fd, size, offset)

    def pread_f(self, f, size: int, offset: int) -> bytes:
        self._check("read", getattr(f, "path", getattr(f, "name", "")))
        return os.pread(f.fileno(), size, offset)

    def pwrite(self, fd: int, data: bytes, offset: int) -> int:
        path = self._fd_paths.get(fd, "")
        self._check("write", path)
        if path:
            with self._lock:
                st = self._files.get(path)
                if st is not None and offset < st.synced_size:
                    # overwriting durable bytes: remember the original so a
                    # simulated crash can undo the unsynced overwrite. Only
                    # ranges not already logged need an entry — the oldest
                    # original is what a rollback restores, so the undo log
                    # stays bounded by synced_size however often the same
                    # bytes are rewritten.
                    n = min(len(data), st.synced_size - offset)
                    for s, e in st.uncovered(offset, offset + n):
                        original = os.pread(fd, e - s, s)
                        st.undo.append((s, original))
                        st.undo_bytes += len(original)
                    st.cover(offset, offset + n)
        return os.pwrite(fd, data, offset)

    def truncate_fd(self, fd: int, size: int) -> None:
        path = self._fd_paths.get(fd, "")
        self._check("truncate", path)
        os.ftruncate(fd, size)
        if path:
            self._note_truncate(path, size)

    def rename(self, src, dst) -> None:
        self._check("rename", src)
        os.rename(src, dst)
        with self._lock:
            if src in self._files:
                self._files[dst] = self._files.pop(src)

    def unlink(self, path) -> None:
        self._check("unlink", path)
        os.unlink(path)
        with self._lock:
            self._files.pop(path, None)

    def listdir(self, path):
        self._check("listdir", path)
        return os.listdir(path)

    def link(self, src, dst) -> None:
        # a hard link shares the inode, so both names must share ONE state
        # object — independent copies let drop_unsynced ftruncate the inode
        # down through one name (its stale smaller synced_size) and then
        # zero-extend it back through the other, corrupting synced bytes
        # that a real power-cut would have kept
        self._check("link", dst)
        os.link(src, dst)
        with self._lock:
            st = self._files.get(src)
            if st is not None:
                self._files[dst] = st

    def ship(self, stream: str, blob: bytes) -> list:
        # crash/rule gate first: a dead primary cannot send, and crash
        # harnesses can arm kill points on the ship edge itself
        self._check("ship", stream)
        with self._lock:
            drop, dup, reorder, corrupt = self._transport_faults
            held, self._held_frame = self._held_frame, None
            out = []
            rnd = self._rng.random
            if drop and rnd() < drop:
                self.transport_stats["dropped"] += 1
            else:
                if corrupt and blob and rnd() < corrupt:
                    i = self._rng.randrange(len(blob))
                    blob = blob[:i] + bytes((blob[i] ^ 0xFF,)) + blob[i + 1:]
                    self.transport_stats["corrupted"] += 1
                if reorder and rnd() < reorder:
                    # hold this frame back; it rides after the next send
                    self._held_frame = blob
                    self.transport_stats["reordered"] += 1
                else:
                    out.append(blob)
                    if dup and rnd() < dup:
                        out.append(blob)
                        self.transport_stats["duplicated"] += 1
            if held is not None:
                out.append(held)
            return out
