"""Block-based SSTable (LevelDB-style layout, simplified).

File layout::

    [data block 0] ... [data block N-1] [filter block] [index block] [footer]

* data block — entries sorted by user key:
  ``varint(klen) key varint(seq) type(1B) varint(vlen) value``;
  1-byte compression flag + optional zstd per block.

  **Format v2** appends a restart-point trailer to the (pre-compression)
  block payload: ``[u32 offset x R][u32 R]`` where each offset points at an
  entry boundary, one per ``restart_interval`` entries. Point lookups
  binary-search the restart array (decoding only one key per probe) and
  then decode at most ``restart_interval`` entries — replacing v1's
  full-block linear decode. v2/v3 entries are not prefix-compressed, so
  every entry boundary is self-parseable.

  **Format v4** prefix-compresses keys inside each restart interval
  (LevelDB-style): ``varint(shared) varint(non_shared) key_suffix
  varint(seq) type(1B) varint(vlen) value`` where ``shared`` is the byte
  length of the prefix reused from the PREVIOUS entry's key. Every restart
  entry writes ``shared = 0`` (full key), so restart offsets stay
  self-parseable and the v2 restart binary search works unchanged; only
  the linear walk between restarts becomes stateful (it rebuilds keys from
  the running previous key).
* filter block — :class:`~repro.core.bloom.BloomFilter` over user keys.
* index block — msgpack list of ``(last_key, offset, length[, crc32])``;
  the optional 4th element is the block's crc32, verified on read under
  ``paranoid_checks`` and by the ``DB.verify_integrity`` scrub. Tables
  written before the CRC existed decode fine (entries are 3-wide).
* range-tombstone block (v3) — msgpack list of ``(seq, start, end)``
  range deletes carried by this table (end exclusive), placed between the
  index block and the footer. Empty list when the table has none.
* footer — v1: fixed 40 B ``filter_off, filter_len, index_off, index_len,
  magic``; v2: fixed 48 B with a ``version`` field before a new magic;
  v3/v4: fixed 64 B adding ``range_off, range_len`` before the version
  field (v4 shares the v3 footer layout and magic — the version field
  disambiguates). Readers dispatch on the trailing magic, so v1 tables
  written by older code keep decoding forever (compat rule: readers
  support every version ≤ FORMAT_VERSION; writers emit
  ``DBConfig.sstable_format_version``).

A user key may appear MULTIPLE times within a table (format v3+ / MVCC):
entries are sorted by (user_key asc, seq desc), so the first occurrence of
a key is its newest version — point lookups still resolve on the first hit.
Single-version tables behave exactly as before.

Decoded blocks are wrapped in :class:`Block` objects so a shared
:class:`~repro.core.blockcache.BlockCache` can hold them across reads: the
first access decodes lazily (restart binary search / early-exit scan), and
a block that is hit again — i.e. one that stayed cached — materializes a
parsed entry list + key index once, making every later lookup a dict/bisect
operation instead of byte parsing.
"""
from __future__ import annotations

import bisect
import os
import struct
import zlib
from dataclasses import dataclass

import msgpack

try:  # optional dep: compression degrades to store-uncompressed when absent
    import zstandard

    _ZCTX = zstandard.ZstdCompressor(level=1)
    _DCTX = zstandard.ZstdDecompressor()
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None
    _ZCTX = None
    _DCTX = None

from .bloom import BloomFilter
from .env import DEFAULT_ENV
from .errors import CorruptionError
from .record import decode_varint, encode_varint

_FOOTER_V1 = struct.Struct("<QQQQQ")
_FOOTER_V2 = struct.Struct("<QQQQQQ")
_FOOTER_V3 = struct.Struct("<QQQQQQQQ")
_MAGIC_V1 = 0xB7_15_3D_CA_FE_10_57_01
_MAGIC_V2 = 0xB7_15_3D_CA_FE_10_57_02
_MAGIC_V3 = 0xB7_15_3D_CA_FE_10_57_03
_U32 = struct.Struct("<I")

#: newest on-disk format this build writes (and the max it can read)
FORMAT_VERSION = 4


def _shared_prefix_len(a: bytes, b: bytes) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


@dataclass(slots=True)
class FileMetadata:
    file_no: int
    size: int
    smallest: bytes
    largest: bytes
    entries: int

    def to_wire(self):
        return [self.file_no, self.size, self.smallest, self.largest, self.entries]

    @staticmethod
    def from_wire(w) -> "FileMetadata":
        return FileMetadata(w[0], w[1], bytes(w[2]), bytes(w[3]), w[4])


def table_path(directory: str, file_no: int) -> str:
    return os.path.join(directory, f"{file_no:06d}.sst")


class SSTableWriter:
    def __init__(
        self,
        path: str,
        block_size: int = 4096,
        compression: bool = False,
        format_version: int = FORMAT_VERSION,
        restart_interval: int = 16,
        env=None,
    ):
        if not 1 <= format_version <= FORMAT_VERSION:
            raise ValueError(f"unsupported sstable format_version {format_version}")
        self.path = path
        self.block_size = block_size
        self.compression = compression
        self.format_version = format_version
        self.restart_interval = max(1, restart_interval)
        self._env = env or DEFAULT_ENV
        self._f = self._env.open(path, "wb")
        self._block: list[bytes] = []
        self._block_bytes = 0
        self._restarts: list[int] = []
        # index entries are (last_key, offset, length, crc32-of-blob); the
        # crc is a 4th element so v2 tables written before it existed (plain
        # 3-element entries) keep decoding — readers accept both widths.
        self._index: list[tuple[bytes, int, int, int]] = []
        self._keys: list[bytes] = []
        self._offset = 0
        self._count = 0
        self._last_seq: int | None = None
        self.smallest: bytes | None = None
        self.largest: bytes | None = None

    def add(self, key: bytes, seq: int, type_: int, value: bytes) -> None:
        # (user_key asc, seq desc): duplicate user keys are multi-version
        # runs and must arrive newest-first
        assert (
            self.largest is None
            or key > self.largest
            or (key == self.largest and seq < self._last_seq)
        ), "keys must be added in (user_key asc, seq desc) order"
        if self.smallest is None:
            self.smallest = key
        dup = key == self.largest
        prev_key = self.largest
        self.largest = key
        self._last_seq = seq
        at_restart = len(self._block) % self.restart_interval == 0
        if at_restart:
            self._restarts.append(self._block_bytes)
        if self.format_version >= 4:
            # prefix-compress against the previous entry IN THIS BLOCK;
            # restart entries always carry the full key (shared = 0) so
            # restart offsets stay self-parseable
            shared = 0
            if not at_restart and self._block:
                shared = _shared_prefix_len(prev_key, key)
            ent = b"".join(
                (
                    encode_varint(shared),
                    encode_varint(len(key) - shared),
                    key[shared:],
                    encode_varint(seq),
                    bytes([type_]),
                    encode_varint(len(value)),
                    value,
                )
            )
        else:
            ent = b"".join(
                (
                    encode_varint(len(key)),
                    key,
                    encode_varint(seq),
                    bytes([type_]),
                    encode_varint(len(value)),
                    value,
                )
            )
        self._block.append(ent)
        self._block_bytes += len(ent)
        if not dup:  # bloom + last-key tracking want distinct user keys
            self._keys.append(key)
        self._count += 1
        if self._block_bytes >= self.block_size:
            self._flush_block(key)

    def _flush_block(self, last_key: bytes) -> None:
        if not self._block:
            return
        raw = b"".join(self._block)
        if self.format_version >= 2:
            raw += b"".join(_U32.pack(o) for o in self._restarts)
            raw += _U32.pack(len(self._restarts))
        if self.compression and _ZCTX is not None:
            comp = _ZCTX.compress(raw)
            blob = b"\x01" + comp if len(comp) < len(raw) else b"\x00" + raw
        else:
            blob = b"\x00" + raw
        self._f.write(blob)
        self._index.append((last_key, self._offset, len(blob), zlib.crc32(blob) & 0xFFFFFFFF))
        self._offset += len(blob)
        self._block = []
        self._block_bytes = 0
        self._restarts = []

    def finish(self, file_no: int, range_tombstones=()) -> FileMetadata:
        """``range_tombstones``: iterable of (seq, start, end) range deletes
        carried by this table (format v3+). The returned metadata's
        smallest/largest are EXTENDED by the tombstone bounds so version
        candidate selection routes covered point reads at this file (the
        exclusive end is used as an inclusive largest — a safe
        over-approximation)."""
        if range_tombstones and self.format_version < 3:
            raise ValueError("range tombstones need sstable format v3+")
        if self._block:
            self._flush_block(self._keys[-1])
        bloom = BloomFilter.build(self._keys).encode()
        filter_off = self._offset
        self._f.write(bloom)
        index = msgpack.packb([[k, o, ln, crc] for k, o, ln, crc in self._index])
        index_off = filter_off + len(bloom)
        self._f.write(index)
        range_off = index_off + len(index)
        range_blob = b""
        if self.format_version >= 3:
            range_blob = msgpack.packb(
                [[s, a, b] for s, a, b in sorted(range_tombstones)]
            )
            self._f.write(range_blob)
        if self.format_version >= 3:
            footer = _FOOTER_V3.pack(
                filter_off, len(bloom), index_off, len(index),
                range_off, len(range_blob), self.format_version, _MAGIC_V3,
            )
        elif self.format_version == 2:
            footer = _FOOTER_V2.pack(
                filter_off, len(bloom), index_off, len(index),
                self.format_version, _MAGIC_V2,
            )
        else:
            footer = _FOOTER_V1.pack(
                filter_off, len(bloom), index_off, len(index), _MAGIC_V1
            )
        self._f.write(footer)
        self._f.flush()
        self._env.fsync(self._f)
        self._f.close()
        size = range_off + len(range_blob) + len(footer)
        smallest, largest = self.smallest, self.largest
        for seq, start, end in range_tombstones:
            if smallest is None or start < smallest:
                smallest = start
            if largest is None or end > largest:
                largest = end
        return FileMetadata(file_no, size, smallest or b"", largest or b"", self._count)

    def abandon(self) -> None:
        self._f.close()
        self._env.unlink(self.path)


def _decompress(blob: bytes) -> bytes:
    if blob[0] == 1:
        if _DCTX is None:
            raise IOError("zstd-compressed block but the zstandard module is unavailable")
        return _DCTX.decompress(blob[1:])
    return blob[1:]


def _parse_entry(raw: bytes, pos: int) -> tuple[bytes, int, int, bytes, int]:
    """Decode one entry at ``pos``; returns (key, seq, type, value, next_pos)."""
    klen, pos = decode_varint(raw, pos)
    key = raw[pos : pos + klen]
    pos += klen
    seq, pos = decode_varint(raw, pos)
    type_ = raw[pos]
    pos += 1
    vlen, pos = decode_varint(raw, pos)
    value = raw[pos : pos + vlen]
    pos += vlen
    return key, seq, type_, value, pos


def _entry_key(raw: bytes, pos: int) -> bytes:
    """Decode only the user key of the entry at ``pos`` (restart probes)."""
    klen, pos = decode_varint(raw, pos)
    return raw[pos : pos + klen]


def _parse_entry_pfx(raw: bytes, pos: int, prev_key: bytes) -> tuple[bytes, int, int, bytes, int]:
    """Decode one prefix-compressed (v4) entry at ``pos``; the key is
    rebuilt from ``prev_key``'s shared prefix + the stored suffix."""
    shared, pos = decode_varint(raw, pos)
    non_shared, pos = decode_varint(raw, pos)
    suffix = raw[pos : pos + non_shared]
    key = prev_key[:shared] + suffix if shared else suffix
    pos += non_shared
    seq, pos = decode_varint(raw, pos)
    type_ = raw[pos]
    pos += 1
    vlen, pos = decode_varint(raw, pos)
    value = raw[pos : pos + vlen]
    pos += vlen
    return key, seq, type_, value, pos


def _restart_key_pfx(raw: bytes, pos: int) -> bytes:
    """Decode only the user key of the v4 entry at a RESTART offset
    (``shared`` is 0 there, so the stored suffix is the whole key)."""
    _shared, pos = decode_varint(raw, pos)
    klen, pos = decode_varint(raw, pos)
    return raw[pos : pos + klen]


class Block:
    """One decoded data block: entry bytes plus (v2) the restart array.

    Access-adaptive decoding: the first :meth:`get` stays lazy — restart
    binary search on v2, early-exit linear scan on v1 — so one-shot reads
    (cache disabled, compaction) never materialize anything. A second
    ``get`` on the same object means the block survived in the cache, so it
    pays one full parse and serves every later lookup from a key→entry dict
    and every iteration from the parsed list.
    """

    __slots__ = (
        "raw", "limit", "restarts", "prefixed", "_gets", "_entries", "_keys", "_kv",
        "_mat_extra", "_cache", "_cache_key",
    )

    def __init__(self, blob: bytes):
        if blob[0] > 1:  # reserved for future block encodings
            raise IOError(f"unknown block encoding {blob[0]}")
        raw = _decompress(blob)
        self.restarts: tuple[int, ...] | None = None
        self.prefixed = False  # v4 prefix-compressed entries
        self.limit = len(raw)
        self.raw = raw
        self._gets = 0
        self._entries: list[tuple[bytes, int, int, bytes]] | None = None
        self._keys: list[bytes] | None = None
        self._kv: dict | None = None
        self._mat_extra = 0  # extra bytes held by the materialized structures
        self._cache = None  # set by BlockCache.put; recharged on materialize
        self._cache_key: tuple[int, int] | None = None

    @classmethod
    def from_blob(cls, blob: bytes, version: int) -> "Block":
        blk = cls(blob)
        if version >= 2:
            raw = blk.raw
            (n_restarts,) = _U32.unpack_from(raw, len(raw) - 4)
            trailer = 4 + 4 * n_restarts
            blk.restarts = struct.unpack_from(f"<{n_restarts}I", raw, len(raw) - trailer)
            blk.limit = len(raw) - trailer
        blk.prefixed = version >= 4
        return blk

    @property
    def charge(self) -> int:
        """Cache accounting: decoded payload bytes + fixed object overhead,
        plus the parsed-structure estimate once the block materializes (the
        cache is re-charged at that point — see ``_materialize``)."""
        return len(self.raw) + 64 + self._mat_extra

    # -- point lookup ---------------------------------------------------
    def get(self, key: bytes):
        """Return (key, seq, type, value) or None."""
        if self._kv is None:
            self._gets += 1
            if self._gets < 2:
                return self._lazy_get(key)
            self._materialize()
        ent = self._kv.get(key)
        return None if ent is None else (key, *ent)

    def _lazy_get(self, key: bytes):
        raw, limit = self.raw, self.limit
        prefixed = self.prefixed
        pos = 0
        if self.restarts:
            # binary search the restart array: find the LAST restart whose
            # key is strictly BELOW the target; only one key is decoded per
            # probe. (``<`` not ``<=``: with multi-version duplicate-key
            # runs a restart can land mid-run, and starting there would
            # return an older version instead of the newest.) Restart
            # entries always store their full key, prefixed or not.
            restart_key = _restart_key_pfx if prefixed else _entry_key
            restarts = self.restarts
            lo, hi = 0, len(restarts) - 1
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if restart_key(raw, restarts[mid]) < key:
                    lo = mid
                else:
                    hi = mid - 1
            pos = restarts[lo]
        if prefixed:
            # in-place key reconstruction: one bytearray mutated per entry
            # (`del buf[shared:]` + append suffix) instead of slice+concat
            # allocations, and values of skipped entries are never sliced —
            # this walk is HOT (every cache-off get) and must not lose to
            # the uncompressed v2 walk it replaces
            buf = bytearray()
            while pos < limit:
                # varints inlined for the one-byte case (shared/non_shared
                # are bounded by the key length, vlen by the block size —
                # almost always < 128): the function-call overhead per
                # entry is what this loop's throughput lives and dies by
                shared = raw[pos]
                if shared < 0x80:
                    pos += 1
                else:
                    shared, pos = decode_varint(raw, pos)
                non_shared = raw[pos]
                if non_shared < 0x80:
                    pos += 1
                else:
                    non_shared, pos = decode_varint(raw, pos)
                del buf[shared:]
                buf += raw[pos : pos + non_shared]
                pos += non_shared
                seq, pos = decode_varint(raw, pos)
                type_ = raw[pos]
                pos += 1
                vlen = raw[pos]
                if vlen < 0x80:
                    pos += 1
                else:
                    vlen, pos = decode_varint(raw, pos)
                if buf == key:
                    return key, seq, type_, raw[pos : pos + vlen]
                if buf > key:
                    return None
                pos += vlen
            return None
        while pos < limit:
            k, seq, type_, value, pos = _parse_entry(raw, pos)
            if k == key:
                return k, seq, type_, value
            if k > key:
                return None
        return None

    def _materialize(self) -> None:
        entries = list(self._scan(0))
        # publication order matters: other threads gate on _kv (get) and
        # _entries (iteration), so every side structure must be complete
        # before EITHER gate field is assigned — _keys first, _kv next,
        # _entries last. Each assignment publishes a fully-built object, so
        # a concurrent reader sees either the lazy path or the fast path,
        # never a half-built one.
        self._keys = [e[0] for e in entries]
        # first occurrence wins: with multi-version runs the first entry for
        # a user key is its NEWEST version (a plain dict comprehension would
        # keep the last = oldest)
        kv: dict = {}
        for e in entries:
            kv.setdefault(e[0], (e[1], e[2], e[3]))
        self._kv = kv
        # parsed copies hold the key/value bytes again plus per-entry
        # object overhead (tuple + dict/list slots)
        self._mat_extra = sum(len(e[0]) * 2 + len(e[3]) for e in entries) + 120 * len(entries)
        self._entries = entries
        cache = self._cache
        if cache is not None:
            cache.recharge(self._cache_key, self)

    # -- iteration ------------------------------------------------------
    def _scan(self, pos: int):
        """Yield entries from ``pos`` to the block end. ``pos`` must be an
        entry boundary — and, for prefixed (v4) blocks, a RESTART boundary
        (mid-interval entries don't carry their full key)."""
        raw, limit = self.raw, self.limit
        if self.prefixed:
            prev = b""
            while pos < limit:
                k, seq, type_, value, pos = _parse_entry_pfx(raw, pos, prev)
                prev = k
                yield k, seq, type_, value
        else:
            while pos < limit:
                k, seq, type_, value, pos = _parse_entry(raw, pos)
                yield k, seq, type_, value

    def __iter__(self):
        if self._entries is not None:
            yield from self._entries
            return
        yield from self._scan(0)

    def iter_from(self, start: bytes):
        if self._entries is not None:
            yield from self._entries[bisect.bisect_left(self._keys, start):]
            return
        pos = 0
        if self.restarts:
            raw = self.raw
            restart_key = _restart_key_pfx if self.prefixed else _entry_key
            restarts = self.restarts
            lo, hi = 0, len(restarts) - 1
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if restart_key(raw, restarts[mid]) < start:
                    lo = mid
                else:
                    hi = mid - 1
            pos = restarts[lo]
        for ent in self._scan(pos):
            if ent[0] >= start:
                yield ent

    def largest_below(self, bound: bytes | None) -> bytes | None:
        """Largest user key strictly below ``bound`` in this block (reverse
        cursor step); ``None`` bound means unbounded (the block's last
        key). Linear within one block — blocks are ~4 KiB."""
        if self._keys is not None:
            if bound is None:
                return self._keys[-1] if self._keys else None
            i = bisect.bisect_left(self._keys, bound)
            return self._keys[i - 1] if i else None
        best = None
        for k, _seq, _type, _value in self:
            if bound is not None and k >= bound:
                break
            best = k
        return best


class SSTableReader:
    """Random + sequential access to one table.

    ``cache`` (a :class:`~repro.core.blockcache.BlockCache`) is shared
    across every reader of a DB; blocks are keyed ``(file_no, block_idx)``.
    ``fill_cache=False`` on the iteration APIs reads through the cache but
    never populates it (compaction bypass — one-shot streams should not
    evict the foreground working set).
    """

    def __init__(self, path: str, file_no: int = 0, cache=None, env=None, paranoid=False):
        self.path = path
        self.file_no = file_no
        self.cache = cache
        self._env = env or DEFAULT_ENV
        self.paranoid = paranoid
        self._f = self._env.open(path, "rb")
        self._f.seek(0, os.SEEK_END)
        file_size = self._f.tell()
        tail = self._env.pread_f(self._f, min(file_size, _FOOTER_V3.size), max(0, file_size - _FOOTER_V3.size))
        (magic,) = struct.unpack_from("<Q", tail, len(tail) - 8)
        range_off = range_len = 0
        if magic == _MAGIC_V1:
            filter_off, filter_len, index_off, index_len, _ = _FOOTER_V1.unpack(
                tail[len(tail) - _FOOTER_V1.size:]
            )
            self.format_version = 1
        elif magic == _MAGIC_V2:
            filter_off, filter_len, index_off, index_len, version, _ = _FOOTER_V2.unpack(
                tail[len(tail) - _FOOTER_V2.size:]
            )
            if version > FORMAT_VERSION:
                raise IOError(
                    f"{path}: sstable format v{version} is newer than this build (v{FORMAT_VERSION})"
                )
            self.format_version = version
        elif magic == _MAGIC_V3:
            (filter_off, filter_len, index_off, index_len,
             range_off, range_len, version, _) = _FOOTER_V3.unpack(tail)
            if version > FORMAT_VERSION:
                raise IOError(
                    f"{path}: sstable format v{version} is newer than this build (v{FORMAT_VERSION})"
                )
            self.format_version = version
        else:
            raise IOError(f"bad SSTable magic in {path}")
        #: (seq, start, end-exclusive) range tombstones, sorted by seq —
        #: empty for v1/v2 tables
        self.range_tombstones: list[tuple[int, bytes, bytes]] = []
        if range_len:
            self.range_tombstones = [
                (e[0], bytes(e[1]), bytes(e[2]))
                for e in msgpack.unpackb(self._env.pread_f(self._f, range_len, range_off))
            ]
        self.bloom = BloomFilter.decode(self._env.pread_f(self._f, filter_len, filter_off))
        # index entries may be 3-wide (pre-CRC tables) or 4-wide (with a
        # per-block crc32). ``self.index`` stays 3-tuples — downstream code
        # (compaction bounds augmentation) unpacks ``k, off, len`` — and the
        # crcs live in a parallel list (None per block when absent).
        raw_index = msgpack.unpackb(self._env.pread_f(self._f, index_len, index_off))
        self.index = [(bytes(e[0]), e[1], e[2]) for e in raw_index]
        self.block_crcs = [e[3] if len(e) > 3 else None for e in raw_index]

    def _read_block(self, idx: int, fill_cache: bool = True, meter=None) -> Block:
        cache = self.cache
        if cache is not None:
            key = (self.file_no, idx)
            # bypass streams peek: no MRU promotion, no hit/miss accounting
            blk = cache.get(key) if fill_cache else cache.peek(key)
            if blk is not None:
                return blk
        _, off, length = self.index[idx]
        if meter is not None:
            # charge the I/O budget for the bytes about to leave the disk —
            # cache hits above never reach here, so only real preads pay
            meter(length)
        # positional read: one reader object is shared by foreground gets
        # and background flush/compaction iterators, and a seek+read pair
        # would interleave offsets between threads (silently decoding the
        # wrong block). pread has no cursor, so it is race-free.
        blob = self._env.pread_f(self._f, length, off)
        if self.paranoid:
            self._check_block(idx, blob, length)
        blk = Block.from_blob(blob, self.format_version)
        if cache is not None and fill_cache:
            cache.put(key, blk)
        return blk

    def _check_block(self, idx: int, blob: bytes, length: int) -> None:
        """CRC-verify one block's raw bytes. A short read is an OSError
        (truncation/unlink race — transient, retryable), never corruption:
        only a full-length blob whose checksum disagrees is corrupt."""
        if len(blob) != length:
            raise IOError(
                f"short SSTable block read in {self.path} "
                f"(block {idx}: got {len(blob)}, want {length})"
            )
        crc = self.block_crcs[idx]
        if crc is not None and (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
            raise CorruptionError(
                f"SSTable block CRC mismatch in {self.path} (block {idx})",
                sst_file_no=self.file_no,
                path=self.path,
            )

    def verify_block(self, idx: int) -> None:
        """Scrub entry point: read block ``idx`` from disk (never the cache),
        CRC-verify it regardless of ``paranoid``, and fully parse it.
        Raises CorruptionError on bad bytes, OSError on short reads."""
        _, off, length = self.index[idx]
        blob = self._env.pread_f(self._f, length, off)
        self._check_block(idx, blob, length)
        try:
            for _ in Block.from_blob(blob, self.format_version):
                pass
        except CorruptionError:
            raise
        except Exception as exc:
            # undecodable despite a matching (or absent) CRC — pre-CRC
            # tables land here when their bytes are damaged
            raise CorruptionError(
                f"SSTable block {idx} in {self.path} failed to parse: {exc}",
                sst_file_no=self.file_no,
                path=self.path,
            ) from exc

    def _seek_block(self, key: bytes) -> int:
        """Index of the first block whose last_key >= key (or len(index))."""
        index = self.index
        lo, hi = 0, len(index) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if index[mid][0] < key:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def get(self, key: bytes):
        """Returns (found, seq, type, value) — the newest version of key."""
        if not self.bloom.may_contain(key):
            return False, 0, 0, b""
        lo = self._seek_block(key)
        if lo >= len(self.index) or self.index[lo][0] < key:
            return False, 0, 0, b""
        ent = self._read_block(lo).get(key)
        if ent is None:
            return False, 0, 0, b""
        return True, ent[1], ent[2], ent[3]

    def get_at(self, key: bytes, read_seq: int):
        """Snapshot point read: newest version of ``key`` with
        ``seq <= read_seq`` — walks the key's (possibly block-spanning)
        multi-version run. Returns (found, seq, type, value)."""
        if not self.bloom.may_contain(key):
            return False, 0, 0, b""
        return self._get_at_nobloom(key, read_seq)

    def _get_at_nobloom(self, key: bytes, read_seq: int):
        for k, seq, type_, value in self.iter_from(key):
            if k != key:
                break
            if seq <= read_seq:
                return True, seq, type_, value
        return False, 0, 0, b""

    def get_many(self, keys, read_seq: int | None = None) -> dict:
        """Batch point lookup: all ``keys`` against this table in one pass.

        Returns ``{key: (seq, type, value)}`` for the keys present (newest
        version, or newest with ``seq <= read_seq`` when given). The whole
        batch is bloom-probed in ONE vectorized call, survivors are grouped
        by data block, and each block is fetched/decoded once no matter how
        many keys land in it.
        """
        out: dict = {}
        index = self.index
        if not index or not keys:
            return out
        mask = self.bloom.may_contain_many(keys)
        n_blocks = len(index)
        by_block: dict[int, list[bytes]] = {}
        for key, maybe in zip(keys, mask):
            if not maybe:
                continue
            b = self._seek_block(key)
            if b >= n_blocks or index[b][0] < key:
                continue
            by_block.setdefault(b, []).append(key)
        if read_seq is None:
            for b, ks in by_block.items():
                blk = self._read_block(b)
                for key in ks:
                    ent = blk.get(key)
                    if ent is not None:
                        out[key] = (ent[1], ent[2], ent[3])
        else:
            # snapshot reads walk multi-version runs that may span blocks;
            # bloom negatives are already gone and block fetches still
            # coalesce through the cache
            for ks in by_block.values():
                for key in ks:
                    found, seq, type_, value = self._get_at_nobloom(key, read_seq)
                    if found:
                        out[key] = (seq, type_, value)
        return out

    def max_tombstone_seq(self, key: bytes, read_seq: int) -> int:
        """Max seq of a range tombstone in THIS table covering ``key`` and
        visible at ``read_seq`` (0 if none)."""
        best = 0
        for seq, start, end in self.range_tombstones:
            if seq <= read_seq and start <= key < end and seq > best:
                best = seq
        return best

    def largest_key_below(self, bound: bytes | None) -> bytes | None:
        """Largest user key strictly below ``bound`` (reverse cursor);
        ``None`` bound means unbounded (the table's last point key)."""
        if not self.index:
            return None
        if bound is None:
            idx = len(self.index) - 1
        else:
            idx = min(self._seek_block(bound), len(self.index) - 1)
        while idx >= 0:
            best = self._read_block(idx).largest_below(bound)
            if best is not None:
                return best
            idx -= 1  # at most one extra hop: block idx-1's last_key < bound
        return None

    def __iter__(self):
        yield from self.iter_all()

    def iter_all(self, fill_cache: bool = True, meter=None):
        for i in range(len(self.index)):
            yield from self._read_block(i, fill_cache, meter)

    def iter_from(self, start: bytes, fill_cache: bool = True, meter=None):
        lo = self._seek_block(start)
        if lo < len(self.index):
            yield from self._read_block(lo, fill_cache, meter).iter_from(start)
        for i in range(lo + 1, len(self.index)):
            yield from self._read_block(i, fill_cache, meter)

    def close(self) -> None:
        self._f.close()
