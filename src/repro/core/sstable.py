"""Block-based SSTable (LevelDB-style layout, simplified).

File layout::

    [data block 0] ... [data block N-1] [filter block] [index block] [footer]

* data block — entries sorted by user key:
  ``varint(klen) key varint(seq) type(1B) varint(vlen) value``;
  1-byte compression flag + optional zstd per block.
* filter block — :class:`~repro.core.bloom.BloomFilter` over user keys.
* index block — msgpack list of ``(last_key, offset, length)``.
* footer — fixed 40 B: filter_off, filter_len, index_off, index_len, magic.

Within a table every user key appears at most once (the engine has no
snapshot support; MemTable dedups and compaction keeps the newest version),
which keeps point lookups single-probe.
"""
from __future__ import annotations

import os
import struct
from dataclasses import dataclass

import msgpack

try:  # optional dep: compression degrades to store-uncompressed when absent
    import zstandard

    _ZCTX = zstandard.ZstdCompressor(level=1)
    _DCTX = zstandard.ZstdDecompressor()
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None
    _ZCTX = None
    _DCTX = None

from .bloom import BloomFilter
from .record import decode_varint, encode_varint

_FOOTER = struct.Struct("<QQQQQ")
_MAGIC = 0xB7_15_3D_CA_FE_10_57_01


@dataclass(slots=True)
class FileMetadata:
    file_no: int
    size: int
    smallest: bytes
    largest: bytes
    entries: int

    def to_wire(self):
        return [self.file_no, self.size, self.smallest, self.largest, self.entries]

    @staticmethod
    def from_wire(w) -> "FileMetadata":
        return FileMetadata(w[0], w[1], bytes(w[2]), bytes(w[3]), w[4])


def table_path(directory: str, file_no: int) -> str:
    return os.path.join(directory, f"{file_no:06d}.sst")


class SSTableWriter:
    def __init__(self, path: str, block_size: int = 4096, compression: bool = False):
        self.path = path
        self.block_size = block_size
        self.compression = compression
        self._f = open(path, "wb")
        self._block: list[bytes] = []
        self._block_bytes = 0
        self._index: list[tuple[bytes, int, int]] = []
        self._keys: list[bytes] = []
        self._offset = 0
        self._count = 0
        self.smallest: bytes | None = None
        self.largest: bytes | None = None

    def add(self, key: bytes, seq: int, type_: int, value: bytes) -> None:
        assert self.largest is None or key > self.largest, "keys must be added in order"
        if self.smallest is None:
            self.smallest = key
        self.largest = key
        ent = b"".join(
            (
                encode_varint(len(key)),
                key,
                encode_varint(seq),
                bytes([type_]),
                encode_varint(len(value)),
                value,
            )
        )
        self._block.append(ent)
        self._block_bytes += len(ent)
        self._keys.append(key)
        self._count += 1
        if self._block_bytes >= self.block_size:
            self._flush_block(key)

    def _flush_block(self, last_key: bytes) -> None:
        if not self._block:
            return
        raw = b"".join(self._block)
        if self.compression and _ZCTX is not None:
            comp = _ZCTX.compress(raw)
            blob = b"\x01" + comp if len(comp) < len(raw) else b"\x00" + raw
        else:
            blob = b"\x00" + raw
        self._f.write(blob)
        self._index.append((last_key, self._offset, len(blob)))
        self._offset += len(blob)
        self._block = []
        self._block_bytes = 0

    def finish(self, file_no: int) -> FileMetadata:
        if self._block:
            self._flush_block(self._keys[-1])
        bloom = BloomFilter.build(self._keys).encode()
        filter_off = self._offset
        self._f.write(bloom)
        index = msgpack.packb([[k, o, l] for k, o, l in self._index])
        index_off = filter_off + len(bloom)
        self._f.write(index)
        self._f.write(_FOOTER.pack(filter_off, len(bloom), index_off, len(index), _MAGIC))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        size = index_off + len(index) + _FOOTER.size
        return FileMetadata(file_no, size, self.smallest or b"", self.largest or b"", self._count)

    def abandon(self) -> None:
        self._f.close()
        os.unlink(self.path)


def _decode_block(blob: bytes) -> bytes:
    if blob[0] == 1:
        if _DCTX is None:
            raise IOError("zstd-compressed block but the zstandard module is unavailable")
        return _DCTX.decompress(blob[1:])
    return blob[1:]


def _iter_block(raw: bytes):
    pos = 0
    n = len(raw)
    while pos < n:
        klen, pos = decode_varint(raw, pos)
        key = raw[pos : pos + klen]
        pos += klen
        seq, pos = decode_varint(raw, pos)
        type_ = raw[pos]
        pos += 1
        vlen, pos = decode_varint(raw, pos)
        value = raw[pos : pos + vlen]
        pos += vlen
        yield key, seq, type_, value


class SSTableReader:
    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        self._f.seek(-_FOOTER.size, os.SEEK_END)
        filter_off, filter_len, index_off, index_len, magic = _FOOTER.unpack(
            self._f.read(_FOOTER.size)
        )
        if magic != _MAGIC:
            raise IOError(f"bad SSTable magic in {path}")
        self._f.seek(filter_off)
        self.bloom = BloomFilter.decode(self._f.read(filter_len))
        self._f.seek(index_off)
        self.index = [
            (bytes(k), o, l) for k, o, l in msgpack.unpackb(self._f.read(index_len))
        ]

    def _read_block(self, idx: int) -> bytes:
        _, off, length = self.index[idx]
        # positional read: one reader object is shared by foreground gets
        # and background flush/compaction iterators, and a seek+read pair
        # would interleave offsets between threads (silently decoding the
        # wrong block). pread has no cursor, so it is race-free.
        return _decode_block(os.pread(self._f.fileno(), length, off))

    def get(self, key: bytes):
        """Returns (found, seq, type, value)."""
        if not self.bloom.may_contain(key):
            return False, 0, 0, b""
        lo, hi = 0, len(self.index) - 1
        # first block whose last_key >= key
        while lo < hi:
            mid = (lo + hi) // 2
            if self.index[mid][0] < key:
                lo = mid + 1
            else:
                hi = mid
        if lo >= len(self.index) or self.index[lo][0] < key:
            return False, 0, 0, b""
        for k, seq, type_, value in _iter_block(self._read_block(lo)):
            if k == key:
                return True, seq, type_, value
            if k > key:
                break
        return False, 0, 0, b""

    def __iter__(self):
        for i in range(len(self.index)):
            yield from _iter_block(self._read_block(i))

    def iter_from(self, start: bytes):
        lo, hi = 0, len(self.index) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if self.index[mid][0] < start:
                lo = mid + 1
            else:
                hi = mid
        for i in range(lo, len(self.index)):
            for item in _iter_block(self._read_block(i)):
                if item[0] >= start:
                    yield item

    def close(self) -> None:
        self._f.close()
