"""DBConfig — one config selects among the paper's three systems.

``separation_mode``:

* ``"none"``  — RocksDB baseline: values ride WAL → MemTable → every level.
* ``"flush"`` — BlobDB/WiscKey baseline: separation at MemTable→L0 flush
  (full value still in WAL + MemTable).
* ``"wal"``   — **BVLSM**: separation before the WAL append; only
  Key-ValueOffset goes downstream.

``wal_mode``: ``"sync" | "async" | "off"`` — the paper's R-WS/R-WA/R-WO axes.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(slots=True)
class DBConfig:
    """Every engine knob, grouped by subsystem. The full table — each knob,
    its default, and one line of meaning — lives in
    ``docs/ARCHITECTURE.md``; the constructors ``rocksdb_like`` /
    ``blobdb_like`` / ``bvlsm`` pin ``separation_mode`` to the paper's
    three systems."""
    # --- the paper's variable ---
    separation_mode: str = "wal"  # none | flush | wal
    value_threshold: int = 4096  # bytes; >= threshold → separated
    # --- durability ---
    wal_mode: str = "sync"  # sync | async | off
    wal_flush_interval_s: float = 0.05
    wal_flush_bytes: int = 1 << 20
    # --- write pipeline (RocksDB-style leader/follower group commit) ---
    # When enabled, concurrent writers enqueue and the queue head ("leader")
    # commits every queued batch with ONE WAL write + fsync, then applies all
    # entries to the MemTable in bulk. False restores the pre-pipeline
    # one-record-one-fsync path (benchmark baseline).
    wal_group_commit: bool = True
    wal_group_max_batches: int = 128  # max writers merged into one group
    wal_group_max_entries: int = 4096  # max KV entries per group
    wal_group_max_bytes: int = 4 << 20  # hard ceiling on WAL payload bytes/group
    # --- pipelined commit (write pipeline v2) ---
    # The leader hands the writer queue off as soon as it has drained its
    # group: the next leader encodes + writes its WAL batch while the
    # previous group's fsync is still in flight. Groups publish (memtable
    # apply + follower wakeup) strictly in sequence order. False restores
    # the single-outstanding-group pipeline of PR 1 (≡ depth 1).
    wal_pipelined_commit: bool = True
    wal_pipeline_depth: int = 4  # max commit groups in flight at once
    # don't hand off into a near-empty queue: while an earlier group is
    # still in flight, a new group only forms once this many writers are
    # queued (or the pipeline drains) — tiny groups would pay full
    # per-group overhead for no extra amortization.
    wal_pipeline_min_fill: int = 4
    # --- adaptive group sizing ---
    # Replaces the fixed byte cap with a latency-target controller: the
    # effective cap grows (×1.5) while the persist-latency EWMA sits under
    # half the target and shrinks (×0.7) when it overshoots, clamped to
    # [wal_group_min_bytes, wal_group_max_bytes]. Entry/batch caps above
    # stay as hard ceilings.
    wal_group_adaptive: bool = True
    wal_group_target_latency_s: float = 0.004  # persist (write+fsync) target
    wal_group_min_bytes: int = 32 << 10  # adaptive cap floor
    wal_group_init_bytes: int = 256 << 10  # adaptive cap starting point
    # --- memtable ---
    memtable_size: int = 8 << 20  # paper: 128 MiB; scaled default for tests
    max_immutables: int = 2  # paper setup: 1 immutable (+5 mutable pool)
    # sharded apply: a commit group with at least this many entries is
    # partitioned by key hash across a small worker pool instead of applied
    # serially (0 disables). Keys never split across shards, so the result
    # is identical to the serial apply.
    memtable_shard_apply_entries: int = 4096
    memtable_apply_shards: int = 4
    # --- levels / compaction ---
    num_levels: int = 7
    l0_compaction_trigger: int = 4
    l0_slowdown_trigger: int = 8
    l0_stop_trigger: int = 12
    level1_max_bytes: int = 64 << 20
    level_size_multiplier: int = 10
    max_compaction_input_bytes: int = 256 << 20
    # --- write-amp-aware compaction picking ---
    # "overlap": among levels over their trigger, pick the candidate whose
    # job moves the most bytes per byte rewritten (urgency discounted by
    # 1 + overlap_bytes/input_bytes, the job's write amplification).
    # "fullness": the legacy policy — hottest level first, round-robin file
    # pointer within the level (the write-amp benchmark's ablation baseline).
    compaction_pick_policy: str = "overlap"  # overlap | fullness
    # a picked file with ZERO overlap at the target level is promoted by a
    # manifest edit alone — no read, no rewrite, no new tables. False
    # restores rewrite-everything (ablation baseline).
    trivial_move: bool = True
    # a trivial move is skipped (the file is rewritten instead) when the
    # moved file would overlap more than this many grandparent-level bytes
    # — parking a wide file at Ln+1 just makes the future Ln+1→Ln+2 job
    # more expensive than the rewrite it avoided. 0 = no limit.
    trivial_move_max_gp_bytes: int = 64 << 20
    # --- background job scheduler ---
    # flush jobs run on a dedicated high-priority pool so a long compaction
    # can never starve the flush that unblocks writers; compaction and GC
    # jobs share the low-priority pool (its width is also the cap on
    # concurrent compaction jobs — inputs are lock-disjoint).
    flush_threads: int = 1
    background_threads: int = 2
    # one compaction splits its key range into up to this many shards, each
    # merging + writing its own output tables; all shards commit as one
    # atomic manifest edit. 1 disables partitioning.
    max_subcompactions: int = 2
    # adaptive shard count: the number of shards is chosen from the live
    # input size and the historical per-shard merge throughput (EWMA), so
    # tiny compactions run unsharded (no fan-out overhead) and huge ones
    # use the full budget. False always fans out to max_subcompactions.
    subcompaction_adaptive: bool = True
    # target wall time for one shard: shards sized ewma_bytes_per_s × this
    subcompaction_target_seconds: float = 0.5
    # floor on the per-shard input size (also the pre-history default
    # target): inputs below this never shard at all
    subcompaction_min_bytes: int = 256 << 10
    # --- background I/O rate limiter ---
    # shared token bucket for every background byte written (compaction
    # output, flush, GC rewrites); flushes draw at high priority. 0 =
    # unlimited (limiter disabled, zero overhead).
    bg_io_bytes_per_sec: int = 0
    bg_io_refill_period_s: float = 0.005
    # unified device model: foreground BValue queue writes (WAL-time value
    # separation) charge the same token bucket at a foreground priority
    # that is accounted but never blocked — sustained value-log traffic
    # shrinks the refill available to compaction/GC (floored at
    # bg_io_min_fraction) instead of the two competing blindly for the
    # device. GC's value rewrites inherit LOW priority (they block on the
    # bucket like any background work). False restores the
    # background-only budget.
    unified_io_budget: bool = True
    # fraction of the bucket rate background work always keeps, no matter
    # how hard the foreground writes (starvation floor)
    bg_io_min_fraction: float = 0.1
    # --- delayed-write controller (replaces binary slowdown stalls) ---
    # above l0_slowdown_trigger / soft_pending_compaction_bytes, writers pay
    # a per-byte delay at a rate that decays ×0.8 while the backlog grows
    # and recovers ×1.25 as compaction catches up; at l0_stop_trigger /
    # hard_pending_compaction_bytes they block outright.
    delayed_write_rate: int = 32 << 20  # initial/max delayed rate, bytes/s
    delayed_write_min_rate: int = 1 << 20  # decay floor
    soft_pending_compaction_bytes: int = 64 << 20
    hard_pending_compaction_bytes: int = 256 << 20
    # overlap-aware debt estimate: pending-compaction bytes count not just
    # each level's excess but the target- and grandparent-level bytes the
    # excess will drag through rewrites on its way down (cascaded, each
    # step's overlap ratio clamped at level_size_multiplier) — the
    # controller sees real write debt instead of just displaced bytes.
    # False restores the excess-only estimate.
    pending_debt_overlap_aware: bool = True
    # --- background BValue GC ---
    # when enabled, a GC pass is scheduled (low priority) as soon as a
    # sealed BValue file's dead ratio crosses the trigger — typically right
    # after a compaction drops superseded pointers. The manual
    # ``DB.gc_collect`` API stays as a synchronous wrapper either way.
    gc_auto: bool = False
    gc_dead_ratio_trigger: float = 0.7
    # auto-GC pacing: one scheduled GC job rewrites at most this many live
    # bytes, then yields its LOW thread; the remaining candidates are
    # picked up by follow-up job slices (scheduled at the completion
    # edge), so one huge candidate file can't monopolize a background
    # thread for seconds. 0 = unsliced (one job runs the whole pass).
    # Manual ``gc_collect`` is always unsliced.
    gc_slice_bytes: int = 8 << 20
    # --- sstable ---
    block_size: int = 4096
    compression: bool = False
    # on-disk block format the WRITERS emit: 4 = v3 + prefix-compressed
    # keys inside restart intervals, 3 = v2 + range-tombstone side block
    # and multi-version (user_key, seq desc) runs, 2 = restart-point blocks
    # (intra-block binary search), 1 = the pre-restart linear format.
    # Readers always decode all four, so mixed-version DB directories are
    # fine — but range deletes require v3+ (delete_range raises below it).
    sstable_format_version: int = 4
    block_restart_interval: int = 16  # entries per restart point (v2 blocks)
    # --- batched reads ---
    # DB.multi_get slices caller batches to this size so one huge batch
    # can't pin a version/memtable set for an unbounded stretch.
    multi_get_max_batch: int = 1024
    # --- MVCC: snapshots / cursors / range deletes / checkpoint ---
    # hard cap on concurrently live Snapshot objects (cursors pin one
    # each). Every live snapshot widens memtable/compaction version
    # retention, so an unbounded leak would grow space forever; exceeding
    # the cap raises instead of silently degrading.
    max_snapshots: int = 1024
    # compaction clips range tombstones at output-table boundaries, which
    # fragments a wide delete across tables. When True, fragments of the
    # same tombstone (same seq) that touch or overlap are re-coalesced
    # before a table's range block is written, bounding fragmentation
    # growth across repeated compactions.
    range_tombstone_coalesce: bool = True
    # checkpoint(dir) hard-links SSTables + value files into the target
    # directory when the filesystem supports it; False (or a cross-device
    # link error) falls back to copying bytes.
    checkpoint_hardlink: bool = True
    # --- shared block cache (read path) ---
    # LRU over decoded data blocks, shared by gets/scans/compaction across
    # every SSTable, keyed (file_no, block_idx), charged by decoded bytes.
    # 0 disables caching entirely.
    block_cache_bytes: int = 8 << 20
    block_cache_shards: int = 8  # independent lock+LRU shards
    # compaction streams read THROUGH the cache but do not populate it, so
    # a one-shot merge can't evict the foreground working set. False lets
    # compaction warm the cache (useful when compaction output is hot).
    block_cache_compaction_bypass: bool = True
    # admission policy: "2q" (default) holds first-touch blocks in a
    # probationary FIFO (A1in) and only promotes to the main LRU (Am) on
    # re-reference — or on readmission while the block's key is still in
    # the A1out ghost history — so one-shot cursor sweeps can't flush the
    # point-get working set. "lru" restores the plain LRU of PR 3.
    block_cache_policy: str = "2q"  # 2q | lru
    # fraction of each shard's capacity reserved for the A1in probationary
    # queue (2Q only); the ghost list remembers ~cap/avg_block_size
    # recently evicted probationary keys at zero byte cost.
    block_cache_a1_fraction: float = 0.25
    # charge compaction's block READS against the unified I/O budget at
    # LOW priority when the bucket is enabled (bg_io_bytes_per_sec > 0),
    # so a read-heavy merge can no longer starve foreground unseen. False
    # restores write-only metering.
    compaction_read_metering: bool = True
    # --- BValue multi-queue store (paper §III-C) ---
    num_bvalue_queues: int = 4
    bvalue_dispatch: str = "round_robin"  # round_robin | least_loaded
    bvalue_page_size: int = 4096
    bvalue_batch_bytes: int = 1 << 20
    bvalue_max_file_bytes: int = 256 << 20
    bvalue_gather_window_s: float = 0.02
    # --- BVCache (paper §III-D) ---
    bvcache_bytes: int = 8 << 20  # paper: equal to MemTable capacity
    bvcache_policy: str = "lru"  # lru | lfu
    bvcache_enabled: bool = True  # ablation: False bypasses optimization
    # hits (pinned/unpersisted entries are still consulted — correctness)
    # --- failure handling (docs/ARCHITECTURE.md §Failure model & recovery) ---
    # pluggable filesystem layer: every open/write/fsync/rename/unlink/
    # listdir the engine performs goes through this Env. None = the real
    # filesystem (core.env.DEFAULT_ENV); tests pass a FaultInjectionEnv to
    # inject errors, simulate ENOSPC, drop unsynced writes on simulated
    # crash, and flip bytes for corruption checks.
    env: object | None = None
    # background jobs retry transient I/O errors this many times with
    # exponential backoff (base doubling each attempt, capped, ×jitter in
    # [0.5, 1.5)) before the error escalates to hard and latches the DB
    # read-only. 0 disables retries.
    bg_error_max_retries: int = 3
    bg_error_backoff_ms: float = 20.0
    bg_error_backoff_max_ms: float = 2000.0
    # --- replication (docs/ARCHITECTURE.md §Replication & failover) ---
    # path of the primary this instance follows. Setting it opens the DB as
    # a replica (equivalent to DB(path, cfg, role="replica")): user writes
    # are rejected until promote(), and replication.attach() uses it as the
    # default source for WAL catch-up reads.
    replica_of: str | None = None
    # target size of one shipped frame: a commit group larger than this is
    # split into multiple frames so a single fault (drop/corrupt) costs at
    # most this many bytes of retransmission via catch-up.
    repl_batch_bytes: int = 256 << 10
    # follower lag (in sequence numbers) above which each apply round bumps
    # the repl_lag_warnings counter — the observability hook a deployment
    # would alarm on.
    repl_lag_warn_seqs: int = 10_000
    # divergence detection: the stream carries a rolling CRC over each run
    # of this many consecutive sequence numbers; the follower folds the
    # same CRC over what it applied and re-bootstraps on mismatch instead
    # of silently forking.
    repl_crc_interval: int = 128
    # --- sharding router (core.sharded.ShardedDB; docs §Sharding) ---
    # each shard gets block_cache_bytes/N and bvcache_bytes/N so a sharded
    # store consumes the same total cache memory the config names. False
    # gives every shard the full budget (N× total memory — deliberate
    # over-provisioning for benchmarks or small N).
    shard_divide_cache_budget: bool = True
    # cross-shard WriteBatch durability log (ROUTER_LOG): once it grows
    # past this size with no batch in flight, the router flushes the
    # shards (their WALs then cover everything logged) and truncates it.
    router_log_max_bytes: int = 4 << 20
    # fan multi-shard operations (write apply, multi_get, flush/checkpoint
    # barriers) across a small thread pool instead of looping serially —
    # per-shard WAL fsyncs overlap. False keeps the router single-threaded
    # (deterministic orderings for debugging).
    router_parallel_fanout: bool = True
    # --- misc ---
    paranoid_checks: bool = False  # CRC-verify SSTable block + BValue reads
    sync_flush_io: bool = True

    def level_max_bytes(self, level: int) -> int:
        if level <= 0:
            return self.l0_compaction_trigger * self.memtable_size
        b = self.level1_max_bytes
        for _ in range(level - 1):
            b *= self.level_size_multiplier
        return b

    @staticmethod
    def rocksdb_like(**kw) -> "DBConfig":
        return DBConfig(separation_mode="none", **kw)

    @staticmethod
    def blobdb_like(**kw) -> "DBConfig":
        return DBConfig(separation_mode="flush", **kw)

    @staticmethod
    def bvlsm(**kw) -> "DBConfig":
        return DBConfig(separation_mode="wal", **kw)
