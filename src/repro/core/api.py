"""KVStore — the one client-facing protocol every store implements.

Both :class:`~repro.core.db.DB` (one engine) and
:class:`~repro.core.sharded.ShardedDB` (N engines behind a router)
satisfy this surface, so everything above the engine — the checkpoint
store, the serving stack, benchmarks, the differential harness — is
written against ``KVStore`` and runs unchanged on either. The protocol
is ``runtime_checkable`` for the conformance test
(``tests/test_api.py``), which parameterizes every behavioural check
over both implementations.

Opaque associated types: ``snapshot()`` returns *some* pinned read
point accepted back by ``get``/``multi_get``/``iterator``/``range`` of
the same store (``Snapshot`` for ``DB``, ``ShardedSnapshot`` for
``ShardedDB``) and released via ``.release()`` / ``with``; likewise
``iterator()`` returns a seek/next/prev cursor (``Cursor`` or
``MergedCursor``). The protocol deliberately types them as ``Any`` —
cross-store mixing is a programming error, not something the type
system promises to catch.

``scan(start, count)`` is NOT part of the protocol: it is deprecated
(both stores keep a ``DeprecationWarning`` shim) in favour of
``range(start, end=None, limit=None)``.
"""
from __future__ import annotations

from typing import Any, Iterator, Protocol, runtime_checkable


@runtime_checkable
class KVStore(Protocol):
    """Client surface shared by ``DB`` and ``ShardedDB``.

    The canonical way to obtain one is the ``open()`` classmethod on the
    concrete class (``DB.open(path, config=None)`` /
    ``ShardedDB.open(path, shards=N, config=None)``).
    """

    def put(self, key: bytes, value: bytes) -> None:
        """Durably (per ``wal_mode``) write ``key -> value``."""
        ...

    def get(self, key: bytes, snapshot: Any | None = None) -> bytes | None:
        """Point lookup at latest, or at a pinned ``snapshot``."""
        ...

    def multi_get(self, keys, snapshot: Any | None = None) -> list[bytes | None]:
        """Batched point lookup; result aligned with ``keys``."""
        ...

    def delete(self, key: bytes) -> None:
        """Tombstone ``key``."""
        ...

    def delete_range(self, start: bytes, end: bytes) -> None:
        """Range-tombstone every key in ``[start, end)``."""
        ...

    def write(self, batch: Any) -> None:
        """Apply a ``WriteBatch`` atomically (see the implementation's
        documented cross-shard semantics for ``ShardedDB``)."""
        ...

    def range(
        self,
        start: bytes = b"",
        end: bytes | None = None,
        limit: int | None = None,
        snapshot: Any | None = None,
    ) -> Iterator[tuple[bytes, bytes]]:
        """Stream live ``(key, value)`` pairs with ``start <= key``
        (``< end`` when given), ascending, up to ``limit``."""
        ...

    def iterator(self, snapshot: Any | None = None) -> Any:
        """A seek/next/prev cursor over a stable read point."""
        ...

    def snapshot(self) -> Any:
        """Pin the current read point; release via ``.release()``."""
        ...

    def checkpoint(self, directory: str) -> None:
        """Materialize a consistent, openable copy in ``directory``."""
        ...

    def stats(self) -> dict:
        """One consistent dict of engine counters/gauges."""
        ...

    def flush(self) -> None:
        """Synchronous durability barrier."""
        ...

    def close(self) -> None:
        """Release every resource; the store is unusable afterwards."""
        ...
