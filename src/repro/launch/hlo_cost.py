"""Static cost model over compiled (post-SPMD) HLO text with **loop
attribution** — XLA's built-in ``cost_analysis()`` counts a while body once,
which undercounts scanned models (layers × microbatches) by orders of
magnitude. This analyzer:

* parses every computation block and its instructions,
* resolves while-loop trip counts from the loop condition's comparison
  constant (jax ``scan`` lowers to a 0..N counter),
* recursively accumulates per-computation FLOPs (dot ops: 2·|out|·|contract|),
  boundary memory traffic (op output + unique operand bytes; fusion internals
  free), and collective wire bytes (ring-corrected, ICI vs DCN by replica
  group), each scaled by the product of enclosing trip counts.

Known model limitations (documented in EXPERIMENTS.md): CPU-backend HLO
emulates bf16 via f32 (inflates byte counts ~2×, FLOPs unaffected); fusion
granularity differs from TPU; DUS counted at full operand width.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: dict[str, Instr] = field(default_factory=dict)
    params: dict[str, str] = field(default_factory=dict)  # param name -> type str
    root: str = ""


_COMMENT_RE = re.compile(r"/\*.*?\*/")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*?)\)(.*)$"
)
_OPERAND = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = ""
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{"):
            head = line.lstrip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY") :].lstrip()
            if head.startswith("%") or head.startswith("HloModule") is False:
                name = head.split()[0].lstrip("%").rstrip("(")
                # strip a trailing "(args...)" glued to the name
                name = name.split("(")[0]
                if name and name != "HloModule":
                    cur = Computation(name)
                    comps[cur.name] = cur
                    if is_entry:
                        entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, args, attrs = m.groups()
        if opcode == "parameter":  # e.g. %p = f32[2,3] parameter(0)
            cur.params[name] = type_str
        operands = _OPERAND.findall(args)
        cur.instrs[name] = Instr(name, type_str, opcode, operands, attrs, line)
        if line.lstrip().startswith("ROOT"):
            cur.root = name
    return comps, entry


# ---------------------------------------------------------------------------
# per-instruction costs
# ---------------------------------------------------------------------------


def _operand_type(comp: Computation, comps: dict[str, Computation], op: str) -> str | None:
    ins = comp.instrs.get(op)
    if ins is not None:
        return ins.type_str
    return comp.params.get(op)


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _dot_flops(comp: Computation, comps, ins: Instr) -> float:
    out = _first_shape(ins.type_str)
    if out is None:
        return 0.0
    out_elems = 1
    for d in out[1]:
        out_elems *= d
    lhs_t = _operand_type(comp, comps, ins.operands[0]) if ins.operands else None
    contract = 1
    if lhs_t:
        lhs = _first_shape(lhs_t)
        m = _CONTRACT_RE.search(ins.attrs)
        if lhs and m and m.group(1):
            for d in m.group(1).split(","):
                contract *= lhs[1][int(d)]
    return 2.0 * out_elems * contract


_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[[\d,]+\]<=\[[\d,]+\](?:T\([\d,]+\))?)")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _group_info(attrs: str, pod_size: int) -> tuple[int, bool]:
    m = _GROUPS_RE.search(attrs)
    if m:
        spec = m.group(1)
        if spec.startswith("{{"):
            first = spec[2:].split("}", 1)[0]
            ids = [int(x) for x in first.split(",") if x.strip()]
            return max(len(ids), 1), len({i // pod_size for i in ids}) > 1
        m2 = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](T\([\d,]+\))?", spec)
        if m2:
            n = int(m2.group(2))
            dims = [int(x) for x in m2.group(3).split(",")]
            total = 1
            for d in dims:
                total *= d
            trans = m2.group(4)
            if trans:
                import numpy as np

                perm = [int(x) for x in trans[2:-1].split(",")]
                ids = np.arange(total).reshape(dims).transpose(perm).reshape(-1)[:n]
                crosses = len({int(i) // pod_size for i in ids}) > 1
            else:
                crosses = n > pod_size
            return n, crosses
    m = _SRC_TGT_RE.search(attrs)
    if m:
        crosses = any(
            int(a) // pod_size != int(b) // pod_size
            for a, b in re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        )
        return 2, crosses
    return 1, False


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "reshape", "broadcast", "convert", "copy-done",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
}


_TRIP_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COMPARE = re.compile(r"compare\((.*?)\).*direction=(\w+)")


def _trip_count(cond: Computation) -> int:
    """jax scan conditions are `lt(counter, constant)` — take the constant."""
    consts = {}
    for ins in cond.instrs.values():
        if ins.opcode == "constant":
            m = _TRIP_CONST.search(ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs.values():
        if ins.opcode == "compare":
            for op in ins.operands:
                if op in consts:
                    return consts[op]
    return max(consts.values(), default=1)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    wire_ici: float = 0.0
    wire_dcn: float = 0.0
    wire_f32: float = 0.0  # collective wire carried in 4-byte lanes
    per_coll: dict = field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        self.wire_ici += other.wire_ici * scale
        self.wire_dcn += other.wire_dcn * scale
        self.wire_f32 += other.wire_f32 * scale
        for k, v in other.per_coll.items():
            rec = self.per_coll.setdefault(k, {"count": 0.0, "wire": 0.0})
            rec["count"] += v["count"] * scale
            rec["wire"] += v["wire"] * scale


_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def analyze(text: str, pod_size: int = 256) -> dict:
    comps, entry = parse_hlo(text)
    memo: dict[str, Cost] = {}

    def comp_cost(name: str, in_fusion: bool = False) -> Cost:
        key = f"{name}|{in_fusion}"
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        c = Cost()
        if comp is None:
            memo[key] = c
            return c
        for ins in comp.instrs.values():
            c.add(_instr_cost(comp, ins, in_fusion))
        memo[key] = c
        return c

    def _instr_cost(comp: Computation, ins: Instr, in_fusion: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        if op == "while":
            body = _CALLS_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            trip = 1
            if cond and cond.group(1) in comps:
                trip = _trip_count(comps[cond.group(1)])
            if body:
                c.add(comp_cost(body.group(1)), scale=trip)
            return c
        if op in ("call", "conditional"):
            for m in _CALLS_RE.finditer(ins.attrs):
                c.add(comp_cost(m.group(1)))
            return c
        if op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            root_op = ""
            if m:
                inner = comp_cost(m.group(1), in_fusion=True)
                c.flops += inner.flops  # dots inside fusions still count
                fused = comps.get(m.group(1))
                if fused and fused.root:
                    root_op = fused.instrs[fused.root].opcode
            if not in_fusion:
                out_b = _type_bytes(ins.type_str)
                opnd_b = []
                for opnd in set(ins.operands):
                    t = _operand_type(comp, comps, opnd)
                    if t:
                        opnd_b.append(_type_bytes(t))
                if root_op == "dynamic-update-slice":
                    # in-place update: traffic ≈ the small operands (update
                    # slice + indices), not the aliased full buffer
                    small = sum(b for b in opnd_b if b < max(out_b // 4, 1))
                    c.bytes += 2 * small if small else out_b
                else:
                    # skip operands ≫ output: they are sliced/gathered inside
                    c.bytes += out_b + sum(b for b in opnd_b if b <= 4 * out_b)
            return c
        if op.rstrip("-start") in _COLLECTIVES or op in _COLLECTIVES:
            base = op[:-6] if op.endswith("-start") else op
            nbytes = _type_bytes(ins.type_str)
            n, crosses = _group_info(ins.attrs, pod_size)
            if n > 1:
                ring = (n - 1) / n
                if base == "all-gather":
                    wire = ring * nbytes
                elif base == "reduce-scatter":
                    wire = (n - 1) * nbytes
                elif base == "all-reduce":
                    wire = 2 * ring * nbytes
                elif base == "all-to-all":
                    wire = ring * nbytes
                else:
                    wire = nbytes
                c.coll_bytes += nbytes
                if "f32[" in ins.type_str or "s32[" in ins.type_str:
                    c.wire_f32 += wire
                if crosses:
                    c.wire_dcn += wire
                else:
                    c.wire_ici += wire
                rec = c.per_coll.setdefault(base + ("_dcn" if crosses else "_ici"), {"count": 0, "wire": 0.0})
                rec["count"] += 1
                rec["wire"] += wire
            if not in_fusion:
                c.bytes += nbytes
            return c
        if op == "dot" or op == "convolution":
            c.flops += _dot_flops(comp, comps, ins)
        if in_fusion or op in _ZERO_COST:
            return c
        # sliced accesses touch only the slice, not the full (aliased) buffer
        if op == "dynamic-slice" or op == "slice":
            c.bytes += 2 * _type_bytes(ins.type_str)  # read slice + write out
            return c
        if op == "dynamic-update-slice":
            upd = _operand_type(comp, comps, ins.operands[1]) if len(ins.operands) > 1 else None
            c.bytes += 2 * _type_bytes(upd) if upd else _type_bytes(ins.type_str)
            return c
        if op == "gather":
            c.bytes += 2 * _type_bytes(ins.type_str)
            return c
        if op == "scatter":
            upd = _operand_type(comp, comps, ins.operands[-1]) if ins.operands else None
            c.bytes += 3 * _type_bytes(upd) if upd else _type_bytes(ins.type_str)
            return c
        # boundary memory traffic: output + unique operands
        c.bytes += _type_bytes(ins.type_str)
        for opnd in set(ins.operands):
            t = _operand_type(comp, comps, opnd)
            if t:
                c.bytes += _type_bytes(t)
        return c

    total = comp_cost(entry)
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "coll_bytes": total.coll_bytes,
        "wire_ici": total.wire_ici,
        "wire_dcn": total.wire_dcn,
        "wire_f32": total.wire_f32,
        "per_coll": total.per_coll,
    }
