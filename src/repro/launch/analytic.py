"""Analytic (idealized-TPU) traffic & FLOPs model per cell.

The HLO-derived byte count is an *upper bound* contaminated by CPU-backend
lowering (bf16 emulated in f32, unfused converts that a TPU pipeline fuses
into the surrounding matmuls). For the roofline's memory term we therefore
use this analytic model — standard practice for roofline analysis — and
report the HLO number alongside as a diagnostic.

MODEL_FLOPS here is the spec's 6·N·D (train) / 2·N·D (inference) with
N = active params, D = tokens, plus the attention term — used for the
"useful compute" ratio against loop-attributed HLO FLOPs.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCell

BF16 = 2
F32 = 4


def _shards(mesh_shape: dict) -> tuple[int, int, int]:
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    model = mesh_shape.get("model", 1)
    total = data * model
    return data, model, total


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Global useful FLOPs for the step (all chips)."""
    N = cfg.active_params_count()
    if cell.kind == "train":
        D = cell.global_batch * cell.seq_len
        base = 6.0 * N * D
        attn_mult = 3.0  # fwd + bwd
    elif cell.kind == "prefill":
        D = cell.global_batch * cell.seq_len
        base = 2.0 * N * D
        attn_mult = 1.0
    else:  # decode: one token per sequence
        D = cell.global_batch
        base = 2.0 * N * D
        attn_mult = 1.0

    # attention FLOPs: 4·H·hd per (q,k) pair per layer (QKᵀ + PV)
    attn = 0.0
    if cfg.n_heads and cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        H = cfg.n_heads
        if cfg.family == "hybrid":
            n_attn = sum(1 for i in range(cfg.n_layers) if cfg._layer_kind(i) == "A")
            win = cfg.window
        else:
            n_attn = cfg.n_layers
            win = None
        if cell.kind == "decode":
            kv = min(cell.seq_len, win) if win else cell.seq_len
            attn = 4.0 * H * hd * kv * cell.global_batch * n_attn
        else:
            S = cell.seq_len
            avg_kv = min(S, win) / 1 if win else S / 2  # causal average
            if win:
                avg_kv = min(S / 2, win)
            attn = 4.0 * H * hd * avg_kv * S * cell.global_batch * n_attn * attn_mult
        if cfg.enc_dec:
            E = cfg.enc_len
            if cell.kind == "decode":
                # decode reuses cached encoder K/V: cross-attn for 1 token only
                attn += 4.0 * H * hd * E * cell.global_batch * cfg.n_layers
            else:
                # encoder self-attn + decoder cross-attn
                attn += (
                    4.0 * H * hd * E * E * cell.global_batch * cfg.enc_layers
                    + 4.0 * H * hd * E * cell.seq_len * cell.global_batch * cfg.n_layers
                ) * attn_mult
    return base + attn


def analytic_memory_bytes(cfg: ModelConfig, cell: ShapeCell, mesh_shape: dict, accum: int = 1) -> float:
    """Idealized per-chip HBM traffic for one step."""
    data, model, total = _shards(mesh_shape)
    N = cfg.params_count()
    d = cfg.d_model
    L = max(cfg.n_layers, 1)
    B = cell.global_batch
    S = cell.seq_len
    b_local = max(B // data, 1)

    if cell.kind == "train":
        # weights: each microstep reads the model-shard of bf16 weights for
        # fwd + remat-fwd + bwd (3×); FSDP gather traffic is collective, but
        # the gathered copy is read from HBM locally.
        w = 3.0 * accum * N * BF16 / model
        # optimizer: read p,m,v + write p,m,v (fp32, fully sharded)
        opt = 6.0 * N * F32 / total
        # gradients: accumulate read+write fp32 per microstep (sharded)
        gacc = 2.0 * accum * N * F32 / total if accum > 1 else 2.0 * N * F32 / total
        # activations: ~30 (b,t,d)-sized reads+writes per layer (fwd+bwd+remat)
        tokens_micro = b_local * S / accum if cell.kind == "train" else b_local * S
        act = 30.0 * L * tokens_micro * d * BF16 * accum
        return w + opt + gacc + act

    if cell.kind == "prefill":
        w = N * BF16 / model
        act = 12.0 * L * b_local * S * d * BF16
        cache = cache_bytes(cfg, cell, mesh_shape)  # write once
        return w + act + cache

    # decode
    w = N * BF16 / model
    cache = cache_bytes(cfg, cell, mesh_shape)  # read once + tiny write
    act = 12.0 * L * b_local * d * BF16
    return w + cache + act


def cache_bytes(cfg: ModelConfig, cell: ShapeCell, mesh_shape: dict) -> float:
    """Per-chip bytes of the decode state/cache."""
    data, model, total = _shards(mesh_shape)
    B, S = cell.global_batch, cell.seq_len
    b_local = max(B // data, 1)
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
        per_seq = nh * cfg.ssm_head_dim * cfg.ssm_state * F32 + conv_dim * (cfg.conv_kernel - 1) * BF16
        return cfg.n_layers * b_local * per_seq
    if cfg.family == "hybrid":
        w = cfg.rnn_width or cfg.d_model
        n_rec = sum(1 for i in range(cfg.n_layers) if cfg._layer_kind(i) == "R")
        n_att = cfg.n_layers - n_rec
        rec = n_rec * b_local * (w * F32 + w * (cfg.conv_kernel - 1) * BF16)
        att = n_att * b_local * min(S, cfg.window) * cfg.n_kv_heads * hd * 2 * BF16 / model
        return rec + att
    kv_len = S
    per = cfg.n_layers * b_local * kv_len * cfg.n_kv_heads * hd * 2 * BF16 / model
    if cfg.enc_dec:
        per += cfg.n_layers * b_local * cfg.enc_len * cfg.n_kv_heads * hd * 2 * BF16
    return per
