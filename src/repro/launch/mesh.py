"""Production mesh definitions.

A function (never a module-level constant) so importing this module does not
touch jax device state. Single pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods × 256 as (pod=2, data=16, model=16) with the ``pod``
axis crossing DCN.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit Auto axis types; 0.4.x has no AxisType and
    # every axis is GSPMD-auto already.
    if hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n) if n > 1 else (1, 1)
    return _make_mesh(shape, axes)


# TPU v5e-ish hardware model used by the roofline analysis (given constants).
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
    "dcn_bw": 6.25e9,  # bytes/s per chip across pods (assumption, see DESIGN)
    "hbm_bytes": 16 * 2**30,
}
